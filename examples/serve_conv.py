"""Fault-tolerant conv serving example: MobileNet-v2 behind the batched
serving runtime, warm-started from per-bucket NetworkPlan artifacts, with
a live fault drill against the supervisor's degrade ladder.

First run compiles one plan per batch bucket and saves the artifacts
(cold); re-running warm-starts every bucket from disk with zero filter
transforms. The drill then injects a permanent executor failure into one
layer mid-traffic and shows the ladder re-place it onto the im2row
fallback without dropping a single in-flight request.

  PYTHONPATH=src python examples/serve_conv.py                 # res 96
  PYTHONPATH=src python examples/serve_conv.py --res 224       # paper res
  PYTHONPATH=src python examples/serve_conv.py --artifacts DIR # warm demo
"""

import argparse
import tempfile

import numpy as np

import jax

from repro.models import cnn
from repro.runtime import inject
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="mobilenet_v2",
                    choices=sorted(cnn.NETWORKS))
    ap.add_argument("--res", type=int, default=96)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--artifacts", default=None,
                    help="artifact dir (default: a temp dir -- pass a real "
                         "path and re-run to see the warm start)")
    args = ap.parse_args()

    specs_fn, _ = cnn.NETWORKS[args.net]
    specs = specs_fn()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=args.res)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((args.res, args.res, 3)).astype(np.float32)
          for _ in range(8)]

    art = args.artifacts or tempfile.mkdtemp(prefix="serve_conv_")
    cfg = ServeConfig(buckets=(1, 2, 4), queue_capacity=32, verbose=True)
    srv = Server(params, specs, res=args.res, algorithm="auto", config=cfg,
                 artifact_dir=art)
    s = srv.stats
    print(f"[serve_conv] {args.net}@{args.res}: "
          f"{s.artifact_warm_starts} warm / {s.artifact_cold_starts} cold "
          f"bucket plans from {art}")

    with srv:
        tickets = [srv.submit(xs[i % len(xs)], deadline_s=30.0)
                   for i in range(args.requests)]
        ys = [t.result(timeout=300) for t in tickets]
        lat = sorted(t.latency_s for t in tickets)
        print(f"[serve_conv] clean: {len(ys)} served, "
              f"p50 {lat[len(lat) // 2] * 1e3:.1f} ms, "
              f"buckets {srv.stats.bucket_batches}")

        # fault drill: a permanently failing executor in one mid layer.
        victim = sorted(srv.nets[1].plans)[len(srv.nets[1].plans) // 2]
        print(f"[serve_conv] injecting permanent executor failure into "
              f"layer {victim!r} ...")
        inject.install_on_server(srv, inject.ExecutorRaise(victim))
        tickets = [srv.submit(xs[i % len(xs)]) for i in range(args.requests)]
        ys2 = [t.result(timeout=300) for t in tickets]

    s = srv.stats.snapshot()
    print(f"[serve_conv] drill: {len(ys2)} served through the fault -- "
          f"retries={s['retries']}, replacements={s['replacements']}, "
          f"failed={s['failed']}, dropped={s['in_flight']}")
    err = max(float(np.max(np.abs(ys2[i] - ys[i]))
                    / (np.max(np.abs(ys[i])) + 1e-9))
              for i in range(len(ys2)))
    print(f"[serve_conv] parity vs pre-fault outputs: "
          f"max rel err {err:.2e}")
    assert s["in_flight"] == 0 and s["failed"] == 0 and err < 2e-3


if __name__ == "__main__":
    main()
