"""Quickstart: the paper's region-wise multi-channel Winograd convolution as
a drop-in JAX op.

  PYTHONPATH=src python examples/quickstart.py

Shows: (1) the unified conv entry point with algorithm selection, (2) the
correctness contract vs direct convolution, (3) the multiplication-reduction
math that motivates the whole paper, (4) the Pallas TPU kernel path
(interpret=True on CPU).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import conv2d
from repro.core.im2col import direct_conv2d
from repro.core.transforms import cook_toom
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 56, 56, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) / 3, jnp.float32)

    # 1. the three algorithm choices, one entry point ------------------------
    y_wino = conv2d(x, w, algorithm="winograd")    # paper's fast scheme
    y_im2c = conv2d(x, w, algorithm="im2col")      # paper's baseline
    y_auto = conv2d(x, w, algorithm="auto")        # paper's mixed policy
    y_ref = direct_conv2d(x, w)

    for name, y in [("winograd", y_wino), ("im2col", y_im2c),
                    ("auto", y_auto)]:
        err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
        print(f"{name:9s}: shape={tuple(y.shape)} rel_err={err:.2e}")

    # 2. the multiplication-reduction math -----------------------------------
    for m, r in [(2, 3), (4, 3), (2, 5), (2, 7)]:
        ct = cook_toom(m, r)
        print(f"F({m}x{m}, {r}x{r}): {m*m*r*r:4d} MACs -> {ct.t**2:3d} "
              f"multiplies ({ct.mult_reduction_2d:.2f}x reduction)")

    # 3. wall-clock comparison (jitted, batch 1 -- the paper's setting) ------
    f_w = jax.jit(lambda x, w: conv2d(x, w, algorithm="winograd"))
    f_i = jax.jit(lambda x, w: conv2d(x, w, algorithm="im2col"))
    for f in (f_w, f_i):
        jax.block_until_ready(f(x, w))
    t = {}
    for name, f in [("winograd", f_w), ("im2col", f_i)]:
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(x, w))
        t[name] = (time.perf_counter() - t0) / 5
    print(f"\n56x56x64->64 3x3 conv: im2col {t['im2col']*1e3:.1f}ms, "
          f"winograd {t['winograd']*1e3:.1f}ms "
          f"({t['im2col']/t['winograd']:.2f}x speedup)")

    # 4. the Pallas TPU kernel (fused transform+GEMM+inverse in VMEM) --------
    y_pallas = ops.winograd_conv2d(x, w, interpret=True)
    err = float(jnp.max(jnp.abs(y_pallas - y_ref)) / jnp.max(jnp.abs(y_ref)))
    print(f"pallas winograd kernel (interpret): rel_err={err:.2e}")

    # 5. the plan/execute split (paper section 4: transform filters ONCE) ----
    from repro.core.plan import plan_conv2d
    plan = plan_conv2d(x.shape, w, algorithm="auto")   # decisions + filter
    f_p = jax.jit(plan.apply)
    y_plan = f_p(x)
    err = float(jnp.max(jnp.abs(y_plan - y_ref)) / jnp.max(jnp.abs(y_ref)))
    jax.block_until_ready(f_p(x))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f_p(x))
    t_planned = (time.perf_counter() - t0) / 5
    print(f"planned ({plan.algorithm}, filter pre-transformed once): "
          f"rel_err={err:.2e} steady-state {t_planned*1e3:.1f}ms "
          f"vs per-call {t['winograd']*1e3:.1f}ms")

    # 6. the graph compiler + deployment artifact (compile/save/load) --------
    import os
    import tempfile

    from repro.core.compile import NetworkPlan, compile as compile_network
    from repro.models import cnn

    specs = cnn.NETWORKS["mobilenet_v1_050"][0]()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=64)
    net = compile_network(params, specs, res=64)   # lower->fuse->place->bind
    xin = jnp.asarray(rng.standard_normal((1, 64, 64, 3)), jnp.float32)
    y_cold = net.apply(xin)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "mbv1.npz")
        net.save(path)                             # pre-transformed weights +
        warm = NetworkPlan.load(path)              # per-layer decisions
        same = bool(jnp.all(warm.apply(xin) == y_cold))
    n_fused = sum(1 for row in net.describe().splitlines()
                  if "separable" in row)
    print(f"compile(): {len(net)} layer plans ({n_fused} fused separable "
          f"blocks), save/load round-trip bitwise identical: {same}")


if __name__ == "__main__":
    main()
