"""Paper scenario: SqueezeNet inference on the framework's CNN zoo, flipping
between the paper's two benchmark configurations.

  PYTHONPATH=src python examples/cnn_inference.py [--network squeezenet]

Reproduces the Table 1 measurement protocol for one network: batch-1 latency
with (a) region-wise multi-channel Winograd on suitable layers + im2row on
the rest ("auto"), vs (b) im2row everywhere.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="squeezenet",
                    choices=sorted(cnn.NETWORKS))
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    specs_fn, res = cnn.NETWORKS[args.network]
    specs = specs_fn()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, res, res, 3)),
                    jnp.float32)

    # layer census: which layers does the paper's scheme accelerate?
    layers = {}
    jax.eval_shape(lambda x: cnn.cnn_forward(params, x, specs,
                                             algorithm="im2col",
                                             layer_times=layers), x)
    fast = [k for k, v in layers.items() if v["suitable"]]
    print(f"{args.network}: {len(layers)} conv layers, "
          f"{len(fast)} Winograd-suitable")

    outs = {}
    for algo in ("im2col", "auto"):
        fn = jax.jit(lambda x: cnn.cnn_forward(params, x, specs,
                                               algorithm=algo))
        outs[algo] = jax.block_until_ready(fn(x))    # compile+check
        t0 = time.perf_counter()
        for _ in range(args.iters):
            jax.block_until_ready(fn(x))
        dt = (time.perf_counter() - t0) / args.iters
        print(f"algorithm={algo:7s}: {dt*1e3:8.1f} ms/inference "
              f"({1/dt:.1f} fps)")

    err = float(jnp.max(jnp.abs(outs["auto"] - outs["im2col"]))
                / (jnp.max(jnp.abs(outs["im2col"])) + 1e-9))
    print(f"prediction agreement between schemes: rel_err={err:.2e}")
    assert err < 1e-3


if __name__ == "__main__":
    main()
