"""End-to-end driver: train a ~100M-parameter qwen2.5-family model for a few
hundred steps with the full production stack -- sharded init, deterministic
prefetched data, ZeRO AdamW, grad accumulation, async checkpointing,
preemption guard, crash retry.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

The ~100M config is the real qwen2_5_3b block structure at reduced width
(d_model 512, 12 layers), i.e. a genuine member of the same family, not a toy.
"""

import argparse
import dataclasses

from repro import configs as cfglib
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12 x (d=768, ff=2048, 12 heads GQA kv=2) + 32k vocab
    base = cfglib.get_config("qwen2_5_3b")
    cfg = dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=2, head_dim=64,
        d_ff=2048, vocab=32_768, max_seq=args.seq, logits_chunk=128)
    n = cfg.n_params
    print(f"[example] training {cfg.name}-100m: {n/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    import repro.configs as c

    # route through the registry so train() picks the custom config
    orig = c.get_config
    c.get_config = lambda name: cfg if name == "custom_100m" else orig(name)
    try:
        _, history = train("custom_100m", steps=args.steps, batch=args.batch,
                           seq=args.seq, smoke=False, ckpt_dir=args.ckpt_dir,
                           ckpt_every=100, accum=2, lr=1e-3, log_every=20)
    finally:
        c.get_config = orig
    print(f"[example] loss {history[0]:.3f} -> {history[-1]:.3f} "
          f"({100*(1-history[-1]/history[0]):.0f}% reduction)")
    assert history[-1] < history[0], "training must reduce loss"


if __name__ == "__main__":
    main()
