"""The paper's technique inside an assigned architecture: falcon-mamba's
depthwise causal conv1d routed through the 1D Cook-Toom algorithm.

  PYTHONPATH=src python examples/mamba_cook_toom.py

Shows the per-layer A/B the dispatcher enables (conv_algorithm switch in
SSMConfig), the multiply-count reduction, and end-to-end equivalence of the
two paths through a full Mamba block.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core.transforms import cook_toom
from repro.core.winograd import ct_depthwise_causal_conv1d
from repro.models import mamba as ssm


def main():
    cfg = cfglib.get_smoke_config("falcon_mamba_7b")
    rng = np.random.default_rng(0)

    # --- the conv itself ----------------------------------------------------
    r = cfg.ssm.d_conv
    ct = cook_toom(4, r)
    print(f"mamba short conv: depthwise causal k={r}")
    print(f"F({ct.m},{ct.r}): {ct.m * ct.r} multiplies -> {ct.t} per channel "
          f"per tile ({ct.mult_reduction_1d:.2f}x reduction)")

    b, l, c = 4, 2048, 4096
    x = jnp.asarray(rng.standard_normal((b, l, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, c)) / r, jnp.float32)

    f_ct = jax.jit(lambda x, w: ct_depthwise_causal_conv1d(x, w))
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    f_direct = jax.jit(lambda x, w: sum(
        xp[:, k:k + l] * w[k][None, None] for k in range(r)))
    y_ct = jax.block_until_ready(f_ct(x, w))
    y_d = jax.block_until_ready(f_direct(x, w))
    err = float(jnp.max(jnp.abs(y_ct - y_d)) / jnp.max(jnp.abs(y_d)))
    print(f"cook-toom vs direct ({b}x{l}x{c}): rel_err={err:.2e}")

    t = {}
    for name, f in [("cook_toom", f_ct), ("direct", f_direct)]:
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(x, w))
        t[name] = (time.perf_counter() - t0) / 5
    print(f"direct {t['direct']*1e3:.1f}ms vs cook-toom "
          f"{t['cook_toom']*1e3:.1f}ms "
          f"({t['direct']/t['cook_toom']:.2f}x)")

    # --- through the full Mamba block ----------------------------------------
    p = ssm.init_mamba(jax.random.key(0), cfg, jnp.float32)
    xin = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    y1 = ssm.mamba_block(p, xin, cfg)            # cook_toom (config default)
    cfg_direct = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, conv_algorithm="direct"))
    y2 = ssm.mamba_block(p, xin, cfg_direct)
    err = float(jnp.max(jnp.abs(y1 - y2)) / jnp.max(jnp.abs(y2)))
    print(f"full mamba block, cook_toom vs direct: rel_err={err:.2e}")
    assert err < 1e-4


if __name__ == "__main__":
    main()
