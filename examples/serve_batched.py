"""Batched TRANSFORMER-decode serving example: continuous batching over a
smoke-size autoregressive model with mixed-length requests
(repro.launch.serve -- slot-based decode ticks, not the conv runtime).

For the conv side of the repo -- batched inference over compiled
NetworkPlan artifacts with bounded admission, deadlines, and the
fault-tolerant degrade ladder (repro.runtime.serve) -- see
examples/serve_conv.py.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen2_5_3b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.distributed import context as dist
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, Server
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = cfglib.get_smoke_config(args.arch)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with dist.use_mesh(mesh):
        params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab,
                                            size=(3 + i % 4,)).astype(np.int32),
                        max_new=args.max_new)
                for i in range(args.requests)]
        srv = Server(cfg, params, max_batch=args.max_batch, max_len=64,
                     mesh=mesh)
        t0 = time.time()
        done, ticks = srv.run(reqs)
        dt = time.time() - t0

    tok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests -> {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {ticks} decode ticks, "
          f"max_batch={args.max_batch})")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")
    assert len(done) == args.requests
    assert all(len(r.out) == args.max_new for r in done)


if __name__ == "__main__":
    main()
