"""Cook-Toom transform generator: exactness and algebraic invariants."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.transforms import (DEFAULT_OUTPUT_TILE, CookToom, cook_toom,
                                   correlate_1d_reference)

VARIANTS = [(2, 3), (4, 3), (6, 3), (2, 5), (4, 5), (2, 7), (4, 7),
            (2, 4), (3, 4), (4, 4), (2, 2), (1, 3), (5, 3)]


@pytest.mark.parametrize("m,r", VARIANTS)
def test_correlation_identity(m, r):
    """y = A^T[(G g) . (B^T d)] equals direct correlation, to fp64 precision."""
    ct = cook_toom(m, r)
    rng = np.random.default_rng(m * 100 + r)
    for _ in range(5):
        d = rng.standard_normal(ct.t)
        g = rng.standard_normal(r)
        y = correlate_1d_reference(ct, d, g)
        ref = np.array([sum(g[k] * d[i + k] for k in range(r))
                        for i in range(m)])
        np.testing.assert_allclose(y, ref, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("m,r", VARIANTS)
def test_shapes_and_reduction(m, r):
    ct = cook_toom(m, r)
    assert ct.t == m + r - 1
    assert ct.AT.shape == (m, ct.t)
    assert ct.G.shape == (ct.t, r)
    assert ct.BT.shape == (ct.t, ct.t)
    assert ct.mult_reduction_1d == pytest.approx(m * r / ct.t)


def test_f23_matches_known_multiplication_count():
    """F(2,3) uses 4 multiplies for 2 outputs (the classic 2.25x 2D case)."""
    ct = cook_toom(2, 3)
    assert ct.t == 4
    assert ct.mult_reduction_2d == pytest.approx(36 / 16)


def test_caching_and_hashability():
    a, b = cook_toom(4, 3), cook_toom(4, 3)
    assert a is b            # lru_cache
    assert hash(a) == hash(b)
    assert isinstance(a, CookToom)


def test_default_variants_cover_paper_filters():
    for r in (2, 3, 4, 5, 7):
        assert r in DEFAULT_OUTPUT_TILE
        ct = cook_toom(DEFAULT_OUTPUT_TILE[r], r)
        assert ct.t - 1 >= r - 1


@given(m=st.integers(1, 6), r=st.integers(2, 5))
@settings(max_examples=24, deadline=None)
def test_property_identity_any_variant(m, r):
    ct = cook_toom(m, r)
    rng = np.random.default_rng(m * 7 + r)
    d = rng.standard_normal(ct.t)
    g = rng.standard_normal(r)
    y = correlate_1d_reference(ct, d, g)
    ref = np.correlate(d, g, mode="valid")[:m]
    np.testing.assert_allclose(y, ref, rtol=1e-9, atol=1e-9)


def test_rejects_bad_args():
    with pytest.raises(ValueError):
        cook_toom(0, 3)
    with pytest.raises(ValueError):
        cook_toom(30, 30)
