"""Capability-declaring executor registry: resolution/coverage invariants,
error-message contracts (enumerate what DOES match), agreement between the
registry and the planner, and the doctest that the README algorithm table is
the registry's own rendering."""

import doctest
import os

import jax.numpy as jnp
import pytest

from repro.core import registry
from repro.core.plan import ALGORITHMS, algorithm_supported, plan_conv2d

_README = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "README.md")


def q(kh, kw, stride, groups=1, c_in=8, c_out=8):
    return registry.as_query(kh, kw, stride, groups=groups, c_in=c_in,
                             c_out=c_out)


# ---------------------------------------------------------------------------
# registry invariants
# ---------------------------------------------------------------------------

def test_families_are_the_requestable_algorithms():
    """Every registered family is a requestable algorithm name and every
    concrete algorithm name has at least one registered capability."""
    concrete = [a for a in ALGORITHMS if a not in ("auto", "auto_tuned")]
    assert sorted(registry.FAMILIES) == sorted(concrete)
    for fam in registry.FAMILIES:
        assert registry.family(fam), fam


def test_resolution_prefers_specialized_executor():
    assert registry.resolve("winograd", q(3, 3, 1)).executor == "winograd"
    assert registry.resolve("winograd", q(1, 7, 1)).executor == "winograd_1d"
    assert registry.resolve("winograd",
                            q(3, 3, 1, groups=8)).executor == \
        "winograd_depthwise"
    assert registry.resolve("winograd",
                            q(3, 3, 1, groups=4)).executor == \
        "winograd_grouped"
    assert registry.resolve("winograd", q(3, 3, 2)).executor == \
        "winograd_strided"
    assert registry.resolve("pallas_winograd", q(3, 3, 2)).executor == \
        "pallas_winograd_strided"
    assert registry.resolve("pallas_winograd",
                            q(3, 3, 2, groups=8)).executor == \
        "pallas_depthwise_strided"


def test_auto_selection_matches_paper_policy():
    assert registry.select_auto(q(3, 3, 1)).executor == "winograd"
    assert registry.select_auto(q(3, 3, 3)).executor == "im2col"
    assert registry.select_auto(q(1, 1, 1)).executor == "im2col"
    assert registry.select_auto(q(4, 4, 2)).executor == "im2col"


def test_strided_capability_covers_exactly_odd_sizes():
    for k in (3, 5, 7):
        assert registry.supported("winograd", q(k, k, 2))
    for k in (2, 4, 6, 8):
        assert not registry.supported("winograd", q(k, k, 2))
    # strided 1xN has no executor
    assert not registry.supported("winograd", q(1, 3, 2))


def test_error_enumerates_matching_executors():
    """The resolution error must name the executors that DO cover the layer
    and never claim a blanket 'need stride (1, 1)' -- the registry has
    stride-2 capabilities now."""
    err = registry.resolution_error("pallas_im2col", q(3, 3, 2, groups=8))
    msg = str(err)
    assert "winograd_strided" in msg            # what does cover it
    assert "pallas_depthwise_strided" in msg
    assert "algorithm='winograd'" in msg        # how to reach it
    assert "need stride (1, 1)" not in msg
    err = registry.resolution_error("winograd", q(4, 4, 3))
    assert "im2col" in str(err)                 # always an escape hatch


def test_error_raised_by_planner_matches_registry(rng):
    w = jnp.zeros((3, 3, 1, 8), jnp.float32)
    with pytest.raises(ValueError) as ei:
        plan_conv2d((1, 12, 12, 8), w, stride=2, groups=8,
                    algorithm="pallas_im2col")
    assert "pallas_depthwise_strided" in str(ei.value)


# ---------------------------------------------------------------------------
# planner <-> registry agreement (supplements the exhaustive sweep in
# tests/test_grouped.py::test_algorithm_supported_matches_plan_conv2d)
# ---------------------------------------------------------------------------

def test_algorithm_supported_is_a_registry_query():
    for kh, kw, stride, groups, c_in, c_out in [
            (3, 3, 2, 1, 8, 8), (3, 3, 2, 8, 8, 8), (3, 3, 2, 8, 8, 16),
            (5, 5, 2, 4, 8, 8), (4, 4, 2, 1, 8, 8)]:
        for alg in ALGORITHMS:
            got = algorithm_supported(alg, kh, kw, stride, groups=groups,
                                      c_in=c_in, c_out=c_out)
            want = registry.supported(
                alg, q(kh, kw, stride, groups, c_in, c_out))
            assert got == want, (alg, kh, kw, stride, groups)


def test_resolved_specs_carry_registry_executor_names():
    executors = {c.executor for c in registry.CAPABILITIES}
    w = jnp.zeros((3, 3, 8, 8), jnp.float32)
    for stride, alg in [(1, "auto"), (2, "auto"), (1, "pallas_winograd"),
                        (2, "pallas_winograd"), (2, "im2col")]:
        p = plan_conv2d((1, 16, 16, 8), w, stride=stride, algorithm=alg)
        assert p.algorithm in executors, (stride, alg, p.algorithm)


# ---------------------------------------------------------------------------
# README table: generated from the registry, doctest'd
# ---------------------------------------------------------------------------

def test_capability_table_doctests():
    results = doctest.testmod(registry)
    assert results.attempted > 0 and results.failed == 0


def test_readme_table_matches_registry():
    """The committed README algorithm table IS capability_table()'s output:
    docs cannot drift from the declared capabilities."""
    with open(_README) as f:
        readme = f.read()
    table = registry.capability_table()
    assert table in readme, (
        "README.md capability table is stale; regenerate the block between "
        "the CAPABILITY TABLE markers with "
        "repro.core.registry.capability_table()")
