"""Property-based tests (hypothesis) for the system's core invariants:

  * cook_toom(m, r) transform identities hold for every variant in range;
  * the region-wise multi-channel scheme == direct convolution for arbitrary
    shapes, filter sizes, paddings, output tiles (2D, 1D rows/cols, 1x1);
  * dispatch policy invariants (suitability is necessary & sufficient);
  * im2row lowering == direct convolution for arbitrary strides.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dispatch, im2col
from repro.core.transforms import cook_toom, correlate_1d_reference
from repro.core.winograd import ct_depthwise_causal_conv1d, winograd_conv2d

from conftest import rel_err

_SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# transform-matrix identities
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(m=st.integers(1, 6), r=st.integers(1, 7), data=st.data())
def test_cook_toom_identity_correlation(m, r, data):
    """A^T[(Gg) . (B^T d)] == valid correlation of d with g, exactly."""
    if m + r - 1 - 1 > 13:
        return
    ct = cook_toom(m, r)
    d = np.array(data.draw(st.lists(
        st.floats(-4, 4, allow_nan=False), min_size=ct.t, max_size=ct.t)))
    g = np.array(data.draw(st.lists(
        st.floats(-4, 4, allow_nan=False), min_size=r, max_size=r)))
    got = correlate_1d_reference(ct, d, g)
    want = np.array([np.dot(d[i:i + r], g) for i in range(m)])
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


@settings(**_SETTINGS)
@given(m=st.integers(1, 6), r=st.integers(1, 7))
def test_cook_toom_shapes_and_reduction(m, r):
    if m + r - 1 - 1 > 13:
        return
    ct = cook_toom(m, r)
    assert ct.AT.shape == (m, ct.t)
    assert ct.G.shape == (ct.t, r)
    assert ct.BT.shape == (ct.t, ct.t)
    # the bilinear algorithm uses t multiplies for m*r MACs
    assert ct.t == m + r - 1
    assert ct.mult_reduction_1d == (m * r) / ct.t


# ---------------------------------------------------------------------------
# region-wise multi-channel winograd == direct conv
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(
    h=st.integers(5, 20), w=st.integers(5, 20),
    c=st.integers(1, 9), mo=st.integers(1, 9),
    k=st.sampled_from([3, 5]), mt=st.sampled_from([2, 4]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_winograd2d_equals_direct(h, w, c, mo, k, mt, padding, seed):
    if padding == "VALID" and (h < k or w < k):
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, c, mo)) / k, jnp.float32)
    got = winograd_conv2d(x, wt, output_tile=mt, padding=padding)
    want = im2col.direct_conv2d(x, wt, padding=padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


@settings(**_SETTINGS)
@given(
    axis=st.sampled_from(["row", "col"]),
    k=st.sampled_from([3, 7]),
    size=st.integers(8, 24), other=st.integers(3, 10),
    c=st.integers(1, 6), mo=st.integers(1, 6),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_winograd_1d_rows_cols_equals_direct(axis, k, size, other, c, mo,
                                             padding, seed):
    """The paper's 1xN / Nx1 case (Inception-v3 1x7/7x1 layers)."""
    rng = np.random.default_rng(seed)
    kh, kw = (k, 1) if axis == "row" else (1, k)
    h, w = (size, other) if axis == "row" else (other, size)
    x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((kh, kw, c, mo)) / k, jnp.float32)
    got = winograd_conv2d(x, wt, output_tile=2, padding=padding)
    want = im2col.direct_conv2d(x, wt, padding=padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


@settings(**_SETTINGS)
@given(
    length=st.integers(1, 65), c=st.integers(1, 12),
    r=st.integers(2, 4), mt=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ct_depthwise_causal_equals_direct(length, c, r, mt, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, length, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, c)), jnp.float32)
    got = ct_depthwise_causal_conv1d(x, w, output_tile=mt)
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    want = sum(xp[:, i:i + length] * w[i][None, None] for i in range(r))
    assert got.shape == x.shape
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# im2row baseline == direct conv (any stride)
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(
    hw=st.integers(6, 18), c=st.integers(1, 8), mo=st.integers(1, 8),
    k=st.integers(1, 5), stride=st.integers(1, 3),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_equals_direct(hw, c, mo, k, stride, padding, seed):
    if padding == "VALID" and hw < k:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, hw, hw, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, c, mo)) / k, jnp.float32)
    got = im2col.im2col_conv2d(x, wt, stride=stride, padding=padding)
    want = im2col.direct_conv2d(x, wt, stride=stride, padding=padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(kh=st.integers(1, 8), kw=st.integers(1, 8), stride=st.integers(1, 3))
def test_dispatch_suitability(kh, kw, stride):
    """winograd_suitable is a registry query: stride-1 layers follow the
    paper's rule; stride-2 2D layers with odd supported filters route to
    the phase-decomposition executor; stride 3 has no fast capability."""
    from repro.core.registry import STRIDED_FILTER_SIZES
    s = dispatch.winograd_suitable(kh, kw, stride)
    if kh == 1 and kw == 1:
        assert not s                               # 1x1 is a pure GEMM
    elif stride == 1:
        assert s == all(k == 1 or k in dispatch.WINOGRAD_FILTER_SIZES
                        for k in (kh, kw))
    elif stride == 2:
        assert s == (kh != 1 and kw != 1
                     and {kh, kw} <= STRIDED_FILTER_SIZES)
    else:
        assert not s


@settings(**_SETTINGS)
@given(k=st.sampled_from([3, 5]), stride=st.integers(1, 2),
       seed=st.integers(0, 2**31 - 1))
def test_dispatch_auto_always_matches_direct(k, stride, seed):
    """algorithm="auto" (the paper's mixed policy) is semantics-preserving
    regardless of which scheme it picks."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, 4, 6)) / k, jnp.float32)
    got = dispatch.conv2d(x, wt, stride=stride, algorithm="auto")
    want = im2col.direct_conv2d(x, wt, stride=stride)
    assert rel_err(got, want) < 1e-4


@settings(**_SETTINGS)
@given(stride=st.sampled_from([2, 3]), k=st.sampled_from([3, 5, 7]),
       length=st.integers(10, 40), seed=st.integers(0, 2**31 - 1))
def test_conv1d_polyphase_stride_equals_direct(stride, k, length, seed):
    """Strided sequence conv via polyphase Cook-Toom decomposition (the
    Whisper stem case) == direct strided conv."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, length, 5)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, 5, 7)) / k, jnp.float32)
    got = dispatch.conv1d(x, w, stride=stride, padding="SAME",
                          algorithm="auto")
    want = jax.lax.conv_general_dilated(
        x[:, :, None], w[:, None], window_strides=(stride, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[:, :, 0]
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4
