"""AdamW reference tests: update math vs a hand-rolled oracle, schedule
shape, clipping, dtype policies (bf16 moments for the 100B+ archs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def _oracle_step(p, g, m, v, step, cfg):
    """Textbook AdamW with bias correction + decoupled weight decay."""
    g = np.asarray(g, np.float32)
    # global-norm clip first (matches apply_updates)
    norm = np.sqrt((g ** 2).sum())
    g = g * min(1.0, cfg.grad_clip / (norm + 1e-9))
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g ** 2
    mhat = m / (1 - cfg.b1 ** step)
    vhat = v / (1 - cfg.b2 ** step)
    lr = float(adamw.schedule(jnp.asarray(step - 1), cfg))
    p = p - lr * (mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
    return p, m, v


def test_adamw_matches_oracle_over_steps(rng):
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10)
    p0 = rng.standard_normal(12).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw.init_state(params, cfg)
    p_ref, m_ref, v_ref = p0.copy(), np.zeros(12), np.zeros(12)
    for step in range(1, 6):
        g = rng.standard_normal(12).astype(np.float32)
        params, state = adamw.apply_updates(params, {"w": jnp.asarray(g)},
                                            state, cfg)
        p_ref, m_ref, v_ref = _oracle_step(p_ref, g, m_ref, v_ref, step, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), p_ref,
                                   rtol=1e-5, atol=1e-6)
    assert int(state.step) == 5


def test_schedule_warmup_then_cosine():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(jnp.asarray(s), cfg)) for s in range(110)]
    assert lrs[0] == pytest.approx(1e-4)          # 1/10 into warmup
    assert lrs[9] == pytest.approx(1e-3)          # warmup end
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] == pytest.approx(1e-4, rel=0.1)   # min_lr_frac * lr
    # monotone decay after warmup
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0]), "b": jnp.asarray([0.0])}   # norm 5
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the threshold: untouched
    clipped2, _ = adamw.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g["a"]), rtol=1e-6)


def test_bf16_moment_states():
    cfg = adamw.AdamWConfig(state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init_state(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    new_p, new_s = adamw.apply_updates(
        params, {"w": jnp.full((4,), 0.1, jnp.bfloat16)}, state, cfg)
    assert new_s.m["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(new_p["w"].astype(jnp.float32))))


def test_weight_decay_decoupled():
    """With zero gradients, params shrink by exactly lr * wd * p."""
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                            weight_decay=0.5)
    params = {"w": jnp.asarray([2.0])}
    state = adamw.init_state(params, cfg)
    new_p, _ = adamw.apply_updates(params, {"w": jnp.asarray([0.0])},
                                   state, cfg)
    lr0 = float(adamw.schedule(jnp.asarray(0), cfg))
    assert float(new_p["w"][0]) == pytest.approx(2.0 - lr0 * 0.5 * 2.0,
                                                 rel=1e-5)
