"""MoE routing invariants: dropless exactness, capacity-drop semantics,
batch-composition independence (the serving-correctness property), and the
load-balance aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import moe as moe_lib
from repro.models.config import ArchConfig, MoEConfig


def _cfg(n_experts=8, top_k=2, d_ff=32, act="swiglu"):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=32,
                      act=act,
                      moe=MoEConfig(n_experts=n_experts, top_k=top_k,
                                    d_ff_expert=d_ff))


def _params(cfg, seed=0):
    return moe_lib.init_moe(jax.random.key(seed), cfg, jnp.float32)


def _dense_reference(p, x, cfg):
    """Oracle: run every expert on every token, combine by top-k gates."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    if m.top_k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # all experts on all tokens: (E, T, D)
    xs = jnp.broadcast_to(xf[None], (m.n_experts,) + xf.shape)
    outs = moe_lib._expert_ffn(p, xs, cfg.act)        # (E, T, D)
    y = jnp.zeros_like(xf)
    for k in range(m.top_k):
        y = y + jnp.take_along_axis(
            outs, expert_ids[None, :, k, None], axis=0)[0] \
            * gate_vals[:, k, None]
    return y.reshape(b, s, d)


@pytest.mark.parametrize("top_k,act", [(1, "swiglu"), (2, "swiglu"),
                                       (8, "gelu"), (2, "squared_relu")])
def test_dropless_matches_dense_reference(rng, top_k, act):
    cfg = _cfg(top_k=top_k, act=act)
    p = _params(cfg)
    x = jnp.asarray(rng.standard_normal((2, 12, 16)), jnp.float32)
    y, aux = moe_lib.moe_block(p, x, cfg, dropless=True)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(aux))


def test_dropless_is_batch_composition_independent(rng):
    """A token's output must not depend on its batch neighbours (the property
    capacity dropping violates, and why serving uses dropless)."""
    cfg = _cfg()
    p = _params(cfg)
    x1 = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
    y_joint, _ = moe_lib.moe_block(p, jnp.concatenate([x1, x2]), cfg,
                                   dropless=True)
    y_solo, _ = moe_lib.moe_block(p, x1, cfg, dropless=True)
    np.testing.assert_allclose(np.asarray(y_joint[0]), np.asarray(y_solo[0]),
                               rtol=1e-5, atol=1e-6)


def test_capacity_bound_drops_overflow_tokens(rng):
    """With capacity 4 and all tokens forced onto one expert, the overflow
    tokens must contribute zero (Switch drop semantics)."""
    cfg = _cfg(n_experts=4, top_k=1)
    p = dict(_params(cfg))
    # router that sends everything to expert 0 (inputs positive so the
    # logit x @ router[:, 0] = 10 * sum(x) is always the max)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.asarray(np.abs(rng.standard_normal((1, 64, 16))) + 0.1,
                    jnp.float32)
    y, _ = moe_lib.moe_block(p, x, cfg, dropless=False)
    # capacity = max(int(1.25 * 64 / 4) + 1, 4) = 21 < 64: some rows dropped
    dropped = np.asarray(jnp.all(y[0] == 0, axis=-1))
    assert dropped.sum() == 64 - 21
    # the kept tokens are exactly the earliest 21 (cumsum order)
    assert not dropped[:21].any() and dropped[21:].all()
    # dropless keeps everything
    y2, _ = moe_lib.moe_block(p, x, cfg, dropless=True)
    assert not np.asarray(jnp.all(y2[0] == 0, axis=-1)).any()


def test_aux_loss_minimal_when_balanced():
    """Uniform routing gives aux == 1 (its minimum); skewed routing > 1."""
    cfg = _cfg(n_experts=4, top_k=1)
    p = dict(_params(cfg))
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.abs(rng.standard_normal((1, 256, 16))) + 0.1,
                    jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])       # uniform probs
    _, aux_uniform = moe_lib.moe_block(p, x, cfg, dropless=True)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux_skew = moe_lib.moe_block(p, x, cfg, dropless=True)
    assert abs(float(aux_uniform) - 1.0) < 0.3
    assert float(aux_skew) > 2.0


def test_gate_renormalization_sums_to_one(rng):
    """top-k gates renormalize: scaling invariance of the combine weights."""
    cfg = _cfg(n_experts=8, top_k=8)   # all experts: y == dense mixture
    p = _params(cfg)
    x = jnp.asarray(rng.standard_normal((1, 6, 16)), jnp.float32)
    y, _ = moe_lib.moe_block(p, x, cfg, dropless=True)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
