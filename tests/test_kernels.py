"""Per-kernel allclose tests: every Pallas kernel swept over shapes/dtypes
against the pure-jnp oracle in repro.kernels.ref (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.transforms import cook_toom
from repro.kernels import conv1d_ct as k_conv1d
from repro.kernels import matmul as k_matmul
from repro.kernels import ops, ref

from conftest import rel_err


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 128),
                                   (128, 384, 256), (512, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_vs_oracle(rng, m, k, n, dtype):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = k_matmul.matmul(a, b, interpret=True)
    want = ref.matmul(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert got.dtype == dtype
    assert rel_err(got.astype(jnp.float32), want.astype(jnp.float32)) < tol


@pytest.mark.parametrize("m,k,n", [(37, 53, 11), (1, 130, 257), (200, 64, 5)])
def test_matmul_wrapper_pads_odd_shapes(rng, m, k, n):
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = ops.matmul(a, b)
    want = ref.matmul(a, b)
    assert got.shape == (m, n)
    assert rel_err(got, want) < 1e-5


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 256, 128)])
def test_matmul_block_shape_invariance(rng, bm, bn, bk):
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    got = k_matmul.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    assert rel_err(got, ref.matmul(a, b)) < 1e-5


# ---------------------------------------------------------------------------
# fused winograd kernel (tiles domain)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mt,k", [(2, 3), (4, 3), (2, 5), (4, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_winograd_fused_vs_oracle(rng, mt, k, dtype):
    ct = cook_toom(mt, k)
    r_, c, mo = 128, 128, 128
    tiles = jnp.asarray(rng.standard_normal((r_, ct.t, ct.t, c)), dtype)
    u = jnp.asarray(rng.standard_normal((ct.t * ct.t, c, mo)), dtype)
    got = ops._k_winograd.winograd_fused(tiles, u, ct_h=ct, ct_w=ct,
                                         interpret=True)
    want = ref.winograd_fused(tiles, u, ct_h=ct, ct_w=ct)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert got.shape == (r_, mt, mt, mo)
    assert rel_err(got.astype(jnp.float32), want.astype(jnp.float32)) < tol


def test_winograd_fused_multiblock_accumulation(rng):
    """C > block_c exercises the cross-step fp32 VMEM accumulator."""
    ct = cook_toom(2, 3)
    tiles = jnp.asarray(rng.standard_normal((128, ct.t, ct.t, 256)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((ct.t * ct.t, 256, 128)), jnp.float32)
    got = ops._k_winograd.winograd_fused(tiles, u, ct_h=ct, ct_w=ct,
                                         block_c=128, interpret=True)
    want = ref.winograd_fused(tiles, u, ct_h=ct, ct_w=ct)
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# end-to-end pallas conv wrappers vs lax.conv oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw,c,m,k", [(12, 8, 16, 3), (16, 16, 8, 5),
                                      (9, 3, 7, 3)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_ops_winograd_conv2d_vs_direct(rng, hw, c, m, k, padding):
    x = jnp.asarray(rng.standard_normal((2, hw, hw, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, c, m)) / k, jnp.float32)
    got = ops.winograd_conv2d(x, w, padding=padding, interpret=True)
    want = ref.conv2d_direct(x, w, padding=padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3, 7])
def test_ops_im2col_conv2d_vs_direct(rng, stride, k):
    x = jnp.asarray(rng.standard_normal((2, 14, 14, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, 6, 10)) / k, jnp.float32)
    got = ops.im2col_conv2d(x, w, stride=stride, interpret=True)
    want = ref.conv2d_direct(x, w, stride=stride)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# depthwise causal Cook-Toom conv1d kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length,c,r", [(64, 128, 4), (100, 130, 4),
                                        (33, 64, 3), (256, 128, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_ct_ops_vs_direct(rng, length, c, r, dtype):
    x = jnp.asarray(rng.standard_normal((2, length, c)), dtype)
    w = jnp.asarray(rng.standard_normal((r, c)) / r, dtype)
    got = ops.ct_depthwise_causal_conv1d(x, w, interpret=True)
    want = ref.depthwise_causal_conv1d_direct(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert got.shape == x.shape
    assert rel_err(got.astype(jnp.float32), want.astype(jnp.float32)) < tol


@pytest.mark.parametrize("mt", [2, 4, 6])
def test_conv1d_ct_kernel_tile_domain(rng, mt):
    ct = cook_toom(mt, 4)
    b, s, c = 2, 64, 128
    tiles = jnp.asarray(rng.standard_normal((b, s, ct.t, c)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((ct.t, c)), jnp.float32)
    got = k_conv1d.conv1d_ct_fused(tiles, u, ct=ct, block_s=32, block_c=128,
                                   interpret=True)
    want = ref.conv1d_ct_fused(tiles, u, ct=ct)
    assert got.shape == (b, s, ct.m, c)
    assert rel_err(got, want) < 1e-4


def test_conv1d_ct_matches_pure_jax_path(rng):
    """Pallas wrapper == the pure-JAX core implementation bit-for-contract."""
    from repro.core.winograd import ct_depthwise_causal_conv1d as core_impl
    x = jnp.asarray(rng.standard_normal((3, 77, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 96)) / 2, jnp.float32)
    a = ops.ct_depthwise_causal_conv1d(x, w, interpret=True)
    b = core_impl(x, w)
    assert rel_err(a, b) < 1e-5
