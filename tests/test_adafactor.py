"""Adafactor: factored-state memory claim, descent behaviour, and parity
with AdamW on a quadratic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor


def test_factored_state_is_small():
    params = {"w": jnp.zeros((1024, 4096), jnp.bfloat16)}
    cfg = adafactor.AdafactorConfig()
    bytes_fac = adafactor.state_bytes(params, cfg)
    # AdamW fp32 m+v would be 2 * 4 * 1024 * 4096
    assert bytes_fac < 0.01 * (8 * 1024 * 4096)
    st = adafactor.init_state(params, cfg)
    assert st.vr["w"].shape == (1024,)
    assert st.vc["w"].shape == (4096,)


def test_small_params_not_factored():
    params = {"b": jnp.zeros((64,)), "s": jnp.zeros(())}
    st = adafactor.init_state(params, adafactor.AdafactorConfig())
    assert st.vr["b"].shape == (64,)       # full second moment


def test_descends_quadratic(rng):
    """min ||W - A||^2 converges."""
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    params = {"w": jnp.zeros((256, 256), jnp.float32)}
    cfg = adafactor.AdafactorConfig(lr=0.3)
    state = adafactor.init_state(params, cfg)

    def loss(p):
        return jnp.mean(jnp.square(p["w"] - a))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adafactor.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.05 * l0
    assert int(state.step) == 60


def test_beta1_momentum_variant(rng):
    params = {"w": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)}
    cfg = adafactor.AdafactorConfig(lr=0.1, beta1=0.9)
    state = adafactor.init_state(params, cfg)
    assert state.m["w"].shape == (128, 128)
    g = {"w": jnp.ones((128, 128))}
    new_p, new_s = adafactor.apply_updates(params, g, state, cfg)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))
    assert float(jnp.max(jnp.abs(new_s.m["w"]))) > 0


def test_update_rms_clipped(rng):
    """Huge gradients produce bounded relative updates (clip_threshold)."""
    params = {"w": jnp.ones((256, 256), jnp.float32)}
    cfg = adafactor.AdafactorConfig(lr=1e-2, clip_threshold=1.0)
    state = adafactor.init_state(params, cfg)
    g = {"w": jnp.asarray(rng.standard_normal((256, 256)) * 1e6, jnp.float32)}
    new_p, _ = adafactor.apply_updates(params, g, state, cfg)
    delta_rms = float(jnp.sqrt(jnp.mean(jnp.square(new_p["w"] - 1.0))))
    # scale = lr * rms(p) = 1e-2; clipped update rms <= 1 (+ weight decay 0)
    assert delta_rms <= 1.05e-2
