import os
import sys

import numpy as np
import pytest

# repo root on sys.path so tests can import the benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def rel_err(a, b):
    import jax.numpy as jnp
    denom = float(jnp.max(jnp.abs(b))) + 1e-9
    return float(jnp.max(jnp.abs(a - b))) / denom
