import os
import sys

import numpy as np
import pytest

# repo root on sys.path so tests can import the benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    """Process-level plan/spec cache state must not leak between tests:
    ordering-dependent cache hits can mask spec-keying bugs (a test that
    plans a shape another test already planned would silently reuse the
    other test's decisions)."""
    from repro.core.plan import clear_plan_cache
    clear_plan_cache()
    yield
    clear_plan_cache()


def rel_err(a, b):
    import jax.numpy as jnp
    denom = float(jnp.max(jnp.abs(b))) + 1e-9
    return float(jnp.max(jnp.abs(a - b))) / denom
