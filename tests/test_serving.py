"""Fault-tolerant serving runtime: admission/backpressure, bucketed
batching, deadlines, and the supervisor's degrade ladder (retry ->
registry re-placement -> recompile-in-place), each driven by the
deterministic fault injectors in repro.runtime.inject, plus the per-array
artifact checksum gate in repro.core.compile."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile as C
from repro.core.compile import (ArtifactMismatchError, LayerExecutionError,
                                NetworkPlan, verify_artifact)
from repro.core.plan import plan_cache_info
from repro.models import cnn
from repro.runtime import inject
from repro.runtime.serve import QueueFullError, ServeConfig, Server

RES = 16
SPECS = [cnn.Conv("c1", 3, 3, 8), cnn.Conv("c2", 3, 3, 8, relu=False)]


@pytest.fixture
def params():
    return cnn.init_cnn(jax.random.key(0), SPECS, 3, res=RES)


@pytest.fixture
def xs(rng):
    return [rng.standard_normal((RES, RES, 3)).astype(np.float32)
            for _ in range(6)]


def make_cfg(**kw):
    base = dict(buckets=(1, 2, 4), queue_capacity=8, verbose=False,
                backoff_base_s=0.002, backoff_cap_s=0.01)
    base.update(kw)
    return ServeConfig(**base)


def oracle_outputs(params, xs):
    net = C.compile(params, SPECS, res=RES, batch=1, algorithm="im2col")
    return [np.asarray(net.apply(jnp.asarray(x[None])))[0] for x in xs]


def assert_close(y, ref, tol=2e-3):
    err = np.max(np.abs(y - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < tol, err


# ---------------------------------------------------------------------------
# per-array artifact checksums (satellite: save/load integrity)
# ---------------------------------------------------------------------------

def test_artifact_checksums_roundtrip(params, tmp_path):
    path = str(tmp_path / "net.npz")
    net = C.compile(params, SPECS, res=RES, algorithm="winograd")
    net.save(path)
    assert verify_artifact(path) == []
    loaded = NetworkPlan.load(path)
    x = jnp.zeros((1, RES, RES, 3), jnp.float32)
    assert np.array_equal(np.asarray(net.apply(x)),
                          np.asarray(loaded.apply(x)))


def test_bitflip_fails_integrity_digest(params, tmp_path):
    path = str(tmp_path / "net.npz")
    C.compile(params, SPECS, res=RES, algorithm="winograd").save(path)
    bad = inject.flip_bit(path)
    assert [bad] == verify_artifact(path)
    with pytest.raises(ArtifactMismatchError,
                       match="integrity digest.*recompile"):
        NetworkPlan.load(path)


def test_corrupt_artifact_recompiles_and_repairs(params, tmp_path):
    """The satellite's corrupt-artifact -> recompile-and-repair contract:
    compile(artifact=) over a bit-flipped file must cold-compile (one
    artifact miss), produce correct outputs, and leave a repaired artifact
    behind."""
    path = str(tmp_path / "net.npz")
    ref = C.compile(params, SPECS, res=RES, algorithm="winograd",
                    artifact=path)
    x = jnp.zeros((1, RES, RES, 3), jnp.float32)
    y_ref = np.asarray(ref.apply(x))
    inject.flip_bit(path)
    before = plan_cache_info()
    net = C.compile(params, SPECS, res=RES, algorithm="winograd",
                    artifact=path)
    after = plan_cache_info()
    assert after["artifact_misses"] == before["artifact_misses"] + 1
    assert np.array_equal(np.asarray(net.apply(x)), y_ref)
    assert verify_artifact(path) == []          # repaired on disk
    NetworkPlan.load(path)                       # and loadable again


# ---------------------------------------------------------------------------
# re-placement hook (core side of the degrade ladder)
# ---------------------------------------------------------------------------

def test_replace_layer_parity(params, xs):
    net = C.compile(params, SPECS, res=RES, batch=1, algorithm="winograd")
    x = jnp.asarray(xs[0][None])
    y_before = np.asarray(net.apply(x))
    assert net.plans["c1"].spec.algorithm != "im2col"
    net.replace_layer("c1", params, algorithm="im2col")
    assert net.plans["c1"].spec.algorithm == "im2col"
    assert_close(np.asarray(net.apply(x)), y_before)


def test_replace_layer_rejects_unknown_node_and_foreign_params(
        params, tmp_path):
    path = str(tmp_path / "net.npz")
    net = C.compile(params, SPECS, res=RES, algorithm="winograd",
                    artifact=path)
    with pytest.raises(ValueError, match="not a plan-bearing node"):
        net.replace_layer("nope", params)
    other = cnn.init_cnn(jax.random.key(1), SPECS, 3, res=RES)
    with pytest.raises(ValueError, match="params_digest mismatch"):
        net.replace_layer("c1", other)


def test_apply_annotates_layer_errors(params):
    net = C.compile(params, SPECS, res=RES, algorithm="winograd")
    inject.install(net, inject.ExecutorRaise("c2"))
    x = jnp.zeros((1, RES, RES, 3), jnp.float32)
    with pytest.raises(inject.InjectedExecutorError):
        net.apply(x)                             # default: raw error
    with pytest.raises(LayerExecutionError) as ei:
        net.apply(x, annotate_errors=True)
    assert ei.value.node_id == "c2"
    assert isinstance(ei.value.__cause__, inject.InjectedExecutorError)


# ---------------------------------------------------------------------------
# serving: the degrade ladder under injected faults
# ---------------------------------------------------------------------------

def test_executor_raise_replacement_parity(params, xs):
    """Permanent executor failure: retries burn out, the supervisor
    re-places the failing layer onto im2row across every bucket, and every
    in-flight request is answered with outputs matching the im2row
    oracle -- zero drops, zero incorrect responses."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    srv.start()
    inject.install_on_server(srv, inject.ExecutorRaise("c1"))
    tickets = [srv.submit(x) for x in xs]
    ys = [t.result(timeout=120) for t in tickets]
    srv.stop()
    s = srv.stats
    assert s.replacements >= 1 and s.executor_failures >= 1
    assert s.failed == 0 and s.in_flight == 0
    for b in srv.buckets:
        assert srv.nets[b].plans["c1"].spec.algorithm == "im2col"
    for y, ref in zip(ys, oracle_outputs(params, xs)):
        assert_close(y, ref)


def test_transient_executor_raise_survived_by_retry(params, xs):
    """A fault that clears within the retry budget never escalates."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    srv.start()
    inject.install_on_server(srv, inject.ExecutorRaise("c1", times=1))
    ys = [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
    srv.stop()
    assert srv.stats.retries >= 1 and srv.stats.replacements == 0
    assert srv.stats.failed == 0 and srv.stats.in_flight == 0
    for y, ref in zip(ys, oracle_outputs(params, xs)):
        assert_close(y, ref)


def test_recompile_rung_when_replacement_cannot_cure(params, xs,
                                                     monkeypatch):
    """When re-placement is unavailable the ladder's last rung recompiles
    every bucket plan from raw params -- which drops the fault proxies --
    and the batch still completes."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    srv.start()
    monkeypatch.setattr(srv, "_replace_layer",
                        lambda *a, **k: False)
    inject.install_on_server(srv, inject.ExecutorRaise("c1"))
    ys = [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
    srv.stop()
    assert srv.stats.recompiles == 1
    assert srv.stats.failed == 0 and srv.stats.in_flight == 0
    for y, ref in zip(ys, oracle_outputs(params, xs)):
        assert_close(y, ref)


def test_queue_overload_bounded_rejection(params, xs):
    """Satellite: overload degrades into bounded rejection with a
    retry-after hint; every ADMITTED request is still served (zero
    drops)."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg(queue_capacity=4))
    accepted, rejected = [], 0
    for i in range(11):
        try:
            accepted.append(srv.submit(xs[i % len(xs)]))
        except QueueFullError as e:
            rejected += 1
            assert e.retry_after_s > 0 and e.capacity == 4
    assert len(accepted) == 4 and rejected == 7
    assert srv.stats.rejected == 7
    srv.start()
    ys = [t.result(timeout=120) for t in accepted]
    srv.stop()
    assert srv.stats.completed == 4 and srv.stats.in_flight == 0
    refs = oracle_outputs(params, [t.x for t in accepted])
    for y, ref in zip(ys, refs):
        assert_close(y, ref)


def test_straggler_eviction_counter(params, xs):
    """Satellite: an injected latency spike on one layer is flagged by the
    per-bucket StepTimer, attributed via per-layer times, and the layer is
    evicted onto the fallback executor after the configured count.
    Straggler attribution needs the eager supervised path's per-layer
    timing hooks, so the jitted dispatch fast path is disabled."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg(buckets=(2,), queue_capacity=64,
                                 jit_dispatch=False,
                                 straggler_window=16,
                                 straggler_min_baseline=5,
                                 straggler_evict_after=2, batch_wait_s=0.0))
    srv.start()
    for _ in range(8):                           # build the baseline
        [t.result(timeout=60) for t in [srv.submit(x) for x in xs[:2]]]
    inject.install_on_server(srv, inject.LatencySpike("c2", delay_s=0.3))
    for _ in range(6):
        [t.result(timeout=60) for t in [srv.submit(x) for x in xs[:2]]]
    srv.stop()
    s = srv.stats
    assert s.stragglers >= 2 and s.evictions >= 1
    assert srv.nets[2].plans["c2"].spec.algorithm == "im2col"
    assert s.failed == 0 and s.in_flight == 0


def test_deadline_timeout_cancellation(params, xs):
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    expired = srv.submit(xs[0], deadline_s=0.0)   # dead before dispatch
    live = srv.submit(xs[1], deadline_s=60.0)
    srv.start()
    with pytest.raises(TimeoutError, match="deadline expired"):
        expired.result(timeout=60)
    assert_close(live.result(timeout=60), oracle_outputs(params, [xs[1]])[0])
    srv.stop()
    assert expired.status == "timeout" and srv.stats.timed_out == 1
    assert srv.stats.completed == 1 and srv.stats.in_flight == 0


def test_corrupt_bucket_artifact_repaired_at_startup(params, xs, tmp_path):
    """A bit-flipped bucket artifact is detected by the per-array checksums
    at server startup, recompiled in place, and serving proceeds with
    correct outputs; the repaired artifact warm-starts the next server."""
    art = str(tmp_path)
    cfg = make_cfg()
    srv = Server(params, SPECS, res=RES, algorithm="winograd", config=cfg,
                 artifact_dir=art)
    assert srv.stats.artifact_cold_starts == len(srv.buckets)
    del srv
    inject.flip_bit(os.path.join(art, "plan_b2.npz"))
    srv2 = Server(params, SPECS, res=RES, algorithm="winograd", config=cfg,
                  artifact_dir=art)
    assert srv2.stats.corrupt_artifacts == 1
    assert srv2.stats.corrupt_arrays >= 1
    assert srv2.stats.artifact_cold_starts == 1     # only the corrupt bucket
    assert srv2.stats.artifact_warm_starts == len(srv2.buckets) - 1
    assert verify_artifact(os.path.join(art, "plan_b2.npz")) == []
    srv2.start()
    ys = [t.result(timeout=120) for t in [srv2.submit(x) for x in xs]]
    srv2.stop()
    for y, ref in zip(ys, oracle_outputs(params, xs)):
        assert_close(y, ref)
    srv3 = Server(params, SPECS, res=RES, algorithm="winograd", config=cfg,
                  artifact_dir=art)
    assert srv3.stats.artifact_warm_starts == len(srv3.buckets)


def test_jit_dispatch_happy_path_counters(params, xs):
    """Satellite: fault-free traffic is served entirely by the jitted
    happy path (stats.jit_dispatches), no bucket ever falls back, and
    outputs match the eager oracle."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    srv.start()
    ys = [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
    srv.stop()
    assert srv.stats.jit_dispatches >= 1
    assert srv.stats.jit_fallbacks == 0 and srv.stats.retries == 0
    for y, ref in zip(ys, oracle_outputs(params, xs)):
        assert_close(y, ref)


def test_probation_promotes_layer_back(params, xs):
    """Satellite: continuous re-placement. A permanent executor fault
    breaks the bucket's jitted path (counted in jit_fallbacks), the
    supervisor evicts the layer onto im2col, and after the probation
    window of clean batches a re-probe promotes it back onto winograd."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg(probation_batches=2))
    srv.start()
    inject.install_on_server(srv, inject.ExecutorRaise("c1"))
    [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
    assert srv.stats.replacements >= 1 and srv.stats.jit_fallbacks >= 1
    # serve clean singles until the probation window fills
    ys = []
    for _ in range(4):
        ys.append(srv.submit(xs[0]).result(timeout=120))
    srv.stop()
    s = srv.stats
    assert s.probation_reprobes >= 1 and s.probation_promotions == 1
    for b in srv.buckets:
        assert srv.nets[b].plans["c1"].spec.algorithm == "winograd"
    ref = oracle_outputs(params, [xs[0]])[0]
    for y in ys:
        assert_close(y, ref)
    assert s.failed == 0 and s.in_flight == 0


def test_probation_window_doubles_on_failed_probe(params, xs, monkeypatch):
    """A failed probation re-probe keeps the layer on the fallback and
    doubles its window instead of flapping."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg(probation_batches=1))
    srv.start()
    inject.install_on_server(srv, inject.ExecutorRaise("c1"))
    [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
    assert srv.stats.replacements >= 1

    def boom(*a, **k):
        raise RuntimeError("probe refused")
    monkeypatch.setattr(srv, "_fresh_plan", boom)
    for _ in range(2):
        srv.submit(xs[0]).result(timeout=120)
    srv.stop()
    s = srv.stats
    assert s.probation_reprobes >= 1 and s.probation_promotions == 0
    assert srv._probation["c1"]["need"] >= 2
    for b in srv.buckets:
        assert srv.nets[b].plans["c1"].spec.algorithm == "im2col"


def test_batches_form_across_buckets(params, xs):
    """Dynamic batch formation picks the smallest covering bucket; a
    pre-loaded queue of 6 forms a 4-batch plus a 2-batch."""
    srv = Server(params, SPECS, res=RES, algorithm="winograd",
                 config=make_cfg())
    tickets = [srv.submit(x) for x in xs]
    srv.start()
    [t.result(timeout=120) for t in tickets]
    srv.stop()
    assert srv.stats.bucket_batches == {4: 1, 2: 1}
    assert srv.stats.completed == 6 and srv.stats.in_flight == 0
