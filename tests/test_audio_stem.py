"""Whisper conv stem: the paper's 1D algorithm (stride-1 Cook-Toom +
polyphase stride-2) vs a direct-convolution oracle, end to end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import audio

from conftest import rel_err


def _direct_stem(params, mel):
    def conv(x, w, stride):
        return jax.lax.conv_general_dilated(
            x[:, :, None], w[:, None], window_strides=(stride, 1),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))[:, :, 0]

    x = jax.nn.gelu(conv(mel, params["conv1_w"], 1) + params["conv1_b"])
    return jax.nn.gelu(conv(x, params["conv2_w"], 2) + params["conv2_b"])


@pytest.mark.parametrize("algorithm", ["auto", "im2col"])
def test_stem_matches_direct(rng, algorithm):
    cfg = cfglib.get_smoke_config("whisper_tiny")
    params = audio.init_stem(jax.random.key(0), cfg, n_mels=16)
    mel = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    got = audio.stem(params, mel, algorithm=algorithm)
    want = _direct_stem(params, mel)
    assert got.shape == (2, 16, cfg.d_model)
    assert rel_err(got, want) < 1e-4


def test_stem_planned_matches_direct(rng):
    """plan_stem builds both conv plans once (incl. polyphase stride-2);
    stem(plans=...) matches the direct oracle with no per-call transform."""
    cfg = cfglib.get_smoke_config("whisper_tiny")
    params = audio.init_stem(jax.random.key(0), cfg, n_mels=16)
    mel = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    plans = audio.plan_stem(params, mel.shape)
    got = audio.stem(params, mel, plans=plans)
    want = _direct_stem(params, mel)
    assert got.shape == (2, 16, cfg.d_model)
    assert rel_err(got, want) < 1e-4


def test_stem_halves_time_axis(rng):
    cfg = cfglib.get_smoke_config("whisper_tiny")
    params = audio.init_stem(jax.random.key(1), cfg, n_mels=8)
    for t in (20, 33):
        mel = jnp.asarray(rng.standard_normal((1, t, 8)), jnp.float32)
        out = audio.stem(params, mel)
        assert out.shape[1] == -(-t // 2)
