"""Stride-2 Winograd via transform-domain phase decomposition: parity of
every strided executor (pure-JAX dense/grouped/depthwise, strided streaming
Pallas kernels) against lax.conv_general_dilated across paddings and
asymmetric shapes, a hypothesis sweep, the MobileNet-v2 inverted-residual
plans (incl. the one-kernel jaxpr regression), and NCHW ingest round trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.im2col import direct_conv2d
from repro.core.plan import (plan_conv2d, plan_inverted_residual,
                             plan_separable_block)

from conftest import rel_err


def _conv_inputs(rng, n, h, w, c_in, kh, kw, c_out, groups=1):
    x = jnp.asarray(rng.standard_normal((n, h, w, c_in)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((kh, kw, c_in // groups, c_out))
                     / (kh * kw), jnp.float32)
    return x, wt


# ---------------------------------------------------------------------------
# parity vs the direct oracle, every strided executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("kh,kw", [(3, 3), (5, 5), (3, 5), (7, 7)])
@pytest.mark.parametrize("h,w", [(12, 12), (13, 17)])
def test_strided_dense_matches_direct(rng, padding, kh, kw, h, w):
    x, wt = _conv_inputs(rng, 2, h, w, 8, kh, kw, 6)
    p = plan_conv2d(x.shape, wt, stride=2, padding=padding,
                    algorithm="winograd")
    assert p.algorithm == "winograd_strided"
    got = p.apply(x)
    want = direct_conv2d(x, wt, stride=2, padding=padding)
    assert got.shape == want.shape == p.out_shape
    assert rel_err(got, want) < 2e-3


@pytest.mark.parametrize("groups,c_in,c_out", [(8, 8, 8), (8, 8, 16),
                                               (4, 8, 8)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_strided_grouped_depthwise_matches_direct(rng, groups, c_in, c_out,
                                                  padding):
    x, wt = _conv_inputs(rng, 1, 14, 11, c_in, 3, 3, c_out, groups)
    p = plan_conv2d(x.shape, wt, stride=2, padding=padding, groups=groups,
                    algorithm="winograd")
    assert p.algorithm == "winograd_strided"
    want = direct_conv2d(x, wt, stride=2, padding=padding, groups=groups)
    assert rel_err(p.apply(x), want) < 2e-3


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("h,w", [(14, 14), (13, 18)])
def test_strided_pallas_dense_matches_direct(rng, padding, h, w):
    x, wt = _conv_inputs(rng, 1, h, w, 8, 3, 3, 9)
    p = plan_conv2d(x.shape, wt, stride=2, padding=padding,
                    algorithm="pallas_winograd")
    assert p.algorithm == "pallas_winograd_strided"
    b = jnp.asarray(rng.standard_normal((9,)), jnp.float32)
    got = p.apply(x, bias=b, activation="relu")
    want = jax.nn.relu(direct_conv2d(x, wt, stride=2, padding=padding) + b)
    assert got.shape == want.shape
    assert rel_err(got, want) < 2e-3


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_strided_pallas_depthwise_matches_direct(rng, padding):
    c = 9
    x, wt = _conv_inputs(rng, 2, 13, 16, c, 3, 3, c, groups=c)
    p = plan_conv2d(x.shape, wt, stride=2, padding=padding, groups=c,
                    algorithm="pallas_winograd")
    assert p.algorithm == "pallas_depthwise_strided"
    b = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    got = p.apply(x, bias=b, activation="relu6")
    want = jnp.minimum(jax.nn.relu(
        direct_conv2d(x, wt, stride=2, padding=padding, groups=c) + b), 6.0)
    assert rel_err(got, want) < 2e-3


def test_strided_plans_under_jit(rng):
    x, wt = _conv_inputs(rng, 1, 16, 16, 8, 3, 3, 8)
    for alg in ("winograd", "pallas_winograd"):
        p = plan_conv2d(x.shape, wt, stride=2, algorithm=alg)
        got = jax.jit(p.apply)(x)
        assert rel_err(got, direct_conv2d(x, wt, stride=2)) < 2e-3


def test_strided_filter_transform_is_plan_time(rng, monkeypatch):
    """The phase filter transform runs once at plan time; apply() reuses the
    cached transform-domain phase filters."""
    from repro.core import winograd as wg
    calls = {"n": 0}
    real = wg.strided_phase_filters

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(wg, "strided_phase_filters", counting)
    x, wt = _conv_inputs(rng, 1, 12, 12, 4, 3, 3, 4)
    p = plan_conv2d(x.shape, wt, stride=2, algorithm="winograd")
    assert calls["n"] == 1
    for _ in range(3):
        p.apply(x)
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# hypothesis sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(h=st.integers(8, 24), w=st.integers(8, 24),
           k=st.sampled_from([3, 5]), padding=st.sampled_from(["SAME",
                                                               "VALID"]),
           groups_mode=st.sampled_from(["dense", "depthwise", "grouped"]),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_strided_sweep_matches_direct(h, w, k, padding, groups_mode,
                                          seed):
        if min(h, w) < k:
            return
        rng = np.random.default_rng(seed)
        c = 8
        groups = {"dense": 1, "depthwise": c, "grouped": 4}[groups_mode]
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(
            rng.standard_normal((k, k, c // groups, 8)) / k ** 2,
            jnp.float32)
        p = plan_conv2d(x.shape, wt, stride=2, padding=padding,
                        groups=groups, algorithm="winograd")
        want = direct_conv2d(x, wt, stride=2, padding=padding, groups=groups)
        got = p.apply(x)
        assert got.shape == want.shape
        assert rel_err(got, want) < 2e-3


# ---------------------------------------------------------------------------
# MobileNet-v2 inverted residual plans
# ---------------------------------------------------------------------------

def _mbv2_oracle(x, p, stride, expand):
    r6 = lambda v: jnp.minimum(jax.nn.relu(v), 6.0)
    h = x
    if expand != 1:
        h = r6(direct_conv2d(h, p["exp"]["w"]) + p["exp"]["b"])
    h = r6(direct_conv2d(h, p["dw"]["w"], stride=stride,
                         groups=h.shape[-1]) + p["dw"]["b"])
    y = direct_conv2d(h, p["pw"]["w"]) + p["pw"]["b"]
    if stride == 1 and x.shape[-1] == y.shape[-1]:
        y = x + y
    return y


def _mbv2_params(rng, c, expand, c_out, k=3):
    ce = c * expand
    p = {"dw": {"w": jnp.asarray(rng.standard_normal((k, k, 1, ce)) / k ** 2,
                                 jnp.float32),
                "b": jnp.asarray(rng.standard_normal((ce,)), jnp.float32)},
         "pw": {"w": jnp.asarray(rng.standard_normal((1, 1, ce, c_out))
                                 / np.sqrt(ce), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)}}
    if expand != 1:
        p["exp"] = {"w": jnp.asarray(rng.standard_normal((1, 1, c, ce))
                                     / np.sqrt(c), jnp.float32),
                    "b": jnp.asarray(rng.standard_normal((ce,)), jnp.float32)}
    return p


@pytest.mark.parametrize("stride,expand,c_out", [(1, 6, 8), (2, 6, 12),
                                                 (1, 1, 8)])
@pytest.mark.parametrize("algorithm", ["auto", "pallas_winograd"])
def test_inverted_residual_matches_oracle(rng, stride, expand, c_out,
                                          algorithm):
    c = 8
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    p = _mbv2_params(rng, c, expand, c_out)
    plan = plan_inverted_residual(
        x.shape, p["exp"]["w"] if expand != 1 else None, p["dw"]["w"],
        p["pw"]["w"], stride=stride, algorithm=algorithm)
    assert plan.residual == (stride == 1 and c == c_out)
    got = plan.apply(x, bias_exp=p.get("exp", {}).get("b"),
                     bias_dw=p["dw"]["b"], bias_pw=p["pw"]["b"])
    want = _mbv2_oracle(x, p, stride, expand)
    assert got.shape == want.shape
    assert rel_err(got, want) < 2e-3


def test_inverted_residual_fused_one_kernel(rng):
    """jaxpr regression: the planned MBv2 block's depthwise+project pair
    compiles to ONE pallas_call (the fused separable streamed kernel); the
    1x1 expand is a plain XLA GEMM, so exactly one kernel appears in the
    whole block."""
    c = 8
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    p = _mbv2_params(rng, c, 6, c)
    plan = plan_inverted_residual(x.shape, p["exp"]["w"], p["dw"]["w"],
                                  p["pw"]["w"], stride=1,
                                  algorithm="pallas_winograd")
    assert plan.mode == "fused_pallas"
    jaxpr = jax.make_jaxpr(
        lambda xx: plan.apply(xx, bias_exp=p["exp"]["b"],
                              bias_dw=p["dw"]["b"],
                              bias_pw=p["pw"]["b"]))(x).jaxpr

    def count(jaxpr, name):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                n += 1
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", None)
                if inner is not None:
                    n += count(getattr(inner, "jaxpr", inner), name)
        return n

    n_kernels = count(jaxpr, "pallas_call")
    assert n_kernels == 1, f"expected one fused kernel, got {n_kernels}"


def test_mobilenet_v2_zoo_planned_forward(rng):
    """The mobilenet_v2 zoo entry plans (inverted residuals as single units)
    and the planned forward matches the im2row baseline."""
    from repro.models import cnn
    specs = cnn.NETWORKS["mobilenet_v2"][0]()
    res = 32
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    plans = cnn.plan_cnn(params, specs, res=res)
    from repro.core.plan import InvertedResidualPlan
    ir_plans = [p for p in plans.values()
                if isinstance(p, InvertedResidualPlan)]
    assert len(ir_plans) == 17
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    planned = cnn.cnn_forward(params, x, specs, plans=plans)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(planned, base) < 1e-3


def test_mobilenet_reduction_block_routes_winograd(rng):
    """The MobileNet-v1 stride-2 reduction blocks (the gap this PR closes)
    now route their depthwise half through winograd-family executors on
    both backends instead of falling back to im2row."""
    c = 8
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 9, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, 2 * c)) / 3, jnp.float32)
    p = plan_separable_block(x.shape, w_dw, w_pw, stride=2, algorithm="auto")
    assert p.mode == "composed" and p.dw.algorithm == "winograd_strided"
    p = plan_separable_block(x.shape, w_dw, w_pw, stride=2,
                             algorithm="pallas_winograd")
    assert p.mode == "composed"
    assert p.dw.algorithm == "pallas_depthwise_strided"
    got = p.apply(x, bias_dw=jnp.zeros((c,)), bias_pw=jnp.zeros((2 * c,)))
    h = jax.nn.relu(direct_conv2d(x, w_dw, stride=2, groups=c))
    want = jax.nn.relu(direct_conv2d(h, w_pw))
    assert rel_err(got, want) < 2e-3


# ---------------------------------------------------------------------------
# NCHW ingest
# ---------------------------------------------------------------------------

def _direct_nchw(x, w, stride, padding="SAME", groups=1):
    stride = (stride, stride) if isinstance(stride, int) else stride
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("algorithm", ["auto", "winograd", "im2col",
                                       "pallas_winograd"])
def test_nchw_round_trip_parity(rng, stride, algorithm):
    """NCHW inputs + OIHW weights in, NCHW out -- parity with lax's native
    NCHW dimension numbers on both stride-1 and stride-2 layers."""
    x = jnp.asarray(rng.standard_normal((2, 6, 13, 12)), jnp.float32)  # NCHW
    w = jnp.asarray(rng.standard_normal((8, 6, 3, 3)) / 9, jnp.float32)  # OIHW
    p = plan_conv2d(x.shape, w, stride=stride, algorithm=algorithm,
                    data_format="NCHW")
    got = p.apply(x)
    want = _direct_nchw(x, w, stride)
    assert got.shape == want.shape == p.out_shape
    assert rel_err(got, want) < 2e-3


def test_nchw_weight_transpose_is_plan_time_and_cache_keyed(rng):
    """The OIHW->HWIO normalization happens at plan time, and NCHW/NHWC
    plans of the same shape occupy distinct spec-cache entries."""
    from repro.core.plan import plan_cache_info
    w_oihw = jnp.asarray(rng.standard_normal((4, 4, 3, 3)) / 9, jnp.float32)
    w_hwio = jnp.transpose(w_oihw, (2, 3, 1, 0))
    p_nchw = plan_conv2d((1, 4, 12, 12), w_oihw, data_format="NCHW")
    p_nhwc = plan_conv2d((1, 12, 12, 4), w_hwio)
    assert plan_cache_info()["misses"] == 2      # distinct cache entries
    assert p_nchw.spec.layout == "NCHW" and p_nhwc.spec.layout == "NHWC"
    # same executor decision and identical bound weights
    assert p_nchw.algorithm == p_nhwc.algorithm
    assert rel_err(p_nchw.u, p_nhwc.u) < 1e-6
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 4, 12, 12)),
                    jnp.float32)
    assert rel_err(p_nchw.apply(x), _direct_nchw(x, w_oihw, 1)) < 1e-3
    with pytest.raises(ValueError, match="NCHW"):
        p_nchw.apply(jnp.zeros((1, 12, 12, 4), jnp.float32))


def test_nchw_depthwise_and_bias(rng):
    c = 8
    x = jnp.asarray(rng.standard_normal((1, c, 14, 14)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((c, 1, 3, 3)) / 9, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    p = plan_conv2d(x.shape, w, stride=2, groups=c, data_format="NCHW")
    got = p.apply(x, bias=b, activation="relu")
    want = jax.nn.relu(_direct_nchw(x, w, 2, groups=c)
                       + b[None, :, None, None])
    assert rel_err(got, want) < 2e-3
