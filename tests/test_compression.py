"""Error-feedback int8 gradient compression: quantization contracts, the
error-feedback zero-bias property over repeated steps, and the int8 cross-pod
mean inside a real shard_map."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.optim import compression as comp


def test_quantize_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.standard_normal(256) * 3.0, jnp.float32)
    c = comp.quantize(g)
    assert c.q.dtype == jnp.int8
    err = np.abs(np.asarray(comp.dequantize(c) - g))
    # max error is half a quantization step
    step = float(c.scale)
    assert err.max() <= 0.5 * step + 1e-7


def test_quantize_zero_tensor():
    c = comp.quantize(jnp.zeros(8))
    assert float(jnp.max(jnp.abs(comp.dequantize(c)))) == 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 100.0))
def test_error_feedback_accumulated_bias_vanishes(seed, scale):
    """sum_t dequant(q_t) == sum_t g_t - err_T: the residual never exceeds
    one quantization step, so the trajectory bias is bounded, not growing."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros(32)
    total_sent = np.zeros(32)
    total_true = np.zeros(32)
    last_scale = 0.0
    for _ in range(20):
        g = jnp.asarray(rng.standard_normal(32) * scale, jnp.float32)
        c, err = comp.compress_with_feedback(g, err)
        total_sent += np.asarray(comp.dequantize(c))
        total_true += np.asarray(g)
        last_scale = max(last_scale, float(c.scale))
    residual = np.abs(total_true - total_sent)
    np.testing.assert_allclose(residual, np.abs(np.asarray(err)), rtol=1e-4,
                               atol=2e-4 * max(scale, 1.0))
    assert residual.max() <= 0.5 * last_scale + 1e-6


def test_pod_mean_int8_in_shard_map():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under dryrun XLA_FLAGS)")
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("pod",))
    rng = np.random.default_rng(0)
    per_pod = jnp.asarray(rng.standard_normal((n, 64)), jnp.float32)
    errs = jnp.zeros((n, 64))

    def body(g, e):
        return comp.pod_mean_int8(g[0], e[0], "pod")

    from repro.distributed.sharding import shard_map
    fn = jax.jit(shard_map(body, mesh=mesh,
                           in_specs=(P("pod"), P("pod")),
                           out_specs=(P(), P("pod")),
                           check_replication=False))
    mean, new_err = fn(per_pod, errs)
    want = np.asarray(per_pod).mean(axis=0)
    got = np.asarray(mean)
    # int8 with per-tensor scale: ~1% relative accuracy on the mean
    assert np.max(np.abs(got - want)) < 0.02 * np.max(np.abs(want)) + 1e-3


def test_init_error_state_matches_tree():
    params = {"a": jnp.ones((3, 2), jnp.bfloat16), "b": jnp.ones(5)}
    errs = comp.init_error_state(params)
    assert errs["a"].shape == (3, 2) and errs["a"].dtype == jnp.float32
