"""HLO cost-parser tests: the roofline numbers are only as good as this
parser, so pin its semantics on hand-written HLO and on real compiled
programs (1-device) where XLA's own cost_analysis is the cross-check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo

HLO_SAMPLE = """
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128,256]) %p), index=0
  %x = f32[128,256] get-tuple-element((s32[], f32[128,256]) %p), index=1
  %w = f32[256,256] constant({...})
  %y = f32[128,256] dot(f32[128,256] %x, f32[256,256] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[128,256] all-gather(f32[128,256] %y), replica_groups={}, dimensions={0}
  %one = s32[] constant(1)
  %ni = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[128,256]) tuple(s32[] %ni, f32[128,256] %ag)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128,256]) %p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

ENTRY %main () -> f32[] {
  %a = f32[128,256] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(s32[] %zero, f32[128,256] %a)
  %loop = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%cond, body=%body
  %out = f32[128,256] get-tuple-element((s32[], f32[128,256]) %loop), index=1
  %ar = f32[128,256] all-reduce(f32[128,256] %out), replica_groups={}, to_apply=%add
  ROOT %r = f32[] reduce(f32[128,256] %ar, f32[] %zero), dimensions={0,1}, to_apply=%add
}
"""


def test_parse_hlo_structure():
    comps = hlo.parse_hlo(HLO_SAMPLE)
    assert set(comps) >= {"body", "cond", "main"}
    assert any(i.opcode == "while" for i in comps["main"].instrs)
    assert any(i.opcode == "dot" for i in comps["body"].instrs)


def test_trip_count_from_condition_constant():
    comps = hlo.parse_hlo(HLO_SAMPLE)
    assert hlo._trip_count(comps["cond"]) == 12


def test_flops_are_trip_aware():
    cost = hlo.HloCost(HLO_SAMPLE).total("main")
    # dot: 2 * (128*256) * 256 per trip, 12 trips
    want = 2.0 * 128 * 256 * 256 * 12
    assert cost.flops == want


def test_collective_bytes_by_kind():
    cost = hlo.HloCost(HLO_SAMPLE).total("main")
    buf = 128 * 256 * 4
    assert cost.coll_by_kind["all-gather"] == buf * 12   # inside the loop
    assert cost.coll_by_kind["all-reduce"] == buf        # outside
    assert cost.coll_bytes == buf * 13


def test_shape_bytes_parses_dtypes():
    assert hlo._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert hlo._shape_bytes("bf16[10]") == 20
    assert hlo._shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert hlo._shape_bytes("pred[]") == 1


def test_real_compiled_dot_flops_close_to_xla():
    """On a real compiled program (no loops), our dot flops == XLA's."""
    m, k, n = 256, 512, 128

    @jax.jit
    def f(a, b):
        return a @ b

    lowered = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                      jax.ShapeDtypeStruct((k, n), jnp.float32))
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    got = hlo.HloCost(compiled.as_text()).total()
    assert got.flops == pytest.approx(float(cost["flops"]), rel=0.01)
    assert got.flops == pytest.approx(2.0 * m * k * n, rel=0.01)


def test_real_scan_is_trip_aware_but_xla_is_not():
    """The reason this module exists: XLA counts a scanned body once."""
    trips = 8

    @jax.jit
    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=trips)
        return x

    compiled = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                       jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    ours = hlo.HloCost(compiled.as_text()).total().flops
    per_body = 2.0 * 64 * 64 * 64
    assert ours == pytest.approx(trips * per_body, rel=0.05)
    # XLA's own count misses the trip multiplier
    assert float(cost["flops"]) <= per_body * 2


def test_roofline_bottleneck_selection():
    rf = hlo.Roofline(flops=197e12, hbm_bytes=1.0, coll_bytes=1.0, n_chips=1)
    assert rf.bottleneck == "compute" and rf.t_compute == pytest.approx(1.0)
    rf = hlo.Roofline(flops=1.0, hbm_bytes=819e9 * 2, coll_bytes=1.0, n_chips=1)
    assert rf.bottleneck == "memory" and rf.t_memory == pytest.approx(2.0)
    rf = hlo.Roofline(flops=1.0, hbm_bytes=1.0, coll_bytes=50e9 * 3, n_chips=1)
    assert rf.bottleneck == "collective" and rf.t_collective == pytest.approx(3.0)
