"""Graph-level compile() API (repro.core.compile): lowering, fusion
pattern rewrites, placement, NetworkPlan execution parity, the serialized
deployment artifact (save/load round-trip, mismatch refusals, the
zero-filter-transform warm path), describe() table generation, and the
deprecation shims over the legacy plan_* entry points."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile as compiler
from repro.core import plan as planlib
from repro.core import registry
from repro.core.compile import (ArtifactMismatchError, LayerIR, NetworkPlan,
                                fuse, infer_shapes, lower, place)
from repro.core.compile import compile as compile_network
from repro.core.im2col import direct_conv2d
from repro.core.plan import (InvertedResidualPlan, SeparableBlockPlan,
                             plan_cache_info)
from repro.models import audio, cnn

from conftest import rel_err

_RES = {"vgg16": 64, "vgg19": 64, "googlenet": 64, "inception_v3": 96,
        "squeezenet": 64, "mobilenet_v1": 64, "mobilenet_v1_050": 64,
        "mobilenet_v2": 64}


def _net(name, res=None, key=0):
    specs = cnn.NETWORKS[name][0]()
    res = res or _RES[name]
    params = cnn.init_cnn(jax.random.key(key), specs, 3, res=res)
    return specs, params, res


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def test_lower_produces_unfused_conv_chains():
    """Composite specs lower to their unfused conv chains: fusion is a
    graph rewrite, not a property of the input format."""
    specs, _, _ = _net("mobilenet_v2")
    ir = lower(specs, c_in=3)
    ops = [n.op for n in ir]
    assert ops.count("separable") == 0 and ops.count("inverted_residual") == 0
    convs = [n for n in ir if n.op == "conv2d"]
    # stem + head + 17 blocks x (expand? + dw + pw); ir1 has expand factor 1
    assert len(convs) == 2 + 16 * 3 + 1 * 2
    adds = [n for n in ir if n.op == "add"]
    assert len(adds) == 10            # MBv2's stride-1 same-width blocks
    # every node's inputs are produced earlier (topological order)
    seen = set()
    for n in ir:
        assert all(i in seen for i in n.inputs), n
        seen.add(n.id)


def test_lower_tracks_depthwise_groups():
    specs, _, _ = _net("mobilenet_v1")
    ir = lower(specs, c_in=3)
    dw = next(n for n in ir if n.id == "sep2.dw")
    assert dw.attrs["depthwise"] and dw.attrs["groups"] == 32
    assert dw.attrs["w_path"] == ("sep2", "dw", "w")


def test_infer_shapes_matches_interpreter():
    specs, params, res = _net("squeezenet")
    ir = fuse(lower(specs, c_in=3))
    shapes = infer_shapes(ir, (1, res, res, 3))
    assert shapes[ir[-1].id] == (1, 1000)
    x = jnp.zeros((1, res, res, 3), jnp.float32)
    out = jax.eval_shape(
        lambda x: cnn.cnn_forward(params, x, specs, algorithm="im2col"), x)
    assert shapes[ir[-1].id] == out.shape


# ---------------------------------------------------------------------------
# fusion pattern rewrites
# ---------------------------------------------------------------------------

def test_fuse_rewrites_separable_blocks():
    specs, _, _ = _net("mobilenet_v1")
    ir = fuse(lower(specs, c_in=3))
    seps = [n for n in ir if n.op == "separable"]
    assert len(seps) == 13
    # fused nodes take the origin block's name and splice its edges
    assert {n.id for n in seps} == {f"sep{i}" for i in range(2, 15)}
    assert all(".dw" not in n.id and ".pw" not in n.id for n in ir)


def test_fuse_rewrites_inverted_residuals():
    specs, _, _ = _net("mobilenet_v2")
    ir = fuse(lower(specs, c_in=3))
    irs = [n for n in ir if n.op == "inverted_residual"]
    assert len(irs) == 17
    assert sum(n.attrs["residual"] for n in irs) == 10
    assert sum(1 for n in irs if n.attrs["exp_w"] is None) == 1   # ir1, t=1
    # the linear-projection chains are fully claimed: nothing separable-
    # fusable remains, and no hand-written fusion branch ever ran
    assert not [n for n in ir if n.op == "separable"]


def test_fuse_requires_single_consumer(rng):
    """A depthwise conv feeding TWO pointwise convs must not fuse (the
    z-cache intermediate would be needed twice)."""
    c = 8
    specs = [cnn.Conv("dw", 3, 3, c, groups=c),
             cnn.Concat([[cnn.Conv("pw1", 1, 1, c)],
                         [cnn.Conv("pw2", 1, 1, c)]])]
    ir = fuse(lower(specs, c_in=c))
    assert [n.op for n in ir if n.op != "input"] == \
        ["conv2d", "conv2d", "conv2d", "concat"]
    params = cnn.init_cnn(jax.random.key(0), specs, c, res=16)
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    net = compile_network(params, specs, res=16, c_in=c)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(net.apply(x), base) < 1e-3


def test_hand_built_ir_residual_flag_is_authoritative(rng):
    """The graph's add (or its absence) decides the skip connection, even
    where shapes would allow one: bind overrides the plan's shape-derived
    residual to match the IR."""
    c = 8
    graph = (
        LayerIR(id="input", op="input"),
        LayerIR(id="dw", op="conv2d", inputs=("input",),
                attrs=dict(kh=3, kw=3, c_out=c, stride=(1, 1),
                           padding="SAME", groups=c, depthwise=True,
                           activation="relu6", w_path=("dw", "w"),
                           b_path=("dw", "b"))),
        LayerIR(id="pw", op="conv2d", inputs=("dw",),
                attrs=dict(kh=1, kw=1, c_out=c, stride=(1, 1),
                           padding="SAME", groups=1, depthwise=False,
                           activation="none", w_path=("pw", "w"),
                           b_path=("pw", "b"))),
    )
    params = {"dw": {"w": jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 9,
                                      jnp.float32),
                     "b": jnp.zeros((c,), jnp.float32)},
              "pw": {"w": jnp.asarray(rng.standard_normal((1, 1, c, c)) / 3,
                                      jnp.float32),
                     "b": jnp.zeros((c,), jnp.float32)}}
    net = compile_network(params, graph, input_shape=(1, 12, 12, c))
    (p,) = [p for p in net.values() if isinstance(p, InvertedResidualPlan)]
    assert p.residual is False        # no add node in the graph
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    h = jax.nn.relu6(direct_conv2d(x, params["dw"]["w"], groups=c))
    want = direct_conv2d(h, params["pw"]["w"])
    assert rel_err(net.apply(x), want) < 1e-3


# ---------------------------------------------------------------------------
# placement + whole-zoo routing
# ---------------------------------------------------------------------------

def test_place_falls_back_per_layer():
    """A forced family falls back to im2col exactly on the layers the
    registry's executors don't cover."""
    specs = [cnn.Conv("a", 3, 3, 8),                      # covered
             cnn.Conv("b", 3, 3, 8, stride=3),            # stride 3: not
             cnn.Conv("c", 1, 1, 8)]                      # pointwise: not
    ir = fuse(lower(specs, c_in=4))
    shapes = infer_shapes(ir, (1, 24, 24, 4))
    placements = place(ir, shapes, "winograd")
    assert placements["a"]["algorithm"] == "winograd"
    assert placements["b"]["algorithm"] == "im2col"
    assert placements["c"]["algorithm"] == "im2col"


@pytest.mark.parametrize("net", sorted(cnn.NETWORKS))
def test_whole_zoo_routes_through_compiler(net):
    """Every zoo model compiles through lower -> fuse -> place -> bind and
    the compiled graph's output shape matches the interpreter's."""
    specs, params, res = _net(net)
    plan = compile_network(params, specs, res=res)
    assert plan.out_shape == (1, 1000)
    assert len(plan.describe().splitlines()) == len(plan.plans) + 2


def test_compiled_parity_with_baseline(rng):
    specs, params, res = _net("mobilenet_v2", res=32)
    net = compile_network(params, specs, res=32)
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(net.apply(x), base) < 1e-3
    assert rel_err(jax.jit(net.apply)(x), base) < 1e-3


def test_audio_stem_routes_through_compiler(rng):
    from repro import configs as cfglib
    cfg = cfglib.get_smoke_config("whisper_tiny")
    params = audio.init_stem(jax.random.key(0), cfg, n_mels=16)
    mel = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    net = compile_network(params, audio.stem_graph(cfg.d_model),
                          input_shape=mel.shape)
    want = audio.stem(params, mel)
    assert rel_err(net.apply(mel), want) < 1e-4
    assert net.out_shape == (2, 16, cfg.d_model)
    # the stem's stride-2 conv planned onto the polyphase decomposition
    assert net["conv2"].mode == "polyphase"


# ---------------------------------------------------------------------------
# deployment artifact: save/load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net,res", [("mobilenet_v2", 32), ("vgg16", 32)])
def test_artifact_round_trip_bitwise(rng, net, res, tmp_path):
    specs, params, _ = _net(net, res=res)
    plan = compile_network(params, specs, res=res)
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    y_cold = np.asarray(plan.apply(x))
    path = str(tmp_path / "net.npz")
    plan.save(path)
    loaded = NetworkPlan.load(path)
    assert np.array_equal(np.asarray(loaded.apply(x)), y_cold)
    assert plan_cache_info()["artifact_hits"] == 1


def test_artifact_audio_stem_round_trip(rng, tmp_path):
    from repro import configs as cfglib
    cfg = cfglib.get_smoke_config("whisper_tiny")
    params = audio.init_stem(jax.random.key(0), cfg, n_mels=16)
    mel = jnp.asarray(rng.standard_normal((1, 40, 16)), jnp.float32)
    net = compile_network(params, audio.stem_graph(cfg.d_model),
                          input_shape=mel.shape)
    path = str(tmp_path / "stem.npz")
    net.save(path)
    loaded = NetworkPlan.load(path)
    assert np.array_equal(np.asarray(loaded.apply(mel)),
                          np.asarray(net.apply(mel)))


def _tamper(path, **header_updates):
    data = dict(np.load(path, allow_pickle=False))
    header = json.loads(str(data["__header__"][()]))
    header.update(header_updates)
    data["__header__"] = np.array(json.dumps(header))
    np.savez(path, **data)


def test_artifact_mismatch_errors(rng, tmp_path, monkeypatch):
    """Version / registry-fingerprint / dtype / layout mismatches refuse
    with actionable errors (and count as artifact misses)."""
    specs, params, _ = _net("squeezenet", res=32)
    plan = compile_network(params, specs[:2], res=32)   # tiny prefix graph
    path = str(tmp_path / "net.npz")
    plan.save(path)

    _tamper(path, version=99)
    with pytest.raises(ArtifactMismatchError, match="version 99.*recompile"):
        NetworkPlan.load(path)

    plan.save(path)
    monkeypatch.setattr(registry, "fingerprint", lambda: "deadbeef")
    with pytest.raises(ArtifactMismatchError, match="registry.*stale"):
        NetworkPlan.load(path)
    monkeypatch.undo()

    with pytest.raises(ArtifactMismatchError, match="float32.*bfloat16"):
        NetworkPlan.load(path, expect_dtype=jnp.bfloat16)
    with pytest.raises(ArtifactMismatchError, match="layout"):
        NetworkPlan.load(path, expect_layout="NCHW")
    _tamper(path, format="something_else")
    with pytest.raises(ArtifactMismatchError, match="format"):
        NetworkPlan.load(path)
    info = plan_cache_info()
    assert info["artifact_misses"] == 5 and info["artifact_hits"] == 0


def test_compile_artifact_warm_start(rng, tmp_path):
    """compile(..., artifact=path): cold compile + save on the first call
    (an artifact miss), a pure load on the second (a hit)."""
    specs, params, _ = _net("squeezenet", res=32)
    path = str(tmp_path / "net.npz")
    p1 = compile_network(params, specs, res=32, artifact=path)
    assert os.path.exists(path)
    assert plan_cache_info()["artifact_misses"] == 1
    p2 = compile_network(params, specs, res=32, artifact=path)
    assert plan_cache_info()["artifact_hits"] == 1
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    assert np.array_equal(np.asarray(p1.apply(x)), np.asarray(p2.apply(x)))


def test_compile_artifact_rejects_stale_arguments(rng, tmp_path):
    """compile(artifact=) validates the artifact against THIS call: a
    different input shape or retrained weights recompile (one miss each)
    instead of silently serving the old plan."""
    specs = [cnn.Conv("a", 3, 3, 8), cnn.Conv("b", 1, 1, 4)]
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=32)
    path = str(tmp_path / "net.npz")
    compile_network(params, specs, res=32, artifact=path)         # cold
    assert plan_cache_info()["artifact_misses"] == 1
    p2 = compile_network(params, specs, res=48, artifact=path)    # stale res
    assert p2.input_shape == (1, 48, 48, 3)
    assert plan_cache_info()["artifact_misses"] == 2
    retrained = cnn.init_cnn(jax.random.key(9), specs, 3, res=48)
    p3 = compile_network(retrained, specs, res=48, artifact=path)
    assert plan_cache_info()["artifact_misses"] == 3
    x = jnp.asarray(rng.standard_normal((1, 48, 48, 3)), jnp.float32)
    base = cnn.cnn_forward(retrained, x, specs, algorithm="im2col")
    assert rel_err(p3.apply(x), base) < 1e-3    # the NEW weights are used
    compile_network(retrained, specs, res=48, artifact=path)      # warm now
    info = plan_cache_info()
    assert info["artifact_hits"] == 1 and info["artifact_misses"] == 3
    # an explicit dtype request that differs from the artifact recompiles
    p4 = compile_network(retrained, specs, res=48, dtype=jnp.bfloat16,
                         artifact=path)
    assert p4.dtype == "bfloat16"
    assert plan_cache_info()["artifact_misses"] == 4


def test_compile_artifact_corrupt_file_falls_back(tmp_path):
    """A truncated/garbage artifact must cold-compile (exactly one miss)
    and overwrite itself with a good one -- never crash every warm start
    until someone deletes the file."""
    specs = [cnn.Conv("a", 3, 3, 8)]
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=16)
    path = str(tmp_path / "net.npz")
    with open(path, "wb") as f:
        f.write(b"definitely not a zip archive")
    compile_network(params, specs, res=16, artifact=path)
    assert plan_cache_info()["artifact_misses"] == 1
    assert NetworkPlan.load(path) is not None   # repaired in place


def test_loaded_plan_performs_zero_filter_transform_ops(rng, tmp_path,
                                                        monkeypatch):
    """The warm path is transform-free, proven two ways: (1) loading never
    reaches the weight-binding chokepoint (every filter arrives in its
    execution domain), and (2) the loaded plan's apply() jaxpr is
    equation-for-equation the cold plan's -- with no raw HWIO filter
    constants left anywhere in it."""
    specs, params, _ = _net("mobilenet_v2", res=32)
    plan = compile_network(params, specs, res=32)
    path = str(tmp_path / "net.npz")
    plan.save(path)

    def boom(*a, **k):
        raise AssertionError("filter transform ran during load()")

    monkeypatch.setattr(planlib, "_bind_weights", boom)
    monkeypatch.setattr(planlib._wg, "transform_filter_2d", boom)
    monkeypatch.setattr(planlib._wg, "strided_phase_filters", boom)
    loaded = NetworkPlan.load(path)
    monkeypatch.undo()

    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    j_cold = jax.make_jaxpr(plan.apply)(x)
    j_warm = jax.make_jaxpr(loaded.apply)(x)
    assert [e.primitive.name for e in j_cold.eqns] == \
        [e.primitive.name for e in j_warm.eqns]
    for const in j_warm.consts:
        shape = getattr(const, "shape", ())
        # every MBv2 conv is 3x3 or 1x1: a (3, 3, C, M)-shaped constant
        # would be an untransformed HWIO filter smuggled into the hot path
        assert not (len(shape) == 4 and shape[0] == shape[1] == 3), shape


def test_fresh_process_warm_load_performs_zero_measurements(rng, tmp_path):
    """Acceptance gate for the measured auto_tuned policy: a saved
    auto_tuned NetworkPlan reloads in a FRESH python process with every
    measured per-layer winner intact and ZERO re-measurement -- the
    measured/fallback resolution counters stay at 0 after load()."""
    import subprocess
    import sys

    specs = [cnn.Conv("a", 3, 3, 8), cnn.Conv("b", 3, 3, 16)]
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=24)
    net = compile_network(params, specs, res=24, algorithm="auto_tuned")
    path = str(tmp_path / "net.npz")
    net.save(path)
    tuned = {k: p.spec for k, p in net.items()
             if getattr(getattr(p, "spec", None), "requested", None)
             == "auto_tuned"}
    assert tuned and all(s.autotune is not None for s in tuned.values())
    winners = {k: s.algorithm for k, s in tuned.items()}

    script = (
        "import json\n"
        "from repro.core.compile import NetworkPlan\n"
        "from repro.core.plan import plan_cache_info\n"
        f"net = NetworkPlan.load({path!r})\n"
        "info = plan_cache_info()\n"
        "tuned = {k: p for k, p in net.items()\n"
        "         if getattr(getattr(p, 'spec', None), 'requested', None)\n"
        "         == 'auto_tuned'}\n"
        "print(json.dumps({\n"
        "    'measured': info['measured'], 'fallback': info['fallback'],\n"
        "    'winners': {k: p.spec.algorithm for k, p in tuned.items()},\n"
        "    'decisions': {k: p.describe()['decision']\n"
        "                  for k, p in tuned.items()}}))\n")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["measured"] == 0 and got["fallback"] == 0
    assert got["winners"] == winners
    assert all(d == "measured" for d in got["decisions"].values())


# ---------------------------------------------------------------------------
# describe(): the per-layer table, same generator as the README table
# ---------------------------------------------------------------------------

def test_describe_uses_the_registry_table_generator(monkeypatch):
    """NetworkPlan.describe() and registry.capability_table() render
    through ONE markdown generator -- the two doc surfaces cannot drift."""
    specs, params, _ = _net("mobilenet_v1_050", res=32)
    net = compile_network(params, specs, res=32)
    calls = []
    real = registry.markdown_table

    def spy(header, rows):
        calls.append(tuple(header))
        return real(header, rows)

    monkeypatch.setattr(registry, "markdown_table", spy)
    table = net.describe()
    registry.capability_table()
    assert len(calls) == 2
    lines = table.splitlines()
    assert lines[1].replace(" ", "").startswith("|---")
    assert any("separable_streamed" in ln or "composed" not in ln
               for ln in lines)
    # one row per bound plan, in graph order, naming the executor
    assert "`winograd_strided`" in table        # the stride-2 stem
    assert "sep2" in table and "fc" not in [r.split("|")[1].strip()
                                            for r in lines[2:]]


def test_describe_reports_fused_modes():
    specs, params, _ = _net("mobilenet_v1_050", res=32)
    net = compile_network(params, specs, res=32,
                          algorithm="pallas_winograd")
    d = net["sep2"].describe()
    assert d["mode"] == "fused_pallas"
    assert d["executor"] == "separable_streamed"
    d3 = net["sep3"].describe()                  # stride-2: composed
    assert d3["mode"] == "composed"
    assert "+" in d3["executor"]


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_legacy_entry_points_warn_and_delegate(rng):
    compiler._DEPRECATION_WARNED.clear()
    specs, params, res = _net("squeezenet", res=32)
    with pytest.warns(DeprecationWarning, match="compile"):
        plans = cnn.plan_cnn(params, specs, res=32)
    assert isinstance(plans, NetworkPlan)
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    with pytest.warns(DeprecationWarning, match="NetworkPlan|compile"):
        got = cnn.cnn_forward(params, x, specs, plans=plans)
    assert np.array_equal(np.asarray(got), np.asarray(plans.apply(x)))

    from repro import configs as cfglib
    cfg = cfglib.get_smoke_config("whisper_tiny")
    ap = audio.init_stem(jax.random.key(0), cfg, n_mels=8)
    mel = jnp.asarray(rng.standard_normal((1, 20, 8)), jnp.float32)
    with pytest.warns(DeprecationWarning, match="stem_graph"):
        stem_plans = audio.plan_stem(ap, mel.shape)
    assert isinstance(stem_plans, NetworkPlan)
    assert rel_err(audio.stem(ap, mel, plans=stem_plans),
                   audio.stem(ap, mel)) < 1e-4


def test_legacy_warns_once_per_process():
    compiler._DEPRECATION_WARNED.clear()
    specs, params, _ = _net("squeezenet", res=32)
    with pytest.warns(DeprecationWarning):
        cnn.plan_cnn(params, specs[:1], res=32)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        cnn.plan_cnn(params, specs[:1], res=32)   # second call: silent
