"""Sharding-rule tests: the divisibility guard, 2-D TP x FSDP parameter
specs, batch/cache specs, and mesh construction -- exercised against the
production mesh *shape* via a lightweight mesh stand-in (the guard and spec
logic only reads axis_names / devices.shape, so no 256 devices needed)."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as cfglib
from repro.distributed import sharding as shd
from repro.models import transformer as tf


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    return types.SimpleNamespace(axis_names=axes,
                                 devices=np.empty(shape, object))


def fake_multipod():
    return fake_mesh((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# divisibility guard
# ---------------------------------------------------------------------------

def test_guard_keeps_divisible_axes():
    m = fake_mesh()
    assert shd._guard(m, (512, 256), P("data", "model")) == P("data", "model")


def test_guard_drops_nondivisible_axis():
    m = fake_mesh()
    # 40 experts on a 16-way model axis: replicate instead of fail
    assert shd._guard(m, (40, 128, 64), P("model", "data", None)) == \
        P(None, "data", None)


def test_guard_handles_tuple_axes():
    m = fake_multipod()
    assert shd._guard(m, (64, 8), P(("pod", "data"), None)) == \
        P(("pod", "data"), None)
    assert shd._guard(m, (30, 8), P(("pod", "data"), None)) == P(None, None)


def test_guard_pads_short_specs():
    m = fake_mesh()
    assert shd._guard(m, (32, 32, 32), P("data")) == P("data", None, None)


# ---------------------------------------------------------------------------
# parameter specs on the production mesh shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_param_specs_cover_every_leaf(arch):
    cfg = cfglib.get_config(arch)
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree.leaves(params_shape))
    for spec in leaves:
        assert isinstance(spec, P)


@pytest.mark.parametrize("arch", ["qwen1_5_32b", "nemotron_4_340b",
                                  "llama4_maverick_400b_a17b"])
def test_big_arch_params_are_2d_sharded(arch):
    """For the 32B+ archs every large matrix must shard on BOTH mesh axes
    (pure TP or pure FSDP would not fit 16 GB/chip)."""
    cfg = cfglib.get_config(arch)
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, spec_leaves):
        n_elem = int(np.prod(leaf.shape))
        if n_elem >= 64e6:               # every big matrix
            used = {a for ax in spec if ax is not None
                    for a in (ax if isinstance(ax, tuple) else (ax,))}
            assert {"data", "model"} <= used, (path, leaf.shape, spec)


def test_embed_sharded_on_vocab_and_dmodel():
    cfg = cfglib.get_config("qwen2_5_3b")
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    assert specs["embed"] == P("model", "data")


def test_moe_expert_parallel_spec():
    cfg = cfglib.get_config("llama4_maverick_400b_a17b")   # 128 experts % 16
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    moe_specs = specs["blocks"]["layer_0"]["moe"]
    # stacked (U, E, D, F): expert axis on "model" (EP)
    assert moe_specs["up"] == P(None, "model", "data", None)
    assert moe_specs["down"] == P(None, "model", None, "data")


def test_granite_moe_falls_back_when_experts_dont_divide():
    cfg = cfglib.get_config("granite_moe_3b_a800m")        # 40 experts % 16
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    up = specs["blocks"]["layer_0"]["moe"]["up"]
    # guard must not leave "model" on the 40-expert axis
    assert up[1] != "model"


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def test_batch_specs_single_and_multipod():
    sd = jax.ShapeDtypeStruct
    batch = {"tokens": sd((256, 4096), jnp.int32),
             "labels": sd((256, 4096), jnp.int32)}
    s1 = shd.batch_specs(batch, fake_mesh())
    assert s1["tokens"] == P(("data",), None)
    s2 = shd.batch_specs(batch, fake_multipod())
    assert s2["tokens"] == P(("pod", "data"), None)


def test_cache_specs_kv_and_mamba():
    cfg = cfglib.get_config("jamba_v0_1_52b")
    cache = tf.abstract_decode_cache(cfg, 128, 1024, jnp.bfloat16)
    specs = shd.cache_specs(cache, cfg, fake_mesh())
    kv = specs["layer_4"]          # jamba's attention layer sits at idx 4
    assert tuple(kv["k"])[1] == ("data",) or tuple(kv["k"])[1] == "data"
    # kv heads (8) don't divide the 16-way model axis -> head_dim shards
    assert tuple(kv["k"])[4] == "model"
    mamba = specs["layer_0"]
    assert "model" in tuple(mamba["ssm"])          # d_in TP
    assert "model" in tuple(mamba["conv"])


def test_cache_specs_batch1_falls_back_to_seq():
    """long_500k has global batch 1: the KV batch axis cannot shard, the
    sequence axis takes the data axes instead."""
    cfg = cfglib.get_config("jamba_v0_1_52b")
    cache = tf.abstract_decode_cache(cfg, 1, 2048, jnp.bfloat16)
    specs = shd.cache_specs(cache, cfg, fake_mesh())
    kv = specs["layer_4"]
    assert tuple(kv["k"])[1] is None
    assert tuple(kv["k"])[2] in (("data",), "data")   # seq axis sharded


# ---------------------------------------------------------------------------
# real (1-device) mesh integration: shardings construct and apply
# ---------------------------------------------------------------------------

def test_shardings_apply_on_host_mesh():
    from repro.launch.mesh import make_host_mesh
    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    mesh = make_host_mesh()
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    sh = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    placed = jax.device_put(params, sh)
    assert jax.tree.all(jax.tree.map(
        lambda x: bool(jnp.all(jnp.isfinite(x))), placed))
