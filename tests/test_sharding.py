"""Sharding-rule tests: the divisibility guard, 2-D TP x FSDP parameter
specs, batch/cache specs, and mesh construction -- exercised against the
production mesh *shape* via a lightweight mesh stand-in (the guard and spec
logic only reads axis_names / devices.shape, so no 256 devices needed)."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as cfglib
from repro.distributed import sharding as shd
from repro.models import transformer as tf


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    return types.SimpleNamespace(axis_names=axes,
                                 devices=np.empty(shape, object))


def fake_multipod():
    return fake_mesh((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# divisibility guard
# ---------------------------------------------------------------------------

def test_guard_keeps_divisible_axes():
    m = fake_mesh()
    assert shd._guard(m, (512, 256), P("data", "model")) == P("data", "model")


def test_guard_drops_nondivisible_axis():
    m = fake_mesh()
    # 40 experts on a 16-way model axis: replicate instead of fail
    assert shd._guard(m, (40, 128, 64), P("model", "data", None)) == \
        P(None, "data", None)


def test_guard_handles_tuple_axes():
    m = fake_multipod()
    assert shd._guard(m, (64, 8), P(("pod", "data"), None)) == \
        P(("pod", "data"), None)
    assert shd._guard(m, (30, 8), P(("pod", "data"), None)) == P(None, None)


def test_guard_pads_short_specs():
    m = fake_mesh()
    assert shd._guard(m, (32, 32, 32), P("data")) == P("data", None, None)


# ---------------------------------------------------------------------------
# parameter specs on the production mesh shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_param_specs_cover_every_leaf(arch):
    cfg = cfglib.get_config(arch)
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree.leaves(params_shape))
    for spec in leaves:
        assert isinstance(spec, P)


@pytest.mark.parametrize("arch", ["qwen1_5_32b", "nemotron_4_340b",
                                  "llama4_maverick_400b_a17b"])
def test_big_arch_params_are_2d_sharded(arch):
    """For the 32B+ archs every large matrix must shard on BOTH mesh axes
    (pure TP or pure FSDP would not fit 16 GB/chip)."""
    cfg = cfglib.get_config(arch)
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, spec_leaves):
        n_elem = int(np.prod(leaf.shape))
        if n_elem >= 64e6:               # every big matrix
            used = {a for ax in spec if ax is not None
                    for a in (ax if isinstance(ax, tuple) else (ax,))}
            assert {"data", "model"} <= used, (path, leaf.shape, spec)


def test_embed_sharded_on_vocab_and_dmodel():
    cfg = cfglib.get_config("qwen2_5_3b")
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    assert specs["embed"] == P("model", "data")


def test_moe_expert_parallel_spec():
    cfg = cfglib.get_config("llama4_maverick_400b_a17b")   # 128 experts % 16
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    moe_specs = specs["blocks"]["layer_0"]["moe"]
    # stacked (U, E, D, F): expert axis on "model" (EP)
    assert moe_specs["up"] == P(None, "model", "data", None)
    assert moe_specs["down"] == P(None, "model", None, "data")


def test_granite_moe_falls_back_when_experts_dont_divide():
    cfg = cfglib.get_config("granite_moe_3b_a800m")        # 40 experts % 16
    params_shape = tf.abstract_params(cfg, jnp.bfloat16)
    specs = shd.param_specs(params_shape, cfg, fake_mesh())
    up = specs["blocks"]["layer_0"]["moe"]["up"]
    # guard must not leave "model" on the 40-expert axis
    assert up[1] != "model"


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def test_batch_specs_single_and_multipod():
    sd = jax.ShapeDtypeStruct
    batch = {"tokens": sd((256, 4096), jnp.int32),
             "labels": sd((256, 4096), jnp.int32)}
    s1 = shd.batch_specs(batch, fake_mesh())
    assert s1["tokens"] == P(("data",), None)
    s2 = shd.batch_specs(batch, fake_multipod())
    assert s2["tokens"] == P(("pod", "data"), None)


def test_cache_specs_kv_and_mamba():
    cfg = cfglib.get_config("jamba_v0_1_52b")
    cache = tf.abstract_decode_cache(cfg, 128, 1024, jnp.bfloat16)
    specs = shd.cache_specs(cache, cfg, fake_mesh())
    kv = specs["layer_4"]          # jamba's attention layer sits at idx 4
    assert tuple(kv["k"])[1] == ("data",) or tuple(kv["k"])[1] == "data"
    # kv heads (8) don't divide the 16-way model axis -> head_dim shards
    assert tuple(kv["k"])[4] == "model"
    mamba = specs["layer_0"]
    assert "model" in tuple(mamba["ssm"])          # d_in TP
    assert "model" in tuple(mamba["conv"])


def test_cache_specs_batch1_falls_back_to_seq():
    """long_500k has global batch 1: the KV batch axis cannot shard, the
    sequence axis takes the data axes instead."""
    cfg = cfglib.get_config("jamba_v0_1_52b")
    cache = tf.abstract_decode_cache(cfg, 1, 2048, jnp.bfloat16)
    specs = shd.cache_specs(cache, cfg, fake_mesh())
    kv = specs["layer_4"]
    assert tuple(kv["k"])[1] is None
    assert tuple(kv["k"])[2] in (("data",), "data")   # seq axis sharded


# ---------------------------------------------------------------------------
# real (1-device) mesh integration: shardings construct and apply
# ---------------------------------------------------------------------------

def test_shardings_apply_on_host_mesh():
    from repro.launch.mesh import make_host_mesh
    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    mesh = make_host_mesh()
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    sh = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    placed = jax.device_put(params, sh)
    assert jax.tree.all(jax.tree.map(
        lambda x: bool(jnp.all(jnp.isfinite(x))), placed))


# ---------------------------------------------------------------------------
# conv NetworkPlan partitioning: decide_partition is a pure IR walk
# ---------------------------------------------------------------------------

from repro.core import compile as cc          # noqa: E402
from repro.core import partition as pt        # noqa: E402
from repro.models import cnn                  # noqa: E402

CNN_SPECS = [cnn.Conv("c1", 3, 3, 8),
             cnn.Conv("c2", 5, 5, 8),
             cnn.Pool("max", 2, 2),
             cnn.Conv("c3", 3, 3, 16),
             cnn.GlobalAvgPool(),
             cnn.Dense("fc", 10, relu=False)]


def _cnn_ir(batch=8, res=32):
    ir = cc.fuse(cc.lower(CNN_SPECS, c_in=3))
    shapes = cc.infer_shapes(ir, (batch, res, res, 3))
    return ir, shapes


def test_decide_partition_data_divisible():
    ir, shapes = _cnn_ir(batch=8)
    part = pt.decide_partition(ir, shapes, 4, "data")
    assert part == {"kind": "data", "axis": "data", "num_shards": 4,
                    "requested_shards": 4, "degraded": None}


def test_decide_partition_data_indivisible_degrades():
    ir, shapes = _cnn_ir(batch=6)
    part = pt.decide_partition(ir, shapes, 4, "data")
    assert part["num_shards"] == 1 and part["requested_shards"] == 4
    assert "does not divide" in part["degraded"]


def test_decide_partition_spatial_modes():
    """The spatial walk: stride-1 odd-k convs halo, the stride-2 pool
    re-gathers (and re-scatters: H/2 still divides), global pooling is a
    local-mean + pmean, the classifier head runs replicated."""
    ir, shapes = _cnn_ir(batch=2, res=32)
    part = pt.decide_partition(ir, shapes, 4, "spatial")
    m = part["modes"]
    assert m["c1"] == "halo" and part["halo"]["c1"] == 1
    assert m["c2"] == "halo" and part["halo"]["c2"] == 2
    pool = next(k for k in m if k.startswith("pool"))
    assert m[pool] == "full" and part["rescatter"][pool]
    assert m["c3"] == "halo"
    gap = next(k for k in m if k.startswith("gap"))
    assert m[gap] == "reduce"
    assert m["fc"] == "local"
    assert part["out_sharded"] is False


def test_decide_partition_spatial_halo_needs_enough_rows():
    """A 5x5 halo (2 rows) cannot come out of a 1-row local strip: the
    conv re-gathers instead of haloing when H/D < (k-1)//2 fails."""
    ir, shapes = _cnn_ir(batch=2, res=8)
    part = pt.decide_partition(ir, shapes, 8, "spatial")
    assert part["modes"]["c1"] == "halo"          # halo 1 <= 1 local row
    assert part["modes"]["c2"] == "full"          # halo 2 > 1 local row


def test_decide_partition_spatial_indivisible_h_degrades():
    ir, shapes = _cnn_ir(batch=2, res=30)
    part = pt.decide_partition(ir, shapes, 4, "spatial")
    assert part["num_shards"] == 1
    assert "does not divide" in part["degraded"]


def test_make_data_mesh_and_host_mesh_guards():
    from repro.launch.mesh import make_data_mesh, make_host_mesh
    n = len(jax.devices())
    mesh = make_data_mesh()
    assert mesh.axis_names == ("data",) and mesh.shape["data"] == n
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_data_mesh(n + 1)
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(model_parallel=3 * n)


# ---------------------------------------------------------------------------
# sharded NetworkPlan execution on 8 forced host devices (subprocesses,
# like test_multidevice.py: the main pytest process stays single-device)
# ---------------------------------------------------------------------------

import os                                     # noqa: E402
import subprocess                             # noqa: E402
import sys                                    # noqa: E402
import textwrap                               # noqa: E402

_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _run_forced(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_batch_sharded_apply_parity_and_degradation_8dev():
    """Data-parallel sharding on 8 forced host devices: batch-8 parity
    against the unsharded oracle, and an indivisible batch degrades to a
    replicated plan (recorded reason) that still serves with parity."""
    stdout = _run_forced("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as C
        from repro.launch.mesh import make_data_mesh
        from repro.models import cnn

        assert jax.device_count() == 8
        SPECS = [cnn.Conv("c1", 3, 3, 8),
                 cnn.Conv("c2", 3, 3, 16, stride=2),
                 cnn.GlobalAvgPool(), cnn.Dense("fc", 10, relu=False)]
        params = cnn.init_cnn(jax.random.key(0), SPECS, 3, res=16)
        x = np.random.default_rng(0).standard_normal(
            (8, 16, 16, 3)).astype(np.float32)
        ref = np.asarray(C.compile(params, SPECS, res=16, batch=8)
                         .apply(jnp.asarray(x)))
        mesh = make_data_mesh(8)
        net = C.compile(params, SPECS, res=16, batch=8, mesh=mesh)
        assert net.partition["kind"] == "data"
        assert net.partition["num_shards"] == 8
        y = np.asarray(net.apply(jnp.asarray(x)))
        err = float(np.max(np.abs(y - ref)) / np.max(np.abs(ref)))
        assert err < 1e-5, err

        net6 = C.compile(params, SPECS, res=16, batch=6, mesh=mesh)
        assert net6.partition["num_shards"] == 1
        assert "does not divide" in net6.partition["degraded"]
        ref6 = np.asarray(C.compile(params, SPECS, res=16, batch=6)
                          .apply(jnp.asarray(x[:6])))
        y6 = np.asarray(net6.apply(jnp.asarray(x[:6])))
        assert np.max(np.abs(y6 - ref6)) / np.max(np.abs(ref6)) < 1e-5
        print("OK", err)
    """)
    assert "OK" in stdout


def test_halo_sharded_apply_parity_8dev():
    """Spatial halo partitioning on 8 forced host devices: H splits
    8-way, stride-1 convs exchange halo rows via ppermute, the stride-2
    pool re-gathers/re-scatters, and the output matches the unsharded
    oracle to 1e-5."""
    stdout = _run_forced("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as C
        from repro.launch.mesh import make_data_mesh
        from repro.models import cnn

        SPECS = [cnn.Conv("c1", 3, 3, 8),
                 cnn.Conv("c2", 5, 5, 8),
                 cnn.Pool("max", 2, 2),
                 cnn.Conv("c3", 3, 3, 16),
                 cnn.GlobalAvgPool(), cnn.Dense("fc", 10, relu=False)]
        params = cnn.init_cnn(jax.random.key(0), SPECS, 3, res=32)
        x = np.random.default_rng(1).standard_normal(
            (2, 32, 32, 3)).astype(np.float32)
        ref = np.asarray(C.compile(params, SPECS, res=32, batch=2)
                         .apply(jnp.asarray(x)))
        net = C.compile(params, SPECS, res=32, batch=2,
                        mesh=make_data_mesh(8), partition="spatial")
        part = net.partition
        assert part["kind"] == "spatial" and part["num_shards"] == 8
        assert part["modes"]["c1"] == "halo"
        assert part["modes"]["c2"] == "halo" and part["halo"]["c2"] == 2
        y = np.asarray(net.apply(jnp.asarray(x)))
        err = float(np.max(np.abs(y - ref)) / np.max(np.abs(ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in stdout


def test_partition_artifact_roundtrip_8dev(tmp_path):
    """Version-5 artifacts persist the partition record: a warm start
    restores the recorded sharding without re-deciding (one artifact hit,
    zero misses), a load without a mesh demands .with_mesh() before
    sharded execution, and an unsharded compile refuses the sharded
    artifact (cold recompile) instead of silently reusing it."""
    stdout = _run_forced(f"""
        import os, numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as C
        from repro.core.plan import clear_plan_cache, plan_cache_info
        from repro.launch.mesh import make_data_mesh
        from repro.models import cnn

        art = os.path.join({str(tmp_path)!r}, "net.npz")
        SPECS = [cnn.Conv("c1", 3, 3, 8),
                 cnn.GlobalAvgPool(), cnn.Dense("fc", 10, relu=False)]
        params = cnn.init_cnn(jax.random.key(0), SPECS, 3, res=16)
        x = np.random.default_rng(2).standard_normal(
            (8, 16, 16, 3)).astype(np.float32)
        mesh = make_data_mesh(8)
        net = C.compile(params, SPECS, res=16, batch=8, mesh=mesh,
                        artifact=art)
        assert plan_cache_info()["artifact_misses"] == 1   # cold
        ref = np.asarray(net.apply(jnp.asarray(x)))

        clear_plan_cache()
        warm = C.compile(params, SPECS, res=16, batch=8, mesh=mesh,
                         artifact=art)
        info = plan_cache_info()
        assert info["artifact_hits"] == 1 and info["artifact_misses"] == 0
        assert warm.partition == net.partition
        y = np.asarray(warm.apply(jnp.asarray(x)))
        assert np.max(np.abs(y - ref)) / np.max(np.abs(ref)) < 1e-6

        loaded = C.NetworkPlan.load(art)     # no mesh attached yet
        assert loaded.is_sharded() and loaded.mesh is None
        try:
            loaded.apply(jnp.asarray(x))
            raise SystemExit("expected ValueError without a mesh")
        except ValueError as e:
            assert "with_mesh" in str(e), e
        y2 = np.asarray(loaded.with_mesh(mesh).apply(jnp.asarray(x)))
        assert np.max(np.abs(y2 - ref)) / np.max(np.abs(ref)) < 1e-6

        clear_plan_cache()
        plain = C.compile(params, SPECS, res=16, batch=8, artifact=art)
        assert plain.partition is None       # sharded artifact rejected
        assert plan_cache_info()["artifact_misses"] == 1
        print("OK")
    """)
    assert "OK" in stdout


def test_server_binds_buckets_to_mesh_8dev(tmp_path):
    """A Server given a mesh serves divisible buckets through sharded
    plans on the jitted happy path (stats.sharded_buckets), indivisible
    buckets through the plain plans, with outputs matching the eager
    oracle; supervisor repairs stay on the single-logical-device plans."""
    stdout = _run_forced("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compile as C
        from repro.launch.mesh import make_data_mesh
        from repro.models import cnn
        from repro.runtime.serve import ServeConfig, Server

        SPECS = [cnn.Conv("c1", 3, 3, 8),
                 cnn.Conv("c2", 3, 3, 8, relu=False)]
        params = cnn.init_cnn(jax.random.key(0), SPECS, 3, res=16)
        cfg = ServeConfig(buckets=(2, 8), queue_capacity=64, verbose=False,
                          backoff_base_s=0.002, backoff_cap_s=0.01)
        srv = Server(params, SPECS, res=16, algorithm="winograd",
                     config=cfg, mesh=make_data_mesh(8))
        assert srv.stats.sharded_buckets == {"8": 8}   # 2 is indivisible
        xs = [np.random.default_rng(i).standard_normal(
                  (16, 16, 3)).astype(np.float32) for i in range(8)]
        srv.start()
        ys = [t.result(timeout=120) for t in [srv.submit(x) for x in xs]]
        srv.stop()
        assert srv.stats.jit_dispatches >= 1
        assert srv.stats.failed == 0 and srv.stats.in_flight == 0
        oracle = C.compile(params, SPECS, res=16, batch=1,
                           algorithm="im2col")
        for x, y in zip(xs, ys):
            ref = np.asarray(oracle.apply(jnp.asarray(x[None])))[0]
            err = np.max(np.abs(y - ref)) / (np.max(np.abs(ref)) + 1e-9)
            assert err < 2e-3, err
        print("OK")
    """)
    assert "OK" in stdout
