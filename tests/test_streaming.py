"""Halo-aware region-streaming Winograd path (kernels.winograd
winograd_streamed + the planned pallas_winograd executor).

Covers: oracle equivalence vs jax.lax.conv_general_dilated across odd H/W
(non-multiples of the tile), SAME/VALID, batch > 1, C/M not multiples of the
block sizes, and every fused epilogue activation; the jaxpr regression that
the streamed path materializes no (R, th, tw, C) tile tensor and performs no
post-kernel un-tiling transpose; the fused GEMM epilogue; and the shared
interpret-mode resolution rule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import winograd as wg
from repro.core.plan import plan_conv2d
from repro.kernels import ops, ref
from repro.kernels import matmul as k_matmul
from repro.kernels import winograd as k_winograd
from repro.kernels import runtime

from conftest import rel_err

# (plan-cache isolation is provided by the autouse _fresh_plan_cache fixture
# in conftest.py)


def _oracle(x, w, bias, activation, padding):
    y = ref.conv2d_direct(x, w, padding=padding)
    if bias is not None:
        y = y + bias
    if activation == "relu":
        y = jax.nn.relu(y)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    return y


# ---------------------------------------------------------------------------
# oracle equivalence of the planned streaming executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w", [(11, 13), (9, 16)])   # odd / non-tile-multiple
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("batch", [1, 3])
def test_streamed_plan_vs_direct(rng, h, w, padding, batch):
    c, m = 5, 7                                  # below the block quantum
    x = jnp.asarray(rng.standard_normal((batch, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, c, m)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, wt, padding=padding, algorithm="pallas_winograd")
    assert p.algorithm == "pallas_winograd"
    got = p.apply(x)
    want = _oracle(x, wt, None, "none", padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


@pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
def test_streamed_fused_epilogue_vs_direct(rng, activation):
    x = jnp.asarray(rng.standard_normal((2, 14, 10, 6)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 6, 9)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((9,)), jnp.float32)
    p = plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    got = p.apply(x, bias=b, activation=activation)
    want = _oracle(x, wt, b, activation, "SAME")
    assert rel_err(got, want) < 1e-4


def test_streamed_multiblock_channels(rng):
    """C and M above one block exercise the cross-C-step accumulator and the
    M-block grid axis; C/M deliberately not multiples of 128."""
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 130)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 130, 136)) / 9, jnp.float32)
    p = plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    got = p.apply(x)
    assert rel_err(got, _oracle(x, wt, None, "none", "SAME")) < 1e-4


def test_streamed_5x5_filter(rng):
    x = jnp.asarray(rng.standard_normal((2, 13, 13, 4)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((5, 5, 4, 6)) / 25, jnp.float32)
    p = plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    got = p.apply(x)
    assert rel_err(got, _oracle(x, wt, None, "none", "SAME")) < 1e-4


def test_materialized_plan_out_shape(rng):
    """out_shape must resolve for every winograd-family algorithm."""
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 12)) / 3, jnp.float32)
    for alg in ("pallas_winograd", "pallas_winograd_materialized"):
        p = plan_conv2d((2, 17, 11, 8), w, algorithm=alg)
        assert p.out_shape == (2, 17, 11, 12)


def test_streamed_matches_materialized_baseline(rng):
    """Streaming executor == the pre-streaming materialized-tiles executor
    (the A/B pair benchmarks/per_layer.py measures)."""
    x = jnp.asarray(rng.standard_normal((2, 17, 11, 8)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 12)) / 3, jnp.float32)
    p_new = plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    p_old = plan_conv2d(x.shape, wt,
                        algorithm="pallas_winograd_materialized")
    assert rel_err(p_new.apply(x), p_old.apply(x)) < 1e-5


# ---------------------------------------------------------------------------
# streamed vs materialized parity on asymmetric and edge shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w,c,m", [
    (11, 25, 5, 7),      # H != W, both non-multiples of the output tile
    (32, 8, 130, 12),    # extreme aspect ratio, C just past one 128 block
    (9, 31, 8, 136),     # M just past one block, W prime
    (17, 11, 3, 5),      # tiny channels (below the block quantum)
    (8, 8, 1, 1),        # degenerate single-channel square
])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_streamed_vs_materialized_edge_shapes(rng, h, w, c, m, padding):
    """The streaming executor and the pre-streaming materialized-tiles
    executor must agree wherever the tile grid is ragged: H != W, spatial
    sizes not multiples of the output tile, C/M not multiples of the block
    sizes."""
    x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, c, m)) / 3, jnp.float32)
    p_new = plan_conv2d(x.shape, wt, padding=padding,
                        algorithm="pallas_winograd")
    p_old = plan_conv2d(x.shape, wt, padding=padding,
                        algorithm="pallas_winograd_materialized")
    got, want = p_new.apply(x), p_old.apply(x)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-5
    # both must also agree with the direct-conv oracle, not just each other
    assert rel_err(got, _oracle(x, wt, None, "none", padding)) < 1e-4


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover - CI installs it
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        h=st.integers(8, 33), w=st.integers(8, 33),
        c=st.integers(1, 17), m=st.integers(1, 17),
        k=st.sampled_from([3, 5]),
        padding=st.sampled_from(["SAME", "VALID"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_streamed_vs_materialized_property(h, w, c, m, k, padding, seed):
        """Property sweep: for arbitrary (H, W, C, M, k, padding) the
        streamed plan, the materialized plan, and the direct-conv oracle all
        agree."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((k, k, c, m)) / k, jnp.float32)
        p_new = plan_conv2d(x.shape, wt, padding=padding,
                            algorithm="pallas_winograd")
        p_old = plan_conv2d(x.shape, wt, padding=padding,
                            algorithm="pallas_winograd_materialized")
        got, want = p_new.apply(x), p_old.apply(x)
        assert got.shape == want.shape
        assert rel_err(got, want) < 1e-5
        assert rel_err(got, _oracle(x, wt, None, "none", padding)) < 1e-4


def test_streamed_kernel_direct_call(rng):
    """winograd_streamed standalone: pre-padded input, aligned channels."""
    from repro.core.transforms import cook_toom
    ct = cook_toom(4, 3)
    bh = bw = 2
    c, m = 8, 8
    xp = jnp.asarray(rng.standard_normal((1, 2 * bh * 4 + 2, bw * 4 + 2, c)),
                     jnp.float32)
    u = jnp.asarray(rng.standard_normal((36, c, m)), jnp.float32)
    y = k_winograd.winograd_streamed(xp, u, None, ct_h=ct, ct_w=ct,
                                     bh=bh, bw=bw, block_c=c, block_m=m,
                                     interpret=True)
    assert y.shape == (1, 2 * bh * 4, bw * 4, m)
    # reference: extract tiles by hand and run the tiles-domain oracle
    tiles = wg._extract_tiles_1d(xp, 1, ct.t, ct.m, 2 * bh)
    tiles = wg._extract_tiles_1d(tiles, 3, ct.t, ct.m, bw)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(2 * bh * bw, ct.t,
                                                      ct.t, c)
    want = ref.winograd_fused(tiles, u, ct_h=ct, ct_w=ct)
    want = want.reshape(1, 2 * bh, bw, 4, 4, m).transpose(0, 1, 3, 2, 4, 5)
    want = want.reshape(1, 2 * bh * 4, bw * 4, m)
    assert rel_err(y, want) < 1e-4


# ---------------------------------------------------------------------------
# jaxpr regression: nothing materializes the tile tensor, nothing un-tiles
# ---------------------------------------------------------------------------

def _top_level_shapes(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield eqn.primitive.name, tuple(getattr(v.aval, "shape", ()))


def test_streamed_jaxpr_has_no_tile_intermediate(rng):
    """The planned streaming path must not materialize a (R, th, tw, C)
    overlapping-tile tensor in HBM nor run a post-kernel un-tiling
    transpose; the whole algorithm lives inside one pallas_call."""
    x = jnp.asarray(rng.standard_normal((1, 20, 20, 12)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 12, 10)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((10,)), jnp.float32)
    p = plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    th = tw = p.spec.ct_h.t
    jaxpr = jax.make_jaxpr(
        lambda xx: p.apply(xx, bias=b, activation="relu"))(x).jaxpr

    tile_like = [s for _, s in _top_level_shapes(jaxpr)
                 if len(s) == 4 and s[1] == th and s[2] == tw]
    assert not tile_like, f"tile tensor materialized: {tile_like}"
    untile = [s for nm, s in _top_level_shapes(jaxpr)
              if nm == "transpose" and len(s) >= 5]
    assert not untile, f"post-kernel un-tiling transpose: {untile}"
    # the epilogue is fused: no add/max on the full NHWC output outside
    # the kernel (bias broadcast add would be a top-level add of rank 4)
    epilogue = [nm for nm, s in _top_level_shapes(jaxpr)
                if nm in ("add", "max") and len(s) == 4]
    assert not epilogue, f"unfused epilogue ops: {epilogue}"


def test_materialized_jaxpr_shows_what_streaming_removed(rng):
    """Sanity check that the regression assertions have teeth: the
    pre-streaming executor does materialize tiles and does un-tile."""
    x = jnp.asarray(rng.standard_normal((1, 20, 20, 12)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 12, 10)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, wt, algorithm="pallas_winograd_materialized")
    th = tw = p.spec.ct_h.t
    jaxpr = jax.make_jaxpr(p.apply)(x).jaxpr
    tile_like = [s for _, s in _top_level_shapes(jaxpr)
                 if len(s) == 4 and s[1] == th and s[2] == tw]
    untile = [s for nm, s in _top_level_shapes(jaxpr)
              if nm == "transpose" and len(s) >= 5]
    assert tile_like and untile


# ---------------------------------------------------------------------------
# fused GEMM epilogue (im2col path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
def test_matmul_kernel_fused_epilogue(rng, activation):
    a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((1, 128)), jnp.float32)
    got = k_matmul.matmul(a, b, bias=bias, activation=activation,
                          interpret=True)
    want = runtime.apply_activation(
        jnp.matmul(a, b, preferred_element_type=jnp.float32) + bias,
        activation)
    assert rel_err(got, want) < 1e-5


def test_im2col_planned_fused_epilogue(rng):
    x = jnp.asarray(rng.standard_normal((2, 10, 10, 6)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 6, 9)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((9,)), jnp.float32)
    p = plan_conv2d(x.shape, wt, stride=2, algorithm="pallas_im2col")
    got = p.apply(x, bias=b, activation="relu")
    want = jax.nn.relu(ref.conv2d_direct(x, wt, stride=2) + b)
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# shared interpret-mode resolution (REPRO_PALLAS_COMPILE-aware defaults)
# ---------------------------------------------------------------------------

def test_default_interpret_env_rule(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert runtime.default_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert runtime.default_interpret() is False
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False


def test_winograd_fused_interpret_defaults_to_runtime_rule(rng):
    """Satellite regression: winograd_fused no longer hardcodes
    interpret=True -- with no argument it follows the shared rule (True on
    this CPU-only host) and still matches the oracle."""
    from repro.core.transforms import cook_toom
    ct = cook_toom(2, 3)
    tiles = jnp.asarray(rng.standard_normal((128, ct.t, ct.t, 128)),
                        jnp.float32)
    u = jnp.asarray(rng.standard_normal((ct.t * ct.t, 128, 128)), jnp.float32)
    got = k_winograd.winograd_fused(tiles, u, ct_h=ct, ct_w=ct)
    assert rel_err(got, ref.winograd_fused(tiles, u, ct_h=ct, ct_w=ct)) < 1e-4
