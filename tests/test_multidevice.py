"""Multi-device behaviours, exercised via subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count (the main pytest process
stays single-device so smoke tests see 1 device; these spawn fresh
interpreters the way launch/dryrun.py does).

Covers: sharded train step on a real (2,2) mesh; elastic checkpoint restore
across mesh shapes (save on 4-way DP, restore on (2,2) DPxTP); int8
error-feedback pod-mean through a real shard_map collective.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")


def _run(code: str, devices: int = 4, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_on_2x2_mesh():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as cfglib
        from repro.distributed import context as dist, sharding as shd
        from repro.launch.steps import make_train_step
        from repro.models import transformer as tf
        from repro.optim import adamw

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = cfglib.get_smoke_config("qwen2_5_3b")
        with dist.use_mesh(mesh):
            params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
            p_shard = shd.param_shardings(jax.eval_shape(lambda: params),
                                          cfg, mesh)
            params = jax.device_put(params, p_shard)
            opt_cfg = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
            opt = adamw.init_state(params, opt_cfg)
            step = jax.jit(make_train_step(cfg, opt_cfg),
                           in_shardings=(p_shard, None, None),
                           out_shardings=(p_shard, None, None),
                           donate_argnums=(0, 1))
            rng = np.random.default_rng(0)
            batch = {"tokens": rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32),
                     "labels": rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)}
            params, opt, metrics = step(params, opt, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss) and loss > 0, loss
            # params really are distributed
            n_shards = len(jax.tree.leaves(params)[1].addressable_shards)
            print("OK", loss, n_shards)
    """)
    assert "OK" in stdout


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint written under a 4-way DP mesh restores onto a (2,2)
    DP x TP mesh (the pod-count-change scenario)."""
    stdout = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as cfglib
        from repro.checkpoint.manager import CheckpointManager
        from repro.distributed import sharding as shd
        from repro.models import transformer as tf

        cfg = cfglib.get_smoke_config("qwen2_5_3b")
        params = tf.init_params(jax.random.key(7), cfg, jnp.float32)

        # save under 4-way data-parallel
        mesh_a = jax.make_mesh((4, 1), ("data", "model"))
        sh_a = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh_a)
        placed = jax.device_put(params, sh_a)
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(3, placed, blocking=True)

        # restore under 2x2 (mesh shape changed: elastic)
        mesh_b = jax.make_mesh((2, 2), ("data", "model"))
        sh_b = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh_b)
        like = jax.eval_shape(lambda: params)
        restored = mgr.restore(3, like, sh_b)
        ok = jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.allclose(a, jax.device_get(b))),
            params, restored))
        assert ok
        print("OK elastic")
    """)
    assert "OK elastic" in stdout


def test_pod_mean_int8_wire():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import shard_map
        from repro.optim import compression as comp

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        per_pod = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
        errs = jnp.zeros((4, 64))

        def body(g, e):
            return comp.pod_mean_int8(g[0], e[0], "pod")

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P("pod"), P("pod")),
                               out_specs=(P(), P("pod")),
                               check_replication=False))
        mean, new_err = fn(per_pod, errs)
        want = np.asarray(per_pod).mean(axis=0)
        err = np.max(np.abs(np.asarray(mean) - want))
        assert err < 0.02 * np.max(np.abs(want)) + 1e-3, err
        print("OK int8", err)
    """)
    assert "OK int8" in stdout
