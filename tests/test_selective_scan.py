"""Selective-scan Pallas kernel vs the sequential oracle, plus agreement
with the pure-JAX chunked formulation used by the XLA path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.selective_scan import selective_scan
from repro.models.mamba import _chunked_selective_scan

from conftest import rel_err


def _inputs(rng, b, l, d, n, dtype=jnp.float32):
    dt = jnp.asarray(0.001 + 0.1 * rng.random((b, l, d)), dtype)
    xs = jnp.asarray(rng.standard_normal((b, l, d)), dtype)
    bmat = jnp.asarray(rng.standard_normal((b, l, n)), dtype)
    cmat = jnp.asarray(rng.standard_normal((b, l, n)), dtype)
    a_mat = -jnp.exp(jnp.asarray(rng.standard_normal((d, n)), jnp.float32))
    return dt, xs, bmat, cmat, a_mat


@pytest.mark.parametrize("b,l,d,n,chunk,bd", [
    (1, 64, 128, 16, 16, 128),
    (2, 128, 256, 16, 32, 128),
    (2, 64, 128, 8, 64, 64),      # single L step
    (1, 96, 128, 4, 32, 128),
])
def test_kernel_vs_sequential_oracle(rng, b, l, d, n, chunk, bd):
    dt, xs, bmat, cmat, a_mat = _inputs(rng, b, l, d, n)
    y, h = selective_scan(dt, xs, bmat, cmat, a_mat, chunk=chunk,
                          block_d=bd, interpret=True)
    y_ref, h_ref = ref.selective_scan(dt, xs, bmat, cmat, a_mat)
    assert y.shape == (b, l, d) and h.shape == (b, d, n)
    assert rel_err(y, y_ref) < 1e-5
    assert rel_err(h, h_ref) < 1e-5


def test_kernel_vs_oracle_bf16_inputs(rng):
    dt, xs, bmat, cmat, a_mat = _inputs(rng, 2, 64, 128, 16, jnp.bfloat16)
    y, h = selective_scan(dt, xs, bmat, cmat, a_mat, chunk=32,
                          interpret=True, block_d=128)
    y_ref, h_ref = ref.selective_scan(dt, xs, bmat, cmat, a_mat)
    assert rel_err(y, y_ref) < 3e-2
    assert rel_err(h, h_ref) < 3e-2


def test_chunked_xla_path_vs_oracle(rng):
    """The pure-JAX formulation the models actually run must agree with the
    same oracle the kernel is held to."""
    dt, xs, bmat, cmat, a_mat = _inputs(rng, 2, 128, 64, 16)
    y, h = _chunked_selective_scan(dt, xs, bmat, cmat, a_mat, chunk=32)
    y_ref, h_ref = ref.selective_scan(dt, xs, bmat, cmat, a_mat)
    assert rel_err(y, y_ref) < 1e-5
    assert rel_err(h, h_ref) < 1e-5


def test_chunk_size_invariance(rng):
    """Chunking is an implementation detail: results identical across sizes."""
    dt, xs, bmat, cmat, a_mat = _inputs(rng, 1, 128, 64, 8)
    outs = [_chunked_selective_scan(dt, xs, bmat, cmat, a_mat, chunk=c)[0]
            for c in (16, 64, 128)]
    for o in outs[1:]:
        assert rel_err(o, outs[0]) < 1e-5


def test_state_carry_across_chunks(rng):
    """Running two half-sequences with carried state == one full sequence
    (the prefill->decode handoff invariant at kernel level)."""
    dt, xs, bmat, cmat, a_mat = _inputs(rng, 1, 64, 64, 8)
    y_full, h_full = ref.selective_scan(dt, xs, bmat, cmat, a_mat)
    y1, h1 = ref.selective_scan(dt[:, :32], xs[:, :32], bmat[:, :32],
                                cmat[:, :32], a_mat)
    # continue from h1 manually via the sequential recurrence
    f32 = jnp.float32

    def step(h, inputs):
        dti, xi, bi, ci = inputs
        a_bar = jnp.exp(dti[..., None] * a_mat[None])
        h = a_bar * h + (dti * xi)[..., None] * bi[:, None, :]
        return h, jnp.einsum("bds,bs->bd", h, ci)

    h2, ys2 = jax.lax.scan(
        step, h1, (dt[:, 32:].transpose(1, 0, 2).astype(f32),
                   xs[:, 32:].transpose(1, 0, 2).astype(f32),
                   bmat[:, 32:].transpose(1, 0, 2).astype(f32),
                   cmat[:, 32:].transpose(1, 0, 2).astype(f32)))
    assert rel_err(ys2.transpose(1, 0, 2), y_full[:, 32:]) < 1e-5
    assert rel_err(h2, h_full) < 1e-5
