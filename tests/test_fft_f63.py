"""PR 6 executors and policy: the rfft2 (FFT) and large-tile F(6,3)
executors vs the lax oracle, the F(6,3) fp32 error budget on adversarial
filters, and the N-way measured auto_tuned race (evidence keys, decision
provenance, measured/fallback counters)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft as fftlib
from repro.core import plan as planlib
from repro.core import registry
from repro.core.transforms import (F63_FP32_ERROR_BUDGET, cook_toom,
                                   scaled_cook_toom)
from repro.kernels import ops

from conftest import rel_err

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


def lax_conv(x, w, padding="SAME", stride=(1, 1)):
    return jax.lax.conv_general_dilated(
        x, w, stride, padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# transform construction
# ---------------------------------------------------------------------------

def test_scaled_cook_toom_preserves_bilinear_identity():
    """Row scaling compensates exactly: scaled and unscaled F(6,3) compute
    the same correlation in float64."""
    base, sc = cook_toom(6, 3), scaled_cook_toom(6, 3)
    rng = np.random.default_rng(0)
    d, g = rng.standard_normal(base.t), rng.standard_normal(3)
    want = base.AT @ ((base.G @ g) * (base.BT @ d))
    got = sc.AT @ ((sc.G @ g) * (sc.BT @ d))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_scaled_cook_toom_equalizes_bt_row_magnitudes():
    """Every scaled B^T row has max-abs in [1/sqrt(2), sqrt(2)) -- the
    power-of-two scale nearest the original row max."""
    sc = scaled_cook_toom(6, 3)
    for row in sc.BT:
        amax = np.max(np.abs(row))
        assert 2 ** -0.5 <= amax < 2 ** 0.5 + 1e-12


def test_fft_geometry_round_trips_through_output_tile():
    """Artifact reload rebuilds the identical FFTGeometry from the output
    tile alone (fft = m + k - 1 lands back on the same power of two)."""
    for h, w, k in [(14, 14, 3), (56, 56, 3), (28, 20, 5), (17, 13, 7)]:
        g = fftlib.choose_fft_geometry(h, w, k, k)
        assert g.fft_h in fftlib.FFT_SIZES and g.fft_w in fftlib.FFT_SIZES
        re = fftlib.choose_fft_geometry(h, w, k, k,
                                        output_tile=(g.m_h, g.m_w))
        assert re == g


# ---------------------------------------------------------------------------
# parity vs the lax oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w", [(7, 7), (13, 9), (21, 17), (33, 33)])
@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_fft_matches_lax_odd_sizes(rng, h, w, k, padding):
    if padding == "VALID" and (h < k or w < k):
        return
    x = jnp.asarray(rng.standard_normal((2, h, w, 5)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, 5, 7)) / k, jnp.float32)
    got = planlib.plan_conv2d(x.shape, wt, algorithm="fft",
                              padding=padding)(x)
    want = lax_conv(x, wt, padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-5


@pytest.mark.parametrize("h,w", [(7, 7), (13, 9), (21, 17), (33, 33)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_f63_matches_lax_odd_sizes(rng, h, w, padding):
    x = jnp.asarray(rng.standard_normal((2, h, w, 5)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 5, 7)) / 3, jnp.float32)
    p = planlib.plan_conv2d(x.shape, wt, algorithm="winograd_f63",
                            padding=padding)
    assert p.spec.output_tile == (6, 6)
    got = p(x)
    want = lax_conv(x, wt, padding)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


@pytest.mark.parametrize("alg", ["fft", "winograd_f63"])
@pytest.mark.parametrize("activation", ["relu", "gelu", "relu6"])
def test_new_executors_fuse_bias_and_activation(rng, alg, activation):
    x = jnp.asarray(rng.standard_normal((1, 15, 11, 4)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 4, 6)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    got = planlib.plan_conv2d(x.shape, wt, algorithm=alg)(
        x, bias=b, activation=activation)
    want = lax_conv(x, wt) + b
    want = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
            "relu6": lambda v: jnp.clip(v, 0, 6)}[activation](want)
    assert rel_err(got, want) < 1e-4


@pytest.mark.parametrize("fn", [ops.fft_conv2d, ops.winograd_f63_conv2d])
def test_unplanned_ops_wrappers_match_lax(rng, fn):
    x = jnp.asarray(rng.standard_normal((1, 19, 14, 3)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 3, 5)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(5), jnp.float32)
    got = fn(x, wt, bias=b, activation="relu")
    want = jax.nn.relu(lax_conv(x, wt) + b)
    assert rel_err(got, want) < 1e-4


def test_f63_ops_wrapper_rejects_non_3x3(rng):
    x = jnp.zeros((1, 8, 8, 2), jnp.float32)
    wt = jnp.zeros((5, 5, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="3x3"):
        ops.winograd_f63_conv2d(x, wt)


if _HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(5, 24).filter(lambda v: v % 2 == 1),
           w=st.integers(5, 24).filter(lambda v: v % 2 == 1),
           c=st.integers(1, 6), mo=st.integers(1, 6),
           k=st.sampled_from([3, 5]),
           padding=st.sampled_from(["SAME", "VALID"]),
           seed=st.integers(0, 2**31 - 1))
    def test_fft_property_sweep(h, w, c, mo, k, padding, seed):
        if padding == "VALID" and (h < k or w < k):
            return
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((k, k, c, mo)) / k, jnp.float32)
        got = ops.fft_conv2d(x, wt, padding=padding)
        assert rel_err(got, lax_conv(x, wt, padding)) < 1e-5

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(5, 24).filter(lambda v: v % 2 == 1),
           w=st.integers(5, 24).filter(lambda v: v % 2 == 1),
           c=st.integers(1, 6), mo=st.integers(1, 6),
           padding=st.sampled_from(["SAME", "VALID"]),
           seed=st.integers(0, 2**31 - 1))
    def test_f63_property_sweep(h, w, c, mo, padding, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, c, mo)) / 3, jnp.float32)
        got = ops.winograd_f63_conv2d(x, wt, padding=padding)
        assert rel_err(got, lax_conv(x, wt, padding)) < 1e-4


# ---------------------------------------------------------------------------
# F(6,3) fp32 error budget on adversarial filters
# ---------------------------------------------------------------------------

def _direct_conv_f64(x, w):
    """float64 SAME-padding direct conv oracle (numpy)."""
    n, h, wd, c = x.shape
    kh, kw, _, m = w.shape
    xp = np.pad(x.astype(np.float64),
                ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    y = np.zeros((n, h, wd, m))
    for i in range(kh):
        for j in range(kw):
            y += np.einsum("nhwc,cm->nhwm",
                           xp[:, i:i + h, j:j + wd, :],
                           w[i, j].astype(np.float64))
    return y


def test_f63_fp32_error_budget_on_adversarial_filters(rng):
    """The scaled F(6,3) executor holds the declared fp32 budget on filters
    with large magnitude and high dynamic range -- the inputs that stress
    the wide-range B^T rows of large-tile Cook-Toom variants."""
    x = jnp.asarray(rng.standard_normal((1, 24, 24, 8)), jnp.float32)
    w = rng.standard_normal((3, 3, 8, 8))
    w *= 10.0 ** rng.uniform(0, 3, size=w.shape)    # magnitudes 1..1000
    wt = jnp.asarray(w, jnp.float32)
    got = np.asarray(planlib.plan_conv2d(x.shape, wt,
                                         algorithm="winograd_f63")(x))
    want = _direct_conv_f64(np.asarray(x), np.asarray(wt))
    err = np.max(np.abs(got - want)) / np.max(np.abs(want))
    assert err < F63_FP32_ERROR_BUDGET, err


# ---------------------------------------------------------------------------
# N-way measured auto_tuned race
# ---------------------------------------------------------------------------

def test_registry_declares_the_new_families():
    for fam in ("winograd_f63", "fft"):
        assert fam in registry.FAMILIES
        q = registry.as_query(3, 3, (1, 1), c_in=8, c_out=8)
        assert registry.supported(fam, q)
        # dense stride-1 only
        assert not registry.supported(fam, registry.as_query(3, 3, (2, 2)))
        assert not registry.supported(
            fam, registry.as_query(3, 3, (1, 1), groups=8, c_in=8, c_out=8))


def test_auto_tuned_races_all_eligible_contenders(rng):
    x_shape = (1, 18, 18, 8)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    p = planlib.plan_conv2d(x_shape, wt, algorithm="auto_tuned")
    report = p.spec.autotune_report
    assert report is not None
    for key in ("t_winograd_s", "t_winograd_f2_s", "t_f63_s", "t_fft_s",
                "t_im2col_s"):
        assert report[key] > 0, key
    assert report["winner"] == p.spec.algorithm
    label_times = {k: v for k, v in report.items() if k.startswith("t_")}
    assert report[f"t_{report['winner_label']}_s"] == min(label_times.values())
    assert p.describe()["decision"] == "measured"


def test_auto_tuned_five_filter_race_skips_f63(rng):
    """5x5 layers have no F(6,3) contender (filter_sizes={3}) but do race
    the FFT executor."""
    x_shape = (1, 16, 16, 4)
    wt = jnp.asarray(rng.standard_normal((5, 5, 4, 4)) / 5, jnp.float32)
    p = planlib.plan_conv2d(x_shape, wt, algorithm="auto_tuned")
    report = p.spec.autotune_report
    assert "t_f63_s" not in report
    assert report["t_fft_s"] > 0


def test_measured_and_fallback_counters(rng):
    x_shape = (1, 12, 12, 4)
    wt = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    base = planlib.plan_cache_info()
    assert base["measured"] == 0 and base["fallback"] == 0
    planlib.plan_conv2d(x_shape, wt, algorithm="auto_tuned")
    assert planlib.plan_cache_info()["measured"] == 1

    traced_shape = (1, 14, 14, 4)    # not in the spec cache yet

    @jax.jit
    def fwd(x, w):
        return planlib.plan_conv2d(traced_shape, w, algorithm="auto_tuned")(x)

    fwd(jnp.zeros(traced_shape, jnp.float32),
        jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32))
    info = planlib.plan_cache_info()
    assert info["fallback"] >= 1     # planning under trace cannot measure
    assert info["measured"] == 1     # ...and did not re-measure
    planlib.clear_plan_cache()
    info = planlib.plan_cache_info()
    assert info["measured"] == 0 and info["fallback"] == 0


def test_static_algorithms_report_static_decision(rng):
    wt = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    for alg in ("winograd", "fft", "winograd_f63", "im2col"):
        p = planlib.plan_conv2d((1, 12, 12, 4), wt, algorithm=alg)
        assert p.describe()["decision"] == "static"
        assert planlib.plan_cache_info()["measured"] == 0


def test_auto_tuned_winner_tile_rebuilds_from_artifact(rng, tmp_path,
                                                       monkeypatch):
    """A measured plan round-trips through the ConvPlan artifact hooks with
    the winner, its tile and the evidence intact, and without re-running
    the filter transform or any measurement."""
    x_shape = (1, 18, 18, 8)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    p = planlib.plan_conv2d(x_shape, wt, algorithm="auto_tuned")
    meta, arrays = p.to_artifact()
    want = p(x)

    def boom(*a, **k):
        raise AssertionError("warm load must not measure or re-transform")

    monkeypatch.setattr(planlib, "_measure_autotune", boom)
    monkeypatch.setattr(planlib, "_bind_weights", boom)
    p2 = planlib.ConvPlan.from_artifact(meta, arrays)
    assert p2.spec.algorithm == p.spec.algorithm
    assert p2.spec.output_tile == p.spec.output_tile
    assert p2.spec.autotune_report == p.spec.autotune_report
    assert p2.describe()["decision"] == "measured"
    np.testing.assert_array_equal(np.asarray(p2(x)), np.asarray(want))
