"""Observability subsystem (repro.obs): span tracing + chrome export,
atomic metrics, the serve-path profiler's per-request decomposition,
provably-zero disabled overhead, the artifact-audit CLI, the BENCH
regression gate, and the fleet tuning database that lets a fresh process
adopt measured auto_tuned placements without re-measuring."""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile as C
from repro.core import plan
from repro.models import cnn
from repro.obs import metrics, profile, regress, trace, tuningdb
from repro.runtime import inject
from repro.runtime import serve as serve_mod
from repro.runtime.serve import ServeConfig, Server

RES = 16
SPECS = [cnn.Conv("c1", 3, 3, 8), cnn.Conv("c2", 3, 3, 8, relu=False)]


@pytest.fixture(autouse=True)
def _obs_clean_slate():
    """Global observability state (tracer, profiler, default metrics,
    tuning DB) must not leak between tests."""
    profile.disable()
    metrics.reset()
    tuningdb.clear()
    yield
    profile.disable()
    metrics.reset()
    tuningdb.clear()


@pytest.fixture
def params():
    return cnn.init_cnn(jax.random.key(0), SPECS, 3, res=RES)


@pytest.fixture
def xs(rng):
    return [rng.standard_normal((RES, RES, 3)).astype(np.float32)
            for _ in range(4)]


def make_cfg(**kw):
    base = dict(buckets=(1, 2), queue_capacity=16, verbose=False,
                jit_dispatch=False, backoff_base_s=0.002,
                backoff_cap_s=0.01)
    base.update(kw)
    return ServeConfig(**base)


def serve_n(srv, xs, n):
    tickets = []
    for i in range(n):
        t = srv.submit(xs[i % len(xs)])
        t.result(timeout=60)
        tickets.append(t)
    return tickets


# ---------------------------------------------------------------------------
# trace: ring buffer, nesting, chrome export
# ---------------------------------------------------------------------------

def test_tracer_ring_capacity_and_dropped():
    tr = trace.Tracer(capacity=4)
    for i in range(10):
        tr.add_span(f"s{i}", float(i), float(i) + 0.5)
    assert len(tr) == 4
    assert tr.dropped == 6
    # oldest dropped first: only s6..s9 survive
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_span_nesting_depth_and_error_capture():
    tr = trace.Tracer()
    with tr.span("outer"):
        with tr.span("inner") as sp:
            sp.set(detail=7)
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["outer"].depth == 0
    assert by_name["inner"].depth == 1
    assert by_name["inner"].args["detail"] == 7
    assert "ValueError" in by_name["boom"].args["error"]
    # depth unwound: a fresh span is top-level again
    with tr.span("later"):
        pass
    assert {s.name: s.depth for s in tr.spans()}["later"] == 0


def test_chrome_export_is_valid_and_rebased(tmp_path):
    tr = trace.Tracer()
    with tr.span("a"):
        time.sleep(0.001)
    tr.instant("mark", k=1)
    path = str(tmp_path / "trace.json")
    doc = tr.export_chrome(path)
    with open(path) as f:
        assert json.load(f) == doc          # file round-trips
    events = doc["traceEvents"]
    assert events[0]["ph"] == "M"           # process-name metadata
    xs = [e for e in events if e["ph"] == "X"]
    ins = [e for e in events if e["ph"] == "i"]
    assert len(xs) == 1 and len(ins) == 1
    assert xs[0]["dur"] > 0
    assert all(e["ts"] >= 0 for e in xs + ins)   # rebased to first span
    assert min(e["ts"] for e in xs + ins) == 0
    assert doc["otherData"]["dropped_spans"] == 0


def test_disabled_module_api_is_noop():
    trace.disable()
    assert trace.span("x") is trace.NULL_SPAN
    trace.add_span("x", 0.0, 1.0)            # no-ops, no error
    trace.instant("x")
    assert trace.get() is None and not trace.is_enabled()
    with pytest.raises(RuntimeError, match="disabled"):
        trace.export_chrome()
    tr = trace.enable(capacity=8)
    assert trace.enable() is tr              # enable() reuses the tracer
    trace.disable()


# ---------------------------------------------------------------------------
# metrics: histogram semantics + atomic snapshots
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_bound():
    reg = metrics.MetricsRegistry("t")
    h = reg.histogram("lat")
    samples = [0.001 * (i + 1) for i in range(100)]
    for s in samples:
        h.record(s)
    true_p50 = float(np.percentile(samples, 50))
    assert true_p50 <= h.percentile(0.5) <= 2 * true_p50
    assert h.percentile(0.99) <= h.max
    st = h.state()
    assert st["count"] == 100
    assert st["min"] == samples[0] and st["max"] == samples[-1]
    assert sum(st["buckets"].values()) == 100
    h.record(0.0)                            # underflow bucket
    assert h.state()["buckets"]["underflow"] == 1


def test_metrics_snapshot_is_atomic_under_hammer():
    """Two counters incremented together under the registry lock must
    never be observed torn by snapshot()."""
    reg = metrics.MetricsRegistry("t")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            with reg.lock:
                reg.count("a")
                reg.count("b")

    threads = [threading.Thread(target=writer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            snap = reg.snapshot()["counters"]
            assert snap.get("a", 0) == snap.get("b", 0), snap
    finally:
        stop.set()
        for t in threads:
            t.join()


def test_snapshot_all_merges_live_server_registries(params):
    srv = Server(params, SPECS, res=RES, config=make_cfg())
    try:
        merged = metrics.snapshot_all()
        assert "default" in merged
        serve_regs = [k for k in merged if k.startswith("serve")]
        assert serve_regs, merged.keys()
        assert "serve.admitted" in merged[serve_regs[0]]["counters"]
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# ServerStats: atomic snapshot under concurrent traffic (satellite 1)
# ---------------------------------------------------------------------------

def test_stats_snapshot_race_stress(params, xs):
    """Hammer snapshot()/in_flight from reader threads while traffic runs:
    no RuntimeError (dict resized during iteration), and every cut is
    internally consistent (in_flight identity holds, never negative)."""
    errors: list[BaseException] = []
    snaps: list[dict] = []
    stop = threading.Event()

    with Server(params, SPECS, res=RES, config=make_cfg()) as srv:
        def reader():
            try:
                while not stop.is_set():
                    s = srv.stats.snapshot()
                    assert s["in_flight"] == (
                        s["admitted"] - s["completed"] - s["timed_out"]
                        - s["cancelled"] - s["failed"])
                    assert s["in_flight"] >= 0, s
                    assert srv.stats.in_flight >= 0
                    snaps.append(s)
            except BaseException as e:      # noqa: BLE001 - reraised below
                errors.append(e)

        readers = [threading.Thread(target=reader) for _ in range(3)]
        for t in readers:
            t.start()
        try:
            serve_n(srv, xs, 24)
        finally:
            stop.set()
            for t in readers:
                t.join()
    assert not errors, errors[0]
    assert len(snaps) > 50
    final = srv.stats.snapshot()
    assert final["completed"] == 24 and final["in_flight"] == 0
    # the attribute views and the snapshot tell one story
    assert srv.stats.completed == 24
    assert sum(final["bucket_batches"].values()) == final["batches"]


# ---------------------------------------------------------------------------
# profiler: disabled-path zero overhead (satellite 4)
# ---------------------------------------------------------------------------

def test_serve_disabled_emits_zero_spans(params, xs):
    """Tracer installed but profiler off: the serve dispatch path records
    NOTHING (the hot path's only obs cost is one `active()` read)."""
    with Server(params, SPECS, res=RES, config=make_cfg()) as srv:
        tr = trace.enable()                  # after compile, before traffic
        tr.clear()
        serve_n(srv, xs, 6)
        assert trace.get().spans() == []
    trace.disable()


def test_profiler_leaves_jitted_computation_unchanged(params):
    """jaxpr-level proof: enabling the profiler does not change what the
    jitted network computes -- instrumentation lives outside the trace."""
    import re

    def jaxpr_of(fn, x):
        # object reprs embed memory addresses that differ between any two
        # traces; strip them so the compare is structural
        return re.sub(r"0x[0-9a-f]+", "0x", str(jax.make_jaxpr(fn)(x)))

    net = C.compile(params, SPECS, res=RES, batch=1, algorithm="winograd")
    x = jnp.zeros((1, RES, RES, 3), jnp.float32)
    before = jaxpr_of(net.apply, x)
    profile.enable()
    after = jaxpr_of(net.apply, x)
    profile.disable()
    assert before == after


def test_serve_overhead_p50_under_5pct(params, xs):
    """Enabled-profiler p50 latency inflation < 5% on a serving smoke,
    measured interleaved so drift hits both arms."""
    lat = {"off": [], "on": []}
    with Server(params, SPECS, res=RES, config=make_cfg()) as srv:
        serve_n(srv, xs, 6)                  # warm both paths
        profile.enable()
        serve_n(srv, xs, 2)
        profile.disable()
        for _ in range(8):
            lat["off"] += [t.latency_s for t in serve_n(srv, xs, 3)]
            profile.enable()
            lat["on"] += [t.latency_s for t in serve_n(srv, xs, 3)]
            profile.disable()
    p50_off = float(np.percentile(lat["off"], 50))
    p50_on = float(np.percentile(lat["on"], 50))
    assert p50_on < p50_off * 1.05, (p50_off, p50_on)


# ---------------------------------------------------------------------------
# profiler: per-request decomposition + per-layer attribution
# ---------------------------------------------------------------------------

def _spans_by_rid(tracer):
    out: dict[int, dict[str, trace.Span]] = {}
    for s in tracer.spans():
        rid = s.args.get("rid")
        if rid is not None:
            out.setdefault(rid, {})[s.name] = s
    return out


def test_decomposition_sums_to_measured_latency(params, xs):
    """queue_wait + batch_formation + dispatch + respond tile
    [submit, finish]: per request the spans sum to the independently
    measured ticket latency."""
    with Server(params, SPECS, res=RES, config=make_cfg()) as srv:
        serve_n(srv, xs, 2)
        profile.enable()
        tickets = serve_n(srv, xs, 6)
        tr = trace.get()
        by_rid = _spans_by_rid(tr)
        dispatches = tr.spans("serve.dispatch")
    for t in tickets:
        parts = by_rid[t.rid]
        qw = parts["serve.queue_wait"]
        bf = parts["serve.batch_formation"]
        rp = parts["serve.respond"]
        d = next(d for d in dispatches
                 if abs(d.t0 - bf.t1) < 1e-9)       # its batch's dispatch
        total = (qw.duration_s + bf.duration_s + d.duration_s
                 + rp.duration_s)
        assert abs(total - t.latency_s) <= 1e-6 + 1e-3 * t.latency_s, \
            (total, t.latency_s)
        # the boundaries are shared stamps, not re-measured
        assert qw.t0 == t.submitted_at and rp.t1 == t.finished_at
    profile.disable()


def test_layer_spans_match_plan_node_ids_mbv2():
    """Satellite 3: on MobileNet-v2, the layer:<nid> spans of one request
    name exactly the planned nodes, in execution order, tagged with each
    plan's executor -- and after replace_layer the NEXT request's spans
    show the new executor."""
    res = 32
    specs = cnn.NETWORKS["mobilenet_v2"][0]()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = np.zeros((res, res, 3), np.float32)
    with Server(params, specs, res=res, algorithm="auto",
                config=make_cfg(buckets=(1,))) as srv:
        net = srv.nets[1]
        want = [n.id for n in net.graph if n.id in net.plans]
        table = net.describe()
        profile.enable()
        srv.submit(x).result(timeout=120)
        got = [s.name.removeprefix("layer:")
               for s in trace.get().spans("layer:")]
        assert got == want
        for s in trace.get().spans("layer:"):
            nid = s.name.removeprefix("layer:")
            assert nid in table
            assert s.args["executor"] == \
                net.plans[nid].describe()["executor"]

        # evict the stem conv onto the fallback; spans must follow
        old = net.plans["conv1"].describe()["executor"]
        assert srv._replace_layer("conv1", reason="test")
        new = net.plans["conv1"].describe()["executor"]
        assert new != old
        trace.get().clear()
        srv.submit(x).result(timeout=120)
        stem = [s for s in trace.get().spans("layer:conv1")]
        assert stem and stem[0].args["executor"] == new
    profile.disable()


def test_compile_and_autotune_spans(params):
    """compile() phases and the measured autotune race land in the trace."""
    trace.enable()
    trace.get().clear()
    C.compile(params, SPECS, res=RES, batch=1, algorithm="auto_tuned")
    names = {s.name for s in trace.get().spans()}
    for phase in ("compile.lower", "compile.fuse", "compile.infer_shapes",
                  "compile.place", "compile.bind"):
        assert phase in names, names
    races = trace.get().spans("plan.autotune.race")
    assert races and "winner" in races[0].args
    trace.disable()


# ---------------------------------------------------------------------------
# verify-artifacts CLI (satellite 2)
# ---------------------------------------------------------------------------

def test_verify_artifacts_cli(params, tmp_path, capsys):
    adir = str(tmp_path / "artifacts")
    with Server(params, SPECS, res=RES, config=make_cfg(),
                artifact_dir=adir):
        pass
    names = sorted(os.listdir(adir))
    assert names == ["plan_b1.npz", "plan_b2.npz"], names

    assert serve_mod.main(["verify-artifacts", adir]) == 0
    out = capsys.readouterr().out
    assert "plan_b1.npz: OK" in out and "all digests verified" in out

    inject.flip_bit(os.path.join(adir, "plan_b2.npz"))
    assert serve_mod.main(["verify-artifacts", adir]) == 1
    out = capsys.readouterr().out
    assert "plan_b2.npz: CORRUPT" in out
    assert "plan_b1.npz: OK" in out
    assert "[CORRUPT" in out                 # the per-array status line

    assert serve_mod.main(["verify-artifacts",
                           str(tmp_path / "nope")]) == 2


# ---------------------------------------------------------------------------
# regression gate (benchmarks/regress.py over repro.obs.regress)
# ---------------------------------------------------------------------------

def _serving_doc(p50=10.0, dropped=0):
    return {"clean": [{"rate_rps": 20, "p50_ms": p50, "p99_ms": 3 * p50,
                       "mean_ms": p50, "throughput_rps": 19.0,
                       "dropped": dropped, "incorrect": 0}],
            "faults": [], "zero_dropped": dropped == 0,
            "zero_incorrect": True, "fault_survived": True}


def test_regress_cli_fails_on_2x_slowdown(tmp_path):
    import benchmarks.regress as cli
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_serving_doc(p50=10.0)))
    cur.write_text(json.dumps(_serving_doc(p50=10.5)))
    assert cli.main([str(base), str(cur)]) == 0      # within threshold
    cur.write_text(json.dumps(_serving_doc(p50=20.0)))
    assert cli.main([str(base), str(cur)]) == 1      # injected 2x
    assert cli.main([str(base), str(cur), "--warn-only"]) == 0
    assert cli.main([str(base), str(cur), "--threshold", "3.0"]) == 0


def test_regress_count_and_bool_gates_zero_tolerance(tmp_path):
    import benchmarks.regress as cli
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_serving_doc(dropped=0)))
    cur.write_text(json.dumps(_serving_doc(dropped=1)))
    assert cli.main([str(base), str(cur)]) == 1      # any drop regresses


def test_regress_observe_format_machine_relative():
    ob = {"format": "repro.observe/v1", "overhead_pct": 1.0,
          "p50_disabled_ms": 100.0,
          "decomposition": {"max_residual_pct": 0.1},
          "gates": {"valid_chrome_trace": True}}
    worse = dict(ob, overhead_pct=9.0, p50_disabled_ms=900.0)
    findings = {f.metric: f for f in regress.compare(ob, worse)}
    assert findings["observe.overhead_pct"].regressed        # +8 points
    # absolute latency is informational: 9x slower machine, no gate
    assert not findings["observe.p50_disabled_ms"].regressed
    ok = dict(ob, overhead_pct=3.0)
    assert not any(f.regressed for f in regress.compare(ob, ok))
    broken = dict(ob, gates={"valid_chrome_trace": False})
    fs = {f.metric: f for f in regress.compare(ob, broken)}
    assert fs["observe.gate.valid_chrome_trace"].regressed


def test_regress_trajectory_pairs_committed_with_ci(tmp_path):
    import benchmarks.regress as cli
    root = tmp_path / "root"
    ci = tmp_path / "ci"
    root.mkdir(), ci.mkdir()
    (root / "BENCH_PR7.json").write_text(json.dumps(_serving_doc(10.0)))
    (ci / "BENCH_PR7_ci_x.json").write_text(
        json.dumps(_serving_doc(40.0)))
    # absolute serving metrics across machines: warn-only -> exit 0
    assert cli.main(["--trajectory", str(ci), "--root", str(root)]) == 0
    # --strict gates them
    assert cli.main(["--trajectory", str(ci), "--root", str(root),
                     "--strict"]) == 1
    # an observe-format pair gates hard without --strict
    ob = {"format": "repro.observe/v1", "overhead_pct": 1.0,
          "gates": {"g": True}, "decomposition": {"max_residual_pct": 0.1}}
    (root / "BENCH_PR10.json").write_text(json.dumps(ob))
    (ci / "BENCH_PR10_ci_y.json").write_text(
        json.dumps(dict(ob, gates={"g": False})))
    assert cli.main(["--trajectory", str(ci), "--root", str(root)]) == 1


# ---------------------------------------------------------------------------
# fleet tuning DB: export -> install -> zero-measurement adoption
# ---------------------------------------------------------------------------

def test_tuningdb_roundtrip_skips_measurement(params):
    net = C.compile(params, SPECS, res=RES, batch=1,
                    algorithm="auto_tuned")
    assert plan.plan_cache_info()["measured"] > 0
    db = tuningdb.export([net])
    assert db["format"] == "repro.tuning_db"
    assert len(db["entries"]) == 2

    plan.clear_plan_cache()
    assert tuningdb.install(db) == 2
    net2 = C.compile(params, SPECS, res=RES, batch=1,
                     algorithm="auto_tuned")
    info = plan.plan_cache_info()
    assert info["measured"] == 0, info       # zero autotune measurements
    assert info["tuningdb_hits"] == 2, info
    for nid in net.plans:
        assert net.plans[nid].describe()["executor"] == \
            net2.plans[nid].describe()["executor"]
    x = jnp.zeros((1, RES, RES, 3), jnp.float32)
    np.testing.assert_allclose(np.asarray(net.apply(x)),
                               np.asarray(net2.apply(x)), atol=1e-5)
    # adopted decisions carry provenance + stay artifact-durable
    meta = net2.plans[next(iter(net2.plans))].describe()
    assert meta["decision"] != "static"


def test_tuningdb_merge_prefers_faster_evidence(params):
    net = C.compile(params, SPECS, res=RES, batch=1,
                    algorithm="auto_tuned")
    db = tuningdb.export([net])
    k, entry = next(iter(db["entries"].items()))
    slower = json.loads(json.dumps(db))
    slower["entries"][k]["winner_time_s"] = entry["winner_time_s"] * 10
    slower["entries"][k]["winner_label"] = "slow_variant"
    merged = tuningdb.merge(db, slower)
    assert merged["entries"][k]["winner_label"] == entry["winner_label"]
    merged2 = tuningdb.merge(slower, db)
    assert merged2["entries"][k]["winner_label"] == entry["winner_label"]


def test_tuningdb_fresh_process_zero_measurements(params, tmp_path):
    """Acceptance: a FRESH process compiling under REPRO_TUNING_DB adopts
    the exported placements with zero autotune measurements."""
    net = C.compile(params, SPECS, res=RES, batch=1,
                    algorithm="auto_tuned")
    db_path = str(tmp_path / "fleet_db.json")
    tuningdb.save(tuningdb.export([net]), db_path)
    placement = {nid: net.plans[nid].describe()["executor"]
                 for nid in net.plans}

    prog = (
        "import json, jax\n"
        "from repro.core import compile as C, plan\n"
        "from repro.models import cnn\n"
        "specs = [cnn.Conv('c1', 3, 3, 8),"
        " cnn.Conv('c2', 3, 3, 8, relu=False)]\n"
        f"params = cnn.init_cnn(jax.random.key(0), specs, 3, res={RES})\n"
        f"net = C.compile(params, specs, res={RES}, batch=1,"
        " algorithm='auto_tuned')\n"
        "info = plan.plan_cache_info()\n"
        "print(json.dumps({'measured': info['measured'],"
        " 'tuningdb_hits': info['tuningdb_hits'],"
        " 'placement': {n: net.plans[n].describe()['executor']"
        " for n in net.plans}}))\n")
    env = dict(os.environ, REPRO_TUNING_DB=db_path,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr
    got = json.loads(proc.stdout.strip().splitlines()[-1])
    assert got["measured"] == 0, got
    assert got["tuningdb_hits"] == 2, got
    assert got["placement"] == placement


def test_tuningdb_rejects_unknown_and_foreign_entries(params):
    """DB entries that don't validate against the live registry fall back
    to a local race instead of poisoning the plan."""
    net = C.compile(params, SPECS, res=RES, batch=1,
                    algorithm="auto_tuned")
    db = tuningdb.export([net])
    for entry in db["entries"].values():
        entry["winner"] = "no_such_executor"
    plan.clear_plan_cache()
    tuningdb.install(db)
    C.compile(params, SPECS, res=RES, batch=1, algorithm="auto_tuned")
    info = plan.plan_cache_info()
    assert info["tuningdb_hits"] == 0
    assert info["measured"] > 0              # raced locally instead
