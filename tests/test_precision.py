"""Mixed-precision transform-domain execution (bf16/int8 Winograd): plan
parity against the fp32 path across every layer kind, per-channel scale
folding under adversarial filter magnitudes, the small-tile accuracy clamp,
dtype-aware planning/validation, the quantized artifact round-trip (bitwise,
zero re-transform AND zero re-quantization on warm load), the enriched
dtype-mismatch refusal, and the precision surfaces of describe()/serve."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as planlib
from repro.core import registry
from repro.core.compile import ArtifactMismatchError, NetworkPlan
from repro.core.compile import compile as compile_network
from repro.core.im2col import direct_conv2d
from repro.models import cnn

from conftest import rel_err

BF16_TOL = 2e-2
INT8_TOL = planlib.AUTOTUNE_ACCURACY_BUDGET["int8"]
TOL = {"bfloat16": BF16_TOL, "int8": INT8_TOL}

# (name, (h, w), w_shape, kwargs) -- dense/depthwise/grouped/strided
# layers under SAME and VALID padding, including an asymmetric (H != W,
# non-tile-aligned) spatial shape.
CASES = [
    ("dense_same", (14, 14), (3, 3, 8, 16), dict()),
    ("dense_valid", (14, 14), (3, 3, 8, 16), dict(padding="VALID")),
    ("dense_asym", (13, 18), (3, 3, 8, 16), dict(padding="VALID")),
    ("depthwise", (14, 14), (3, 3, 1, 8), dict(groups=8)),
    ("grouped", (14, 14), (3, 3, 2, 8), dict(groups=4)),
    ("strided", (14, 14), (3, 3, 8, 16), dict(stride=2)),
]


def _case_arrays(rng, hw, w_shape, kwargs):
    kh, kw, cg, m = w_shape
    c_in = cg * kwargs.get("groups", 1)
    x = jnp.asarray(rng.standard_normal((1, *hw, c_in)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(w_shape) / (kh * kw), jnp.float32)
    return x, wt


# ---------------------------------------------------------------------------
# parity: every layer kind, both reduced dtypes, vs the fp32 plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cd", ["bfloat16", "int8"])
@pytest.mark.parametrize("name,hw,w_shape,kwargs",
                         CASES, ids=[c[0] for c in CASES])
def test_reduced_precision_parity(rng, cd, name, hw, w_shape, kwargs):
    """A bf16/int8 plan agrees with its fp32 twin within the dtype's
    budget on every layer kind and padding mode, with the bias+activation
    epilogue applied AFTER the folded dequantization scale."""
    x, wt = _case_arrays(rng, hw, w_shape, kwargs)
    bias = jnp.asarray(rng.standard_normal((w_shape[3],)), jnp.float32)
    p32 = planlib.plan_conv2d(x.shape, wt, **kwargs)
    p = planlib.plan_conv2d(x.shape, wt, compute_dtype=cd, **kwargs)
    ref = p32.apply(x, bias=bias, activation="relu")
    got = p.apply(x, bias=bias, activation="relu")
    assert got.shape == ref.shape and got.dtype == ref.dtype
    assert rel_err(got, ref) < TOL[cd], (name, cd)
    # and both agree with the direct-conv oracle, not just each other
    oracle = jax.nn.relu(direct_conv2d(
        x, wt, stride=kwargs.get("stride", 1),
        padding=kwargs.get("padding", "SAME"),
        groups=kwargs.get("groups", 1)) + bias)
    assert rel_err(got, oracle) < TOL[cd], (name, cd)
    assert p.describe()["compute_dtype"] == cd


def test_separable_block_composes_reduced(rng):
    """A reduced compute_dtype always composes the separable block (the
    fused kernel is fp32-only) and both halves carry the dtype."""
    x = jnp.asarray(rng.standard_normal((1, 14, 14, 16)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, 16)) / 9, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, 16, 32)), jnp.float32)
    p32 = planlib.plan_separable_block(x.shape, w_dw, w_pw)
    p = planlib.plan_separable_block(x.shape, w_dw, w_pw,
                                     compute_dtype="int8")
    assert p.dw is not None and p.pw is not None      # composed
    assert p.dw.spec.compute_dtype == "int8"
    assert p.pw.spec.compute_dtype == "int8"
    assert p.describe()["compute_dtype"] == "int8"
    assert rel_err(p.apply(x), p32.apply(x)) < INT8_TOL


# ---------------------------------------------------------------------------
# per-channel scales: adversarial filter magnitudes
# ---------------------------------------------------------------------------

def test_int8_per_channel_scale_survives_magnitude_outliers(rng):
    """Adversarial probe: output channels spanning 4 orders of magnitude.
    Per-output-channel symmetric quantization keeps every channel within
    budget; a per-tensor scale would crush the small channels to zero."""
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 8)), jnp.float32)
    wt = rng.standard_normal((3, 3, 8, 16)).astype(np.float32) / 9
    mags = np.logspace(-2, 2, 16).astype(np.float32)
    wt = jnp.asarray(wt * mags)                # channel m scaled by mags[m]
    p32 = planlib.plan_conv2d(x.shape, wt)
    p = planlib.plan_conv2d(x.shape, wt, compute_dtype="int8")
    assert p.scale is not None
    sc = np.asarray(p.scale).reshape(-1)
    assert float(sc.max() / sc.min()) > 100    # genuinely per-channel
    ref, got = np.asarray(p32.apply(x)), np.asarray(p.apply(x))
    # per-channel relative error: every channel within budget, including
    # the 1e-2-magnitude ones a per-tensor scale would zero out
    for c in range(16):
        denom = np.max(np.abs(ref[..., c])) + 1e-9
        assert np.max(np.abs(got[..., c] - ref[..., c])) / denom < INT8_TOL


def test_int8_plan_stores_no_fp32_filter_copy(rng):
    """Jaxpr regression: the int8 plan's hot path closes over the int8
    transformed filter and the O(M) fp32 scale row -- NOT an fp32 copy of
    the transformed-filter tensor (that would double the HBM traffic the
    quantization exists to remove)."""
    x = jnp.asarray(rng.standard_normal((1, 14, 14, 16)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 16, 32)) / 9, jnp.float32)
    p = planlib.plan_conv2d(x.shape, wt, algorithm="winograd",
                            compute_dtype="int8")
    assert p.u.dtype == jnp.int8
    jx = jax.make_jaxpr(lambda v: p.apply(v))(x)
    sizes = {}
    for const in jx.consts:
        dt = getattr(const, "dtype", None)
        if dt is not None:
            sizes.setdefault(str(dt), []).append(int(np.prod(const.shape)))
    assert p.u.size in sizes.get("int8", [])
    big_fp32 = [s for s in sizes.get("float32", []) if s >= p.u.size]
    assert not big_fp32, sizes


# ---------------------------------------------------------------------------
# accuracy-driven planning: small-tile clamp + dtype validation
# ---------------------------------------------------------------------------

def test_reduced_precision_clamps_to_small_tile(rng):
    """Winograd quantization-noise amplification grows steeply with tile
    size (F(4,3) amplifies int8 weight-quantization error ~350x vs F(2,3)),
    so un-pinned reduced-precision plans clamp to the 2x2 output tile."""
    x = jnp.asarray(rng.standard_normal((1, 28, 28, 32)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 32, 32)) / 9, jnp.float32)
    p32 = planlib.plan_conv2d(x.shape, wt, algorithm="winograd")
    p8 = planlib.plan_conv2d(x.shape, wt, algorithm="winograd",
                             compute_dtype="int8")
    assert p32.spec.output_tile == (4, 4)
    assert p8.spec.output_tile == (2, 2)
    # an explicit pin still wins -- the clamp is a default, not a cage
    p8_pin = planlib.plan_conv2d(x.shape, wt, algorithm="winograd",
                                 compute_dtype="int8", output_tile=4)
    assert p8_pin.spec.output_tile == (4, 4)


def test_fp32_only_executors_reject_reduced_dtypes(rng):
    """fft and winograd_f63 are fp32-only in the registry; pinning them
    with a reduced dtype is a plan-time error enumerating what IS
    supported, not a silent fp32 fallback."""
    x = jnp.asarray(rng.standard_normal((1, 16, 16, 8)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 9, jnp.float32)
    for alg in ("fft", "winograd_f63"):
        with pytest.raises(ValueError, match="float32"):
            planlib.plan_conv2d(x.shape, wt, algorithm=alg,
                                compute_dtype="int8")
    assert registry.compute_dtypes_for("fft") == ("float32",)
    assert registry.compute_dtypes_for("winograd_f63") == ("float32",)


def test_compute_dtype_is_part_of_the_cache_key(rng):
    """The same shape planned at two dtypes yields two distinct cached
    specs -- a dtype change must never serve the other dtype's plan."""
    planlib.clear_plan_cache()
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 8)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 9, jnp.float32)
    planlib.plan_conv2d(x.shape, wt)
    planlib.plan_conv2d(x.shape, wt, compute_dtype="int8")
    info = planlib.plan_cache_info()
    assert info["misses"] == 2 and info["hits"] == 0
    assert info["quantized"] == 1
    p_again = planlib.plan_conv2d(x.shape, wt, compute_dtype="int8")
    assert planlib.plan_cache_info()["hits"] == 1
    assert p_again.spec.compute_dtype == "int8"


def test_autotune_race_gates_reduced_dtypes_on_accuracy(rng):
    """compute_dtype="auto" admits bf16/int8 variants only with accuracy
    evidence: the report carries err_* probes next to the t_* timings,
    and a crowned reduced winner is within its dtype's budget."""
    x = jnp.asarray(rng.standard_normal((1, 28, 28, 64)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) / 9, jnp.float32)
    p = planlib.plan_conv2d(x.shape, wt, algorithm="auto_tuned",
                            compute_dtype="auto")
    report = p.spec.autotune_report
    assert report and report.get("winner_dtype") is not None
    errs = {k: v for k, v in report.items() if k.startswith("err_")}
    assert errs, report                       # accuracy evidence recorded
    wd = report["winner_dtype"]
    if wd != "float32":
        lbl = report["winner_label"]
        assert errs[f"err_{lbl}"] <= planlib.AUTOTUNE_ACCURACY_BUDGET[wd]


def test_default_auto_tuned_race_never_lowers_precision(rng):
    """Without the compute_dtype="auto" opt-in the measured race fields no
    reduced contenders: the plan stays fp32 (default auto_tuned numerics
    are unchanged by this feature) and no err_* probes are recorded."""
    x = jnp.asarray(rng.standard_normal((1, 28, 28, 64)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) / 9, jnp.float32)
    p = planlib.plan_conv2d(x.shape, wt, algorithm="auto_tuned")
    assert p.spec.compute_dtype == "float32"
    report = p.spec.autotune_report or {}
    assert not any(k.startswith("err_") for k in report)
    assert not any(k in ("t_winograd_bf16_s", "t_winograd_int8_s")
                   for k in report)
    with pytest.raises(ValueError, match="auto_tuned"):
        planlib.plan_conv2d(x.shape, wt, algorithm="winograd",
                            compute_dtype="auto")


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                    # pragma: no cover - CI installs it
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        h=st.integers(8, 25), w=st.integers(8, 25),
        c=st.integers(1, 12), m=st.integers(1, 12),
        padding=st.sampled_from(["SAME", "VALID"]),
        cd=st.sampled_from(["bfloat16", "int8"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_reduced_precision_property(h, w, c, m, padding, cd, seed):
        """Property sweep: arbitrary (H, W, C, M, padding) reduced plans
        stay within their dtype budget vs the fp32 plan."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, c, m)) / 9,
                         jnp.float32)
        p32 = planlib.plan_conv2d(x.shape, wt, padding=padding)
        p = planlib.plan_conv2d(x.shape, wt, padding=padding,
                                compute_dtype=cd)
        assert rel_err(p.apply(x), p32.apply(x)) < TOL[cd]


# ---------------------------------------------------------------------------
# artifacts: quantized round-trip, warm-load counters, mismatch refusal
# ---------------------------------------------------------------------------

def _mbv2(res=32, key=0):
    specs = cnn.NETWORKS["mobilenet_v2"][0]()
    params = cnn.init_cnn(jax.random.key(key), specs, 3, res=res)
    return specs, params


def test_quantized_artifact_roundtrips_bitwise(rng, tmp_path):
    """An int8-policy MobileNet-v2 artifact persists the quantized filters
    AND their dequantization scales, reloads bitwise, and re-saves to an
    identical payload."""
    specs, params = _mbv2()
    net = compile_network(params, specs, res=32, compute_dtype="int8")
    assert net.compute_dtype == "int8"
    path = str(tmp_path / "net_int8.npz")
    net.save(path)
    loaded = NetworkPlan.load(path)
    assert loaded.compute_dtype == "int8"
    with np.load(path) as z:
        names = list(z.files)
        int8_arrays = [n for n in names if z[n].dtype == np.int8]
        scales = [n for n in names if n.endswith("scale")]
    assert int8_arrays and scales
    path2 = str(tmp_path / "resaved.npz")
    loaded.save(path2)
    with np.load(path) as a, np.load(path2) as b:
        assert set(a.files) == set(b.files)
        for n in a.files:
            assert np.array_equal(a[n], b[n]), n
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    assert np.array_equal(np.asarray(net.apply(x)),
                          np.asarray(loaded.apply(x)))


def test_fresh_process_warm_load_runs_zero_transforms_and_quantizations(
        tmp_path):
    """Acceptance gate: a fresh python process warm-loading the int8
    artifact performs ZERO filter transforms and ZERO re-quantizations --
    the transform/quantize entry points are boobytrapped before load() and
    the plan-time quantization counter stays at 0."""
    specs, params = _mbv2()
    net = compile_network(params, specs, res=32, compute_dtype="int8")
    path = str(tmp_path / "net_int8.npz")
    net.save(path)
    script = (
        "import json\n"
        "from repro.core import plan as planlib\n"
        "from repro.core.compile import NetworkPlan\n"
        "def boom(*a, **k):\n"
        "    raise AssertionError('weight work ran during warm load')\n"
        "planlib._bind_weights = boom\n"
        "planlib._wg.transform_filter_2d = boom\n"
        "from repro.optim import compression\n"
        "compression.quantize_channelwise = boom\n"
        f"net = NetworkPlan.load({path!r})\n"
        "info = planlib.plan_cache_info()\n"
        "import jax.numpy as jnp\n"
        "n_int8 = sum(str(getattr(p, 'u', None) is not None\n"
        "                 and p.u.dtype) == 'int8'\n"
        "             for p in net.plans.values() if hasattr(p, 'u'))\n"
        "print(json.dumps({'quantized': info['quantized'],\n"
        "                  'measured': info['measured'],\n"
        "                  'compute_dtype': net.compute_dtype,\n"
        "                  'n_int8': n_int8}))\n")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["quantized"] == 0 and got["measured"] == 0
    assert got["compute_dtype"] == "int8" and got["n_int8"] > 0


def test_dtype_mismatch_enumerates_per_layer_compute_dtypes(tmp_path):
    """The ArtifactMismatchError for a dtype mismatch names, per layer,
    the artifact's transform-domain compute dtype AND what this build's
    registry supports -- enough to diagnose a stale artifact without
    unpickling it by hand."""
    specs, params = _mbv2()
    net = compile_network(params, specs, res=32, compute_dtype="int8")
    path = str(tmp_path / "net.npz")
    net.save(path)
    with pytest.raises(ArtifactMismatchError) as ei:
        NetworkPlan.load(path, expect_dtype=jnp.bfloat16)
    msg = str(ei.value)
    assert "per-layer transform-domain compute dtypes" in msg
    assert "int8" in msg and "registry:" in msg
    assert "float32/bfloat16/int8" in msg


def test_compile_policy_falls_back_per_layer_and_describes(rng):
    """compile(compute_dtype=...) lowers every eligible layer and the
    describe() table surfaces the per-layer compute dtype column."""
    specs, params = _mbv2()
    net32 = compile_network(params, specs, res=32)
    net8 = compile_network(params, specs, res=32, compute_dtype="int8")
    table = net8.describe()
    header = table.splitlines()[0]
    assert "compute" in header
    assert "int8" in table
    dtypes = [p.describe().get("compute_dtype", "float32")
              for p in net8.plans.values()]
    assert all("int8" in d for d in dtypes)
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
    assert rel_err(net8.apply(x), net32.apply(x)) < 0.2   # random logits
    assert "int8" not in net32.describe().splitlines()[2]


# ---------------------------------------------------------------------------
# serve: per-layer dtype stats + the accuracy-probe promotion ladder
# ---------------------------------------------------------------------------

def test_server_surfaces_dtypes_and_promotes_on_budget_violation():
    """Server.stats carries the per-layer compute dtypes; an impossibly
    tight precision budget forces the probe to promote every reduced layer
    back to fp32 (and the network keeps serving)."""
    from repro.runtime.serve import ServeConfig, Server
    specs = [cnn.Conv("c1", 3, 3, 8), cnn.Conv("c2", 3, 3, 16)]
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=16)

    cfg = ServeConfig(buckets=(1,), verbose=False)
    srv = Server(params, specs, res=16, compute_dtype="int8", config=cfg)
    assert set(srv.stats.layer_compute_dtypes.values()) == {"int8"}
    report = srv.probe_precision()
    assert report and all(not r["promoted"] for r in report.values())
    assert srv.stats.precision_promotions == 0

    cfg2 = ServeConfig(buckets=(1,), verbose=False,
                       precision_budget={"int8": 1e-9})
    srv2 = Server(params, specs, res=16, compute_dtype="int8", config=cfg2)
    report2 = srv2.probe_precision()
    assert all(r["promoted"] for r in report2.values())
    assert srv2.stats.precision_promotions == len(report2)
    assert set(srv2.stats.layer_compute_dtypes.values()) == {"float32"}
    with srv2:
        x = np.zeros((16, 16, 3), np.float32)
        y = srv2.submit(x).result(timeout=60)
    assert y.shape == (16, 16, 16) and np.all(np.isfinite(y))
