"""Grouped / depthwise 2D convolution through the plan -> dispatch ->
executor -> kernel stack (PR 3).

Covers: oracle equivalence of every grouped executor (depthwise Winograd's
transform-domain Hadamard, block-diagonal grouped Winograd, grouped im2row,
the streamed Pallas depthwise kernel, and the fused separable block) vs
jax.lax.conv_general_dilated with feature_group_count; a hypothesis shape
sweep over all of them; plan-cache keying on groups; the groups constraint
errors; and the MobileNet-v1 zoo entry end-to-end through plan_cnn.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.im2col import direct_conv2d
from repro.core.plan import (plan_cache_info, plan_conv2d,
                             plan_separable_block)

from conftest import rel_err


def _sep_oracle(x, w_dw, w_pw, b_dw, b_pw, stride=1):
    c = x.shape[-1]
    h = jax.nn.relu(direct_conv2d(x, w_dw, stride=stride, groups=c) + b_dw)
    return jax.nn.relu(direct_conv2d(h, w_pw) + b_pw)


# ---------------------------------------------------------------------------
# oracle equivalence: every grouped executor vs feature_group_count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["auto", "winograd", "im2col",
                                       "pallas_winograd", "auto_tuned"])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_depthwise_plan_matches_direct(rng, algorithm, padding):
    c = 10
    x = jnp.asarray(rng.standard_normal((2, 13, 11, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, groups=c, padding=padding,
                    algorithm=algorithm)
    got = p.apply(x)
    want = direct_conv2d(x, w, padding=padding, groups=c)
    assert got.shape == want.shape
    assert p.out_shape == want.shape
    assert rel_err(got, want) < 1e-4


@pytest.mark.parametrize("algorithm,resolved", [
    ("auto", "winograd_grouped"), ("winograd", "winograd_grouped"),
    ("im2col", "im2col")])
@pytest.mark.parametrize("groups", [2, 3, 6])
def test_grouped_plan_matches_direct(rng, algorithm, resolved, groups):
    c, m = 12, 18
    x = jnp.asarray(rng.standard_normal((1, 14, 9, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c // groups, m)) / 3,
                    jnp.float32)
    p = plan_conv2d(x.shape, w, groups=groups, algorithm=algorithm)
    assert p.algorithm == resolved
    got = p.apply(x)
    want = direct_conv2d(x, w, groups=groups)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4


def test_depthwise_channel_multiplier(rng):
    """Depthwise with channel multiplier > 1 (output channel o = c*mult+j,
    the lax ordering) through the pure-JAX executors AND the streamed
    Pallas depthwise kernel (widened in PR 5)."""
    c, mult = 6, 3
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c * mult)) / 3, jnp.float32)
    want = direct_conv2d(x, w, groups=c)
    for algorithm in ("winograd", "im2col", "pallas_winograd"):
        p = plan_conv2d(x.shape, w, groups=c, algorithm=algorithm)
        assert rel_err(p.apply(x), want) < 1e-4


@pytest.mark.parametrize("mult", [2, 4])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_depthwise_pallas_channel_multiplier(rng, mult, padding):
    """The streamed depthwise kernel with channel multiplier > 1: parity
    with the lax oracle, asymmetric spatial shape, fused bias+activation
    epilogue, and the registry routing that the compiler's place pass
    relies on."""
    c = 5
    x = jnp.asarray(rng.standard_normal((2, 13, 9, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c * mult)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, groups=c, padding=padding,
                    algorithm="pallas_winograd")
    assert p.algorithm == "pallas_depthwise"     # no im2col fallback
    assert p.u.shape[2] == mult                  # (P, Cp, mult) taps
    want = direct_conv2d(x, w, padding=padding, groups=c)
    assert p.out_shape == want.shape
    assert rel_err(p.apply(x), want) < 1e-4
    b = jnp.asarray(rng.standard_normal((c * mult,)), jnp.float32)
    got = p.apply(x, bias=b, activation="relu")
    assert rel_err(got, jax.nn.relu(want + b)) < 1e-4


def test_depthwise_pallas_multiplier_parity_with_pure_jax(rng):
    """Streamed-vs-pure-JAX executor parity on the widened multiplier
    coverage (the ROADMAP gap this PR closes)."""
    c, mult = 7, 3
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5, 1, c * mult)) / 25,
                    jnp.float32)
    p_pallas = plan_conv2d(x.shape, w, groups=c, algorithm="pallas_winograd")
    p_jax = plan_conv2d(x.shape, w, groups=c, algorithm="winograd")
    assert p_pallas.algorithm == "pallas_depthwise"
    assert p_jax.algorithm == "winograd_depthwise"
    assert rel_err(p_pallas.apply(x), p_jax.apply(x)) < 1e-4


@pytest.mark.parametrize("stride", [1, 2, 3])
def test_grouped_stride_routing(rng, stride):
    """auto routes grouped layers onto the registry's matching executor:
    the block-diagonal stride-1 executor, the stride-2 phase-decomposition
    executor, and (stride 3: no winograd capability) the im2row fallback."""
    c, g = 8, 4
    x = jnp.asarray(rng.standard_normal((1, 11, 11, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c // g, 8)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, groups=g, stride=stride, algorithm="auto")
    assert p.algorithm == {1: "winograd_grouped", 2: "winograd_strided",
                           3: "im2col"}[stride]
    want = direct_conv2d(x, w, stride=stride, groups=g)
    assert rel_err(p.apply(x), want) < 1e-4


def test_depthwise_pallas_fused_epilogue(rng):
    """The streamed depthwise kernel fuses bias+activation into its store."""
    c = 9
    x = jnp.asarray(rng.standard_normal((2, 14, 10, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    p = plan_conv2d(x.shape, w, groups=c, algorithm="pallas_winograd")
    assert p.algorithm == "pallas_depthwise"
    for act, fn in (("relu", jax.nn.relu), ("gelu", jax.nn.gelu)):
        got = p.apply(x, bias=b, activation=act)
        want = fn(direct_conv2d(x, w, groups=c) + b)
        assert rel_err(got, want) < 1e-4


def test_depthwise_pallas_multiblock_channels(rng):
    """C above one 128 block exercises the depthwise kernel's channel grid
    axis; C deliberately not a multiple of 128."""
    c = 131
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 9, jnp.float32)
    p = plan_conv2d(x.shape, w, groups=c, algorithm="pallas_winograd")
    assert rel_err(p.apply(x), direct_conv2d(x, w, groups=c)) < 1e-4


def test_dispatch_conv2d_groups(rng):
    c = 8
    x = jnp.asarray(rng.standard_normal((1, 10, 10, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    got = dispatch.conv2d(x, w, groups=c, bias=b, activation="relu")
    want = jax.nn.relu(direct_conv2d(x, w, groups=c) + b)
    assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# separable blocks (fused + composed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm,mode", [
    ("pallas_winograd", "fused_pallas"), ("auto", "composed"),
    ("im2col", "composed")])
def test_separable_block_matches_oracle(rng, algorithm, mode):
    c, m = 10, 14
    x = jnp.asarray(rng.standard_normal((2, 13, 11, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3, jnp.float32)
    b_dw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    b_pw = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
    p = plan_separable_block(x.shape, w_dw, w_pw, algorithm=algorithm)
    assert p.mode == mode
    got = p.apply(x, bias_dw=b_dw, bias_pw=b_pw)
    want = _sep_oracle(x, w_dw, w_pw, b_dw, b_pw)
    assert got.shape == want.shape == p.out_shape
    assert rel_err(got, want) < 1e-4


def test_separable_block_strided_composes(rng):
    """Stride-2 blocks (MobileNet reductions) cannot fuse; the composed
    fallback must still match the oracle, on the Pallas path too."""
    c, m = 6, 8
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3, jnp.float32)
    b_dw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    b_pw = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
    for algorithm in ("pallas_winograd", "auto"):
        p = plan_separable_block(x.shape, w_dw, w_pw, stride=2,
                                 algorithm=algorithm)
        assert p.mode == "composed"
        got = p.apply(x, bias_dw=b_dw, bias_pw=b_pw)
        assert rel_err(got, _sep_oracle(x, w_dw, w_pw, b_dw, b_pw,
                                        stride=2)) < 1e-4


def test_separable_pallas_baselines_never_fuse(rng):
    """Requesting a Pallas *baseline* algorithm must not silently
    substitute the fused fast path -- the baselines exist to be the other
    arm of an A/B."""
    c, m = 8, 8
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3, jnp.float32)
    for alg in ("pallas_im2col", "pallas_winograd_materialized"):
        p = plan_separable_block(x.shape, w_dw, w_pw, algorithm=alg)
        assert p.mode == "composed", (alg, p.mode)
        assert p.dw.algorithm == "im2col"       # no grouped Pallas baseline
        assert p.pw.algorithm == "pallas_im2col"
        got = p.apply(x, bias_dw=jnp.zeros((c,)), bias_pw=jnp.zeros((m,)))
        want = _sep_oracle(x, w_dw, w_pw, jnp.zeros((c,)), jnp.zeros((m,)))
        assert rel_err(got, want) < 1e-4


def test_algorithm_supported_matches_plan_conv2d(rng):
    """The coverage predicate and the planner must agree: supported ->
    plan_conv2d succeeds; unsupported (for concrete algorithms) ->
    plan_conv2d raises. This is the single-source contract
    models/cnn.py:_layer_algorithm relies on."""
    from repro.core.plan import ALGORITHMS, algorithm_supported
    cases = [
        # (kh, kw, stride, groups, c_in, c_out)
        (3, 3, 1, 1, 8, 8), (3, 3, 2, 1, 8, 8), (1, 7, 1, 1, 8, 8),
        (3, 3, 1, 8, 8, 8), (3, 3, 2, 8, 8, 8), (3, 3, 1, 8, 8, 16),
        (3, 3, 1, 2, 8, 8), (1, 3, 1, 8, 8, 8), (4, 4, 1, 1, 8, 8),
    ]
    for kh, kw, stride, groups, c_in, c_out in cases:
        w = jnp.zeros((kh, kw, c_in // groups, c_out), jnp.float32)
        for alg in ALGORITHMS:
            ok = algorithm_supported(alg, kh, kw, stride, groups=groups,
                                     c_in=c_in, c_out=c_out)
            try:
                plan_conv2d((1, 16, 16, c_in), w, stride=stride,
                            groups=groups, algorithm=alg)
                planned = True
            except ValueError:
                planned = False
            if alg in ("auto", "auto_tuned"):
                assert planned           # policies always resolve something
            else:
                assert planned == ok, (alg, kh, kw, stride, groups,
                                       c_in, c_out)


def test_separable_block_under_jit(rng):
    c, m = 8, 8
    x = jnp.asarray(rng.standard_normal((1, 12, 12, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3, jnp.float32)
    p = plan_separable_block(x.shape, w_dw, w_pw,
                             algorithm="pallas_winograd")
    got = jax.jit(lambda x: p.apply(x))(x)
    want = _sep_oracle(x, w_dw, w_pw, jnp.zeros((c,)), jnp.zeros((m,)))
    assert rel_err(got, want) < 1e-4


def test_separable_fused_keeps_intermediate_out_of_hbm(rng):
    """jaxpr regression: the fused separable path is ONE pallas_call -- no
    top-level op produces the (N, H, W, C) depthwise intermediate, and no
    epilogue add/max runs outside the kernel."""
    c, m = 8, 12
    x = jnp.asarray(rng.standard_normal((1, 16, 16, c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3, jnp.float32)
    b_dw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    b_pw = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
    p = plan_separable_block(x.shape, w_dw, w_pw,
                             algorithm="pallas_winograd")
    assert p.mode == "fused_pallas"
    jaxpr = jax.make_jaxpr(
        lambda xx: p.apply(xx, bias_dw=b_dw, bias_pw=b_pw))(x).jaxpr

    def count(jaxpr, name):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                n += 1
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", None)
                if inner is not None:
                    n += count(getattr(inner, "jaxpr", inner), name)
        return n

    n_kernels = count(jaxpr, "pallas_call")
    assert n_kernels == 1, f"expected one fused kernel, got {n_kernels}"
    # the depthwise intermediate would be a rank-4 tensor with C channels at
    # the input spatial size; only pad/crop of the input itself may match.
    bad = [eqn.primitive.name for eqn in jaxpr.eqns
           for v in eqn.outvars
           if eqn.primitive.name in ("add", "max", "custom_jvp_call")
           and getattr(v.aval, "ndim", 0) == 4]
    assert not bad, f"unfused separable ops outside the kernel: {bad}"


# ---------------------------------------------------------------------------
# hypothesis shape sweep across every grouped executor
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover - CI installs it
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        h=st.integers(7, 24), w=st.integers(7, 24),
        c=st.integers(2, 16), mult=st.integers(1, 2),
        k=st.sampled_from([3, 5]),
        algorithm=st.sampled_from(["winograd", "im2col", "pallas_winograd"]),
        padding=st.sampled_from(["SAME", "VALID"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_depthwise_sweep_matches_direct(h, w, c, mult, k, algorithm,
                                            padding, seed):
        if algorithm == "pallas_winograd" and mult != 1:
            mult = 1                      # the streamed kernel is mult-1 only
        if padding == "VALID" and (h < k or w < k):
            return
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((k, k, 1, c * mult)) / k,
                         jnp.float32)
        p = plan_conv2d(x.shape, wt, groups=c, padding=padding,
                        algorithm=algorithm)
        got = p.apply(x)
        want = direct_conv2d(x, wt, padding=padding, groups=c)
        assert got.shape == want.shape
        assert rel_err(got, want) < 1e-4

    @settings(max_examples=20, deadline=None)
    @given(
        hw=st.integers(7, 20), cg=st.integers(1, 6),
        groups=st.sampled_from([2, 3, 4]), mg=st.integers(1, 5),
        algorithm=st.sampled_from(["winograd", "im2col"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_grouped_sweep_matches_direct(hw, cg, groups, mg, algorithm,
                                          seed):
        rng = np.random.default_rng(seed)
        c, m = cg * groups, mg * groups
        x = jnp.asarray(rng.standard_normal((1, hw, hw, c)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, cg, m)) / 3, jnp.float32)
        p = plan_conv2d(x.shape, wt, groups=groups, algorithm=algorithm)
        got = p.apply(x)
        want = direct_conv2d(x, wt, groups=groups)
        assert got.shape == want.shape
        assert rel_err(got, want) < 1e-4

    @settings(max_examples=12, deadline=None)
    @given(
        h=st.integers(8, 20), w=st.integers(8, 20),
        c=st.integers(2, 12), m=st.integers(1, 14),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_separable_sweep_matches_oracle(h, w, c, m, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
        w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, c)) / 3,
                           jnp.float32)
        w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / 3,
                           jnp.float32)
        b_dw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
        b_pw = jnp.asarray(rng.standard_normal((m,)), jnp.float32)
        p = plan_separable_block(x.shape, w_dw, w_pw,
                                 algorithm="pallas_winograd")
        assert p.mode == "fused_pallas"
        got = p.apply(x, bias_dw=b_dw, bias_pw=b_pw)
        want = _sep_oracle(x, w_dw, w_pw, b_dw, b_pw)
        assert rel_err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# plan-cache keying and constraint errors
# ---------------------------------------------------------------------------

def test_cache_key_includes_groups(rng):
    """Two plans of the same shapes with different groups must not share a
    spec (the depthwise (3, 3, 1, C) filter is also a valid dense filter for
    a 1-channel input slice -- keying on shapes alone is not enough)."""
    c = 8
    w_dense = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, 8)) / 3, jnp.float32)
    plan_conv2d((1, 12, 12, c), w_dense)
    p = plan_conv2d((1, 12, 12, c), w_dw, groups=c)
    assert plan_cache_info()["hits"] == 0
    assert plan_cache_info()["misses"] == 2
    assert p.spec.groups == c
    p2 = plan_conv2d((1, 12, 12, c), w_dw, groups=c)
    assert plan_cache_info()["hits"] == 1
    assert p2.spec is p.spec


def test_groups_constraint_errors(rng):
    w = jnp.asarray(jnp.zeros((3, 3, 4, 8)), jnp.float32)
    # non-divisible groups
    with pytest.raises(ValueError, match="must divide"):
        plan_conv2d((1, 10, 10, 9), jnp.zeros((3, 3, 3, 9)), groups=2)
    # filter input channels inconsistent with groups
    with pytest.raises(ValueError, match="channel mismatch"):
        plan_conv2d((1, 10, 10, 8), w, groups=4)
    # grouped (non-depthwise) pallas_winograd: the registry error names the
    # executors that do cover the layer (block-diagonal grouped winograd)
    with pytest.raises(ValueError, match="winograd_grouped"):
        plan_conv2d((1, 10, 10, 8), w, groups=2, algorithm="pallas_winograd")
    # stride-2 depthwise with multiplier > 1 on the streamed kernel: the
    # strided executor's constraint (mult 1) is stated and the covering
    # executor suggested (the stride-1 streamed kernel handles any
    # multiplier since the widened capability landed)
    with pytest.raises(ValueError, match=r"mult 1.*winograd_strided"):
        plan_conv2d((1, 10, 10, 4), jnp.zeros((3, 3, 1, 8)), stride=2,
                    groups=4, algorithm="pallas_winograd")
    # grouped pallas baselines: no grouped executor registered
    for alg in ("pallas_winograd_materialized", "pallas_im2col"):
        with pytest.raises(ValueError, match="no executor"):
            plan_conv2d((1, 10, 10, 8), jnp.zeros((3, 3, 1, 8)), groups=8,
                        algorithm=alg)
    # unknown algorithm lists the requestable set
    with pytest.raises(ValueError, match="expected one of"):
        plan_conv2d((1, 10, 10, 8), jnp.zeros((3, 3, 8, 8)),
                    algorithm="winogradd")


def test_grouped_1xn_has_no_winograd_executor(rng):
    """Grouped 1xN layers are unsuitable for the winograd family: auto falls
    back to im2col, forced winograd raises the actionable error."""
    c = 6
    x = jnp.asarray(rng.standard_normal((1, 10, 10, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((1, 3, 1, c)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, groups=c, algorithm="auto")
    assert p.algorithm == "im2col"
    assert rel_err(p.apply(x), direct_conv2d(x, w, groups=c)) < 1e-4
    with pytest.raises(ValueError, match="no executor"):
        plan_conv2d(x.shape, w, groups=c, algorithm="winograd")


# ---------------------------------------------------------------------------
# MobileNet-v1 zoo entry
# ---------------------------------------------------------------------------

def test_mobilenet_v1_builds_and_plans(rng):
    from repro.models import cnn
    specs = cnn.NETWORKS["mobilenet_v1"][0]()
    res = 64
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert base.shape == (1, 1000)
    plans = cnn.plan_cnn(params, specs, res=res)
    planned = cnn.cnn_forward(params, x, specs, plans=plans)
    assert rel_err(planned, base) < 1e-3
    # the zoo routes separable blocks through separable-block plans
    from repro.core.plan import SeparableBlockPlan
    sep_plans = [p for p in plans.values()
                 if isinstance(p, SeparableBlockPlan)]
    assert len(sep_plans) == 13


def test_mobilenet_v1_pallas_fuses_stride1_blocks(rng):
    from repro.models import cnn
    specs = cnn.NETWORKS["mobilenet_v1_050"][0]()
    res = 32
    params = cnn.init_cnn(jax.random.key(1), specs, 3, res=res)
    plans = cnn.plan_cnn(params, specs, res=res, algorithm="pallas_winograd")
    modes = {name: p.mode for name, p in plans.items()
             if hasattr(p, "mode")}
    # stride-1 blocks fuse; stride-2 reduction blocks compose
    assert modes["sep2"] == "fused_pallas"
    assert modes["sep3"] == "composed"
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    planned = cnn.cnn_forward(params, x, specs, plans=plans)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(planned, base) < 1e-3


def test_mobilenet_width_multiplier():
    from repro.models import cnn
    full = cnn.mobilenet_v1()
    half = cnn.mobilenet_v1(width_mult=0.5)
    sep_full = [s for s in full if isinstance(s, cnn.SeparableConv)]
    sep_half = [s for s in half if isinstance(s, cnn.SeparableConv)]
    assert len(sep_full) == len(sep_half) == 13
    assert sep_full[-1].c_out == 1024 and sep_half[-1].c_out == 512
    assert all(s.c_out % 8 == 0 for s in sep_half)
