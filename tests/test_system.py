"""End-to-end system behaviour: training convergence, checkpoint/restart
(including crash-mid-write and elastic restore), deterministic data pipeline,
fault-tolerance runtime, and the batched server."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.train import train
from repro.runtime.fault import Backoff, StepTimer, run_with_retries


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

def test_train_loss_decreases(tmp_path):
    _, history = train("qwen2_5_3b", steps=30, batch=8, seq=32, smoke=True,
                       ckpt_dir=None, lr=3e-3, log_every=100)
    assert len(history) == 30
    assert history[-1] < history[0] * 0.9, history
    assert np.isfinite(history).all()


def test_train_restart_resumes_from_checkpoint(tmp_path):
    ck = str(tmp_path / "ckpt")
    train("qwen2_5_3b", steps=6, batch=4, seq=16, smoke=True,
          ckpt_dir=ck, ckpt_every=3, log_every=100)
    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 6
    # second call restores and continues to step 10 without re-running 0-5
    _, history = train("qwen2_5_3b", steps=10, batch=4, seq=16, smoke=True,
                       ckpt_dir=ck, ckpt_every=5, log_every=100)
    assert len(history) == 4          # only steps 6..9 executed
    assert CheckpointManager(ck).latest_step() == 10


def test_train_with_grad_accum_matches_no_accum_loss_scale():
    """accum=2 over the same global batch gives (near-)identical first-step
    loss (dense arch: exact up to reduction order; MoE would differ by
    design -- capacity is per-microbatch)."""
    _, h1 = train("qwen2_5_3b", steps=3, batch=8, seq=16, smoke=True,
                  ckpt_dir=None, accum=1, log_every=100)
    _, h2 = train("qwen2_5_3b", steps=3, batch=8, seq=16, smoke=True,
                  ckpt_dir=None, accum=2, log_every=100)
    np.testing.assert_allclose(h1[0], h2[0], rtol=1e-3)
    # MoE arch under accum still trains finitely
    _, h3 = train("granite_moe_3b_a800m", steps=2, batch=8, seq=16, smoke=True,
                  ckpt_dir=None, accum=2, log_every=100)
    assert np.isfinite(h3).all()


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": {"w": rng.standard_normal((4, 3)).astype(np.float32)},
            "b": [rng.standard_normal(5).astype(np.float32),
                  np.int32(7)]}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree, blocking=True)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                                       np.asarray(x).dtype), tree)
    out = mgr.restore(5, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), tree, out)


def test_checkpoint_keep_last_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.steps() == [3, 4]


def test_checkpoint_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    # simulate a crash mid-write of step 2: stray .tmp dir only
    os.makedirs(tmp_path / "step_2.tmp")
    with open(tmp_path / "step_2.tmp" / "arrays.npz", "w") as f:
        f.write("garbage")
    assert mgr.latest_step() == 1     # .tmp never considered committed


def test_checkpoint_dtype_cast_on_restore(tmp_path):
    """Elastic restore casts to the dtype of `like` (e.g. bf16 params written
    from an fp32 debug run)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.ones((3,), np.float32) * 1.5}
    mgr.save(1, tree, blocking=True)
    like = {"w": jax.ShapeDtypeStruct((3,), jnp.bfloat16)}
    out = mgr.restore(1, like)
    assert out["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_across_restarts():
    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    p1 = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    p2 = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    for step in (0, 5, 100):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    b = SyntheticLM(cfg, batch=2, seq=32).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_host_sharding_partitions_batch():
    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    shards = [SyntheticLM(cfg, batch=8, seq=16, seed=1, host_index=i,
                          host_count=4) for i in range(4)]
    assert all(s.batch == 2 for s in shards)
    got = [s.batch_at(7)["tokens"] for s in shards]
    # host shards are distinct
    assert not np.array_equal(got[0], got[1])


def test_prefetcher_delivers_in_order_and_closes():
    it = Prefetcher(iter([{"i": i} for i in range(5)]), depth=2)
    assert [next(it)["i"] for _ in range(5)] == list(range(5))
    it.close()


# ---------------------------------------------------------------------------
# fault runtime
# ---------------------------------------------------------------------------

def test_run_with_retries_recovers():
    calls = []

    def body(start):
        calls.append(start)
        if len(calls) < 3:
            raise RuntimeError("simulated worker loss")
        return 42

    assert run_with_retries(body, max_failures=3) == 42
    assert len(calls) == 3


def test_run_with_retries_gives_up():
    def body(start):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        run_with_retries(body, max_failures=2)


def test_run_with_retries_paces_with_exponential_backoff():
    sleeps = []

    def body(start):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        run_with_retries(body, max_failures=3, base_delay_s=1.0,
                         max_delay_s=16.0, jitter=0.5, sleep=sleeps.append)
    # three paced retries; delay k is 2**k jittered into [0.5, 1.0] of itself
    assert len(sleeps) == 3
    for k, d in enumerate(sleeps):
        assert 0.5 * 2**k <= d <= 2**k
    assert sleeps[0] < sleeps[1] < sleeps[2]


def test_run_with_retries_lets_systemexit_escape():
    calls = []

    def body(start):
        calls.append(start)
        raise SystemExit(3)           # preemption: do NOT burn retries

    with pytest.raises(SystemExit):
        run_with_retries(body, max_failures=5, sleep=lambda s: None)
    assert len(calls) == 1


def test_backoff_caps_and_resets():
    b = Backoff(base=0.1, factor=2.0, cap=0.3, jitter=0.0)
    assert [b.next() for _ in range(4)] == [0.1, 0.2, 0.3, 0.3]
    b.reset()
    assert b.next() == 0.1


def test_step_timer_flags_stragglers():
    t = StepTimer(window=50, sigma=3.0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        assert not t.record(0.10 + rng.uniform(0, 0.001))
    assert t.record(1.0)              # 10x outlier
    assert t.stragglers == 1


def test_step_timer_excludes_outliers_from_baseline():
    """A flagged straggler must not inflate the baseline window, or it
    would mask the next straggler of the same magnitude."""
    t = StepTimer(window=50, sigma=3.0)
    for _ in range(20):
        t.record(0.10)
    assert t.record(1.0)
    assert 1.0 not in t.baseline and 1.0 in t.times
    assert t.record(1.0)              # still flagged: baseline is clean
    assert t.stragglers == 2


# ---------------------------------------------------------------------------
# batched server
# ---------------------------------------------------------------------------

def test_server_batched_decode():
    from repro.launch.serve import Request, Server
    from repro.models import transformer as tf

    cfg = cfglib.get_smoke_config("qwen2_5_3b")
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    server = Server(cfg, params, max_batch=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (4,)).astype(np.int32),
                    max_new=4) for i in range(3)]
    done, ticks = server.run(reqs)
    assert len(done) == 3
    assert ticks >= 4                 # 3 reqs through 2 slots: >= 2 waves
    for req in done:
        assert req.done and len(req.out) == 4
        assert all(0 <= t < cfg.vocab for t in req.out)
