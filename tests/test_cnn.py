"""Paper CNN zoo: every network builds, runs, and the paper's two benchmark
configurations (fast-mixed vs im2row-everywhere) agree numerically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn

from conftest import rel_err

# reduced resolutions that keep every VALID conv/pool positive-sized
_RES = {"vgg16": 64, "vgg19": 64, "googlenet": 64, "inception_v3": 96,
        "squeezenet": 64, "mobilenet_v1": 64, "mobilenet_v1_050": 64,
        "mobilenet_v2": 64}


@pytest.mark.parametrize("net", sorted(cnn.NETWORKS))
def test_network_builds_and_runs(rng, net):
    specs = cnn.NETWORKS[net][0]()
    res = _RES[net]
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    out = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert out.shape == (1, 1000)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("net", ["squeezenet", "googlenet"])
@pytest.mark.parametrize("algorithm", ["auto", "auto_tuned"])
def test_fast_scheme_agrees_with_baseline(rng, net, algorithm):
    specs = cnn.NETWORKS[net][0]()
    res = _RES[net]
    params = cnn.init_cnn(jax.random.key(1), specs, 3, res=res)
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    fast = cnn.cnn_forward(params, x, specs, algorithm=algorithm)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(fast, base) < 1e-3


@pytest.mark.parametrize("net", ["squeezenet", "googlenet"])
def test_planned_forward_agrees_with_baseline(rng, net):
    """plan_cnn + cnn_forward(plans=...) == im2row everywhere, numerically."""
    specs = cnn.NETWORKS[net][0]()
    res = _RES[net]
    params = cnn.init_cnn(jax.random.key(2), specs, 3, res=res)
    plans = cnn.plan_cnn(params, specs, res=res)
    x = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
    planned = cnn.cnn_forward(params, x, specs, plans=plans)
    base = cnn.cnn_forward(params, x, specs, algorithm="im2col")
    assert rel_err(planned, base) < 1e-3
    # planned forward also works under jit (plans close over the filters)
    jitted = jax.jit(lambda x: cnn.cnn_forward(params, x, specs, plans=plans))
    assert rel_err(jitted(x), base) < 1e-3


def test_layer_inventory_census():
    """Paper Fig-3 denominator: the suitable-layer census is stable."""
    from benchmarks.common import conv_layer_inventory
    inv = conv_layer_inventory("squeezenet")
    assert len(inv) == 26                       # 26 convs in SqueezeNet 1.0
    suitable = [l for l in inv if l["suitable"]]
    # 8 stride-1 3x3 expand layers + the 7x7 stride-2 stem (covered by the
    # stride-2 phase-decomposition executor since the registry landed)
    assert len(suitable) == 9
    assert sorted(l["kh"] for l in suitable) == [3] * 8 + [7]
    # inception has the paper's 1x7/7x1 layers, all suitable
    inv3 = conv_layer_inventory("inception_v3")
    one_d = [l for l in inv3 if l["suitable"] and 1 in (l["kh"], l["kw"])]
    assert len(one_d) >= 10


def test_dense_weights_initialized_eagerly():
    specs = cnn.NETWORKS["vgg16"][0]()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=64)
    assert params["fc6"]["w"].shape == (2 * 2 * 512, 4096)   # 64 / 2^5 = 2
    assert params["fc8"]["w"].shape == (4096, 1000)
