"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting output shapes + finiteness, plus
prefill-vs-decode consistency (the serving-path correctness invariant)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import transformer as tf
from repro.optim import adamw

_DTYPE = jnp.float32


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
           "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    if cfg.encoder is not None:
        out["frames"] = rng.standard_normal(
            (b, cfg.encoder.n_ctx, cfg.d_model)).astype(np.float32)
    return out


@pytest.fixture(scope="module")
def smoke_state():
    """Cache (cfg, params) per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = cfglib.get_smoke_config(arch)
            params = tf.init_params(jax.random.key(0), cfg, _DTYPE)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full config must carry the exact published numbers."""
    cfg = cfglib.get_config(arch)
    expect = {
        "falcon_mamba_7b": dict(n_layers=64, d_model=4096, d_ff=0, vocab=65024),
        "whisper_tiny": dict(n_layers=4, d_model=384, n_heads=6, d_ff=1536,
                             vocab=51865),
        "qwen1_5_32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=40, d_ff=27392, vocab=152064),
        "nemotron_4_340b": dict(n_layers=96, d_model=18432, n_heads=96,
                                n_kv_heads=8, d_ff=73728, vocab=256000),
        "qwen2_5_3b": dict(n_layers=36, d_model=2048, n_heads=16,
                           n_kv_heads=2, d_ff=11008, vocab=151936),
        "yi_34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab=64000),
        "jamba_v0_1_52b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=8, d_ff=14336, vocab=65536),
        "llama4_maverick_400b_a17b": dict(n_layers=48, d_model=5120,
                                          n_heads=40, n_kv_heads=8, d_ff=8192,
                                          vocab=202048),
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512, vocab=49155),
        "chameleon_34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab=65536),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # family-specific invariants
    if arch == "falcon_mamba_7b":
        assert cfg.family == "ssm" and cfg.ssm.d_state == 16
    if arch == "jamba_v0_1_52b":
        assert cfg.attn_every == 8 and cfg.moe.n_experts == 16 \
            and cfg.moe.top_k == 2
    if arch == "llama4_maverick_400b_a17b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 1
    if arch == "granite_moe_3b_a800m":
        assert cfg.moe.n_experts == 40 and cfg.moe.top_k == 8
    if arch == "nemotron_4_340b":
        assert cfg.act == "squared_relu"
    if arch in ("qwen1_5_32b", "qwen2_5_3b"):
        assert cfg.qkv_bias
    if arch == "whisper_tiny":
        assert cfg.encoder is not None and cfg.encoder.n_layers == 4


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_smoke_forward_logits(smoke_state, arch):
    cfg, params = smoke_state(arch)
    batch = _batch(cfg)
    logits = tf.forward_logits(params, jnp.asarray(batch["tokens"]), cfg,
                               frames=jnp.asarray(batch["frames"])
                               if cfg.encoder else None)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_smoke_train_step(smoke_state, arch):
    cfg, params = smoke_state(arch)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = jax.tree.map(jnp.asarray, _batch(cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "falcon_mamba_7b",
                                  "jamba_v0_1_52b", "granite_moe_3b_a800m",
                                  "whisper_tiny"])
def test_prefill_then_decode_matches_forward(smoke_state, arch):
    """Teacher-forced decode after prefill must reproduce forward_logits --
    the invariant tying the three dry-run step kinds together. One arch per
    family (dense/ssm/hybrid/moe/enc-dec)."""
    cfg, params = smoke_state(arch)
    b, s, extra = 2, 8, 4
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + extra)), jnp.int32)
    frames = (jnp.asarray(rng.standard_normal(
        (b, cfg.encoder.n_ctx, cfg.d_model)), jnp.float32)
        if cfg.encoder else None)

    full = tf.forward_logits(params, toks, cfg, frames=frames)

    max_len = s + extra
    logits_p, cache = tf.prefill(params, toks[:, :s], cfg, max_len,
                                 frames=frames)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, s - 1]), rtol=2e-2, atol=2e-3)

    serve = jax.jit(make_serve_step(cfg))
    for i in range(extra):
        logits_d, cache = serve(params, cache, toks[:, s + i:s + i + 1],
                                jnp.asarray(s + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, s + i]),
            rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_cells_assignment(arch):
    """long_500k runnable iff sub-quadratic; all four shapes accounted for."""
    cells = {c[0]: c[3] for c in cfglib.cells(arch)}
    assert set(cells) == set(cfglib.SHAPES)
    cfg = cfglib.get_config(arch)
    if cfg.subquadratic:
        assert cells["long_500k"] == "decode"
        assert arch in ("falcon_mamba_7b", "jamba_v0_1_52b")
    else:
        assert cells["long_500k"] == "skip"


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_param_count_order_of_magnitude(arch):
    """n_params estimate matches the arch's nameplate size (loose: the
    nameplate rounds, ours counts exactly)."""
    nameplate = {
        "falcon_mamba_7b": 7e9, "whisper_tiny": 39e6, "qwen1_5_32b": 32e9,
        "nemotron_4_340b": 340e9, "qwen2_5_3b": 3e9, "yi_34b": 34e9,
        "jamba_v0_1_52b": 52e9, "llama4_maverick_400b_a17b": 400e9,
        "granite_moe_3b_a800m": 3e9, "chameleon_34b": 34e9,
    }[arch]
    n = cfglib.get_config(arch).n_params
    assert 0.4 * nameplate < n < 2.6 * nameplate, (arch, n, nameplate)


def test_moe_active_params_below_total():
    for arch in ("llama4_maverick_400b_a17b", "granite_moe_3b_a800m",
                 "jamba_v0_1_52b"):
        cfg = cfglib.get_config(arch)
        assert cfg.n_active_params < cfg.n_params
    # llama4: ~17B active of ~400B
    cfg = cfglib.get_config("llama4_maverick_400b_a17b")
    assert cfg.n_active_params < 0.15 * cfg.n_params
