"""Plan/execute split (repro.core.plan): numerical equivalence of planned
execution vs the direct-convolution oracle, plan-cache hit/miss behavior,
the transform-once contract, and plan-time measured autotuning."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import winograd as wg
from repro.core.im2col import direct_conv2d
from repro.core.plan import (ConvPlan, clear_plan_cache, plan_cache_info,
                             plan_conv1d, plan_conv2d,
                             plan_depthwise_conv1d)

from conftest import rel_err

# (plan-cache isolation is provided by the autouse _fresh_plan_cache fixture
# in conftest.py)


def _spec_cache():
    """(hits, misses, size) of the spec cache -- plan_cache_info also
    carries the serialized-plan artifact counters (tested in
    test_compile.py), which these tests don't exercise."""
    info = plan_cache_info()
    return (info["hits"], info["misses"], info["size"])


# ---------------------------------------------------------------------------
# numerical equivalence: plan.apply == lax.conv_general_dilated
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kh,kw", [(3, 3), (5, 5), (1, 7), (7, 1)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("algorithm", ["winograd", "im2col", "pallas_winograd"])
def test_plan_apply_matches_direct(rng, kh, kw, padding, algorithm):
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((kh, kw, 4, 5)) / (kh * kw),
                    jnp.float32)
    p = plan_conv2d(x.shape, w, padding=padding, algorithm=algorithm)
    got = p.apply(x)
    want = direct_conv2d(x, w, padding=padding)
    assert got.shape == want.shape
    assert p.out_shape == want.shape
    assert rel_err(got, want) < 1e-3


def test_plan_apply_under_jit(rng):
    x = jnp.asarray(rng.standard_normal((1, 14, 14, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, algorithm="winograd")
    got = jax.jit(p.apply)(x)
    assert rel_err(got, direct_conv2d(x, w)) < 1e-3


def test_plan_allows_different_batch_rejects_different_spatial(rng):
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    p = plan_conv2d((1, 10, 10, 4), w)
    x3 = jnp.asarray(rng.standard_normal((3, 10, 10, 4)), jnp.float32)
    assert rel_err(p.apply(x3), direct_conv2d(x3, w)) < 1e-3
    with pytest.raises(ValueError, match="plan built for"):
        p.apply(jnp.zeros((1, 11, 10, 4), jnp.float32))


# ---------------------------------------------------------------------------
# plan cache hit/miss behavior
# ---------------------------------------------------------------------------

def test_cache_hit_on_same_shape_miss_on_new(rng):
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    assert _spec_cache() == (0, 0, 0)
    p1 = plan_conv2d((1, 12, 12, 4), w)
    assert _spec_cache() == (0, 1, 1)
    p2 = plan_conv2d((1, 12, 12, 4), w)
    assert _spec_cache() == (1, 1, 1)
    assert p1.spec is p2.spec                  # decisions shared, not rebuilt
    plan_conv2d((1, 16, 16, 4), w)             # new spatial shape -> miss
    assert _spec_cache() == (1, 2, 2)
    plan_conv2d((1, 12, 12, 4), w, algorithm="im2col")   # new algorithm -> miss
    assert _spec_cache() == (1, 3, 3)


def test_cache_key_includes_padding_and_stride(rng):
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    plan_conv2d((1, 12, 12, 4), w, padding="SAME")
    plan_conv2d((1, 12, 12, 4), w, padding="VALID")
    plan_conv2d((1, 12, 12, 4), w, stride=2)
    assert plan_cache_info()["misses"] == 3
    assert plan_cache_info()["hits"] == 0


def test_clear_plan_cache(rng):
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    plan_conv2d((1, 12, 12, 4), w)
    clear_plan_cache()
    assert _spec_cache() == (0, 0, 0)


# ---------------------------------------------------------------------------
# the transform-once contract (the paper's section-4 deployment insight)
# ---------------------------------------------------------------------------

def test_filter_transform_called_exactly_once(rng, monkeypatch):
    """transform_filter_2d runs at plan time, once; repeated apply() calls
    reuse the cached Winograd-domain filter."""
    calls = {"n": 0}
    real = wg.transform_filter_2d

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(wg, "transform_filter_2d", counting)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, algorithm="winograd")
    assert calls["n"] == 1
    for _ in range(3):
        p.apply(x)
    assert calls["n"] == 1


def test_no_geometry_derivation_in_apply(rng, monkeypatch):
    """apply() must not re-derive padding/tiling: _pad_amounts is plan-time
    only."""
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    p = plan_conv2d(x.shape, w, algorithm="winograd")

    def boom(*args, **kwargs):
        raise AssertionError("_pad_amounts called during apply()")

    monkeypatch.setattr(wg, "_pad_amounts", boom)
    p.apply(x)


def test_plan_records_build_time_and_domain_filter(rng):
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 6)) / 3, jnp.float32)
    p = plan_conv2d((1, 12, 12, 4), w, algorithm="winograd")
    ct = p.spec.ct_h
    assert p.u.shape == (ct.t, ct.t, 4, 6)     # Winograd-domain filter
    assert p.build_time_s > 0


# ---------------------------------------------------------------------------
# plan-time measured autotuning
# ---------------------------------------------------------------------------

def test_auto_tuned_measures_once_and_caches_winner(rng):
    x_shape = (1, 20, 20, 8)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    p = plan_conv2d(x_shape, w, algorithm="auto_tuned")
    assert p.algorithm in ("winograd", "winograd_f63", "fft", "im2col")
    report = p.spec.autotune_report
    assert report is not None
    assert report["winner"] == p.algorithm
    assert report["t_winograd_s"] > 0 and report["t_im2col_s"] > 0
    # second plan of the same shape: cache hit, no re-measurement
    before = plan_cache_info()["hits"]
    p2 = plan_conv2d(x_shape, w, algorithm="auto_tuned")
    assert plan_cache_info()["hits"] == before + 1
    assert p2.spec is p.spec


def test_auto_tuned_falls_back_to_heuristic_under_jit(rng):
    """Planning inside a jit trace cannot measure; the static amortization
    predicate decides instead, and tracing must not crash."""
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)

    @jax.jit
    def fwd(x, w):
        return plan_conv2d(x.shape, w, algorithm="auto_tuned").apply(x)

    x = jnp.asarray(rng.standard_normal((1, 20, 20, 8)), jnp.float32)
    assert rel_err(fwd(x, w), direct_conv2d(x, w)) < 1e-3


def test_auto_tuned_heuristic_fallback_is_not_cached(rng):
    """A heuristic decision made under a jit trace must not poison the cache:
    a later eager plan of the same shape still gets to measure."""
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 3, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 20, 20, 8)), jnp.float32)
    jax.jit(lambda x, w: plan_conv2d(x.shape, w,
                                     algorithm="auto_tuned").apply(x))(x, w)
    p = plan_conv2d(x.shape, w, algorithm="auto_tuned")   # eager: measures
    assert p.spec.autotune_report is not None


def test_auto_tuned_unsuitable_layer_skips_measurement(rng):
    # stride 3: no winograd-family capability (stride 2 has the strided
    # phase-decomposition executor now), so auto_tuned must not measure.
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    p = plan_conv2d((1, 12, 12, 4), w, stride=3, algorithm="auto_tuned")
    assert p.algorithm == "im2col"
    assert p.spec.autotune is None


def test_forced_winograd_on_uncovered_layer_raises(rng):
    """A forced algorithm with no matching capability raises the registry
    error, which must enumerate the executors that DO cover the layer."""
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    with pytest.raises(ValueError, match="no executor"):
        plan_conv2d((1, 12, 12, 4), w, stride=3, algorithm="winograd")
    with pytest.raises(ValueError, match="im2col"):
        plan_conv2d((1, 12, 12, 4), w, stride=3, algorithm="winograd")


def test_stride2_plans_to_winograd_family(rng):
    """Stride-2 3x3 layers plan onto the phase-decomposition executors."""
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) / 3, jnp.float32)
    p = plan_conv2d((1, 12, 12, 4), w, stride=2, algorithm="winograd")
    assert p.algorithm == "winograd_strided"
    from repro.core.im2col import direct_conv2d
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    assert rel_err(p.apply(x), direct_conv2d(x, w, stride=2)) < 1e-3


# ---------------------------------------------------------------------------
# conv1d plans (incl. polyphase stride-2)
# ---------------------------------------------------------------------------

def _direct_conv1d(x, w, stride):
    return jax.lax.conv_general_dilated(
        x[:, :, None], w[:, None], window_strides=(stride, 1),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))[:, :, 0]


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("length", [20, 33])
def test_conv1d_plan_matches_direct(rng, stride, length):
    x = jnp.asarray(rng.standard_normal((2, length, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 8, 6)) / 3, jnp.float32)
    p = plan_conv1d(x.shape, w, stride=stride)
    got = p.apply(x)
    want = _direct_conv1d(x, w, stride)
    assert got.shape == want.shape
    assert rel_err(got, want) < 1e-4
    assert p.mode == ("as2d" if stride == 1 else "polyphase")


def test_conv1d_polyphase_subplans_are_pretransformed(rng):
    """The polyphase decomposition plans each stride-1 sub-filter once."""
    x = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 8, 6)) / 3, jnp.float32)
    p = plan_conv1d(x.shape, w, stride=2)
    assert len(p.subplans) == 2
    assert all(isinstance(s, ConvPlan) for s in p.subplans)


# ---------------------------------------------------------------------------
# depthwise causal Cook-Toom conv1d plans (Mamba's short conv)
# ---------------------------------------------------------------------------

def _direct_depthwise_causal(x, w):
    r = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    return sum(xp[:, k:k + x.shape[1]] * w[k][None, None] for k in range(r))


@pytest.mark.parametrize("length,r", [(64, 4), (33, 4), (20, 3)])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_depthwise_plan_matches_direct(rng, length, r, backend):
    x = jnp.asarray(rng.standard_normal((2, length, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, 16)) / r, jnp.float32)
    p = plan_depthwise_conv1d(x.shape, w, backend=backend)
    got = p.apply(x)
    assert got.shape == x.shape
    assert rel_err(got, _direct_depthwise_causal(x, w)) < 1e-4


def test_depthwise_plan_decisions_are_cached(rng):
    """Second plan of the same (L, C) shape is a spec-cache hit: cook_toom,
    tile count, padding, and blocking are decided once per shape."""
    x_shape = (2, 48, 16)
    w = jnp.asarray(rng.standard_normal((4, 16)) / 4, jnp.float32)
    p1 = plan_depthwise_conv1d(x_shape, w)
    before = plan_cache_info()["hits"]
    p2 = plan_depthwise_conv1d(x_shape, w)
    assert plan_cache_info()["hits"] == before + 1
    assert p2.spec is p1.spec
    # batch may differ, L/C must match
    x5 = jnp.asarray(jnp.zeros((5,) + x_shape[1:]), jnp.float32)
    assert p1.apply(x5).shape == x5.shape
    with pytest.raises(ValueError, match="plan built for"):
        p1.apply(jnp.zeros((2, 47, 16), jnp.float32))


def test_depthwise_plan_taps_are_pretransformed(rng):
    """apply() never re-derives the transform set: u is already (t, C)."""
    w = jnp.asarray(rng.standard_normal((4, 8)) / 4, jnp.float32)
    p = plan_depthwise_conv1d((1, 32, 8), w)
    assert p.u.shape == (p.spec.ct.t, 8)
    assert p.spec.n_tiles == 8 and p.spec.ct.m == 4
