"""The fused-kernel scan path through a full Mamba block: forward and
gradients must match the XLA chunked path (REPRO_PALLAS_SCAN=1 exercises the
kernel in interpret mode on CPU)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import mamba as ssm

from conftest import rel_err


@pytest.fixture
def pallas_scan_env(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_SCAN", "1")


def test_mamba_block_kernel_path_matches_xla(rng, pallas_scan_env):
    cfg = cfglib.get_smoke_config("falcon_mamba_7b")
    p = ssm.init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    assert ssm._use_pallas_scan()
    y_kernel = ssm.mamba_block(p, x, cfg)
    # XLA path for comparison
    os.environ.pop("REPRO_PALLAS_SCAN")
    assert not ssm._use_pallas_scan()
    y_xla = ssm.mamba_block(p, x, cfg)
    assert rel_err(y_kernel, y_xla) < 1e-5


def test_mamba_block_kernel_path_gradients(rng, pallas_scan_env):
    """custom_vjp backward (recompute through the chunked path) must match
    differentiating the chunked path directly."""
    cfg = cfglib.get_smoke_config("falcon_mamba_7b")
    p = ssm.init_mamba(jax.random.key(1), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)

    def loss(p, x):
        return jnp.sum(jnp.square(ssm.mamba_block(p, x, cfg)))

    g_kernel = jax.grad(loss)(p, x)
    os.environ.pop("REPRO_PALLAS_SCAN")
    g_xla = jax.grad(loss)(p, x)
    for k in g_xla:
        assert rel_err(g_kernel[k], g_xla[k]) < 1e-4, k
