"""Depthwise causal Cook-Toom conv1d Pallas kernel (Mamba short conv).

The paper's 1D algorithm specialized to depthwise form. The per-point channel
GEMM degenerates to a lane-wise multiply (no channel reduction), so the whole
algorithm is VPU work: transform (adds/subs over the tile axis), one Hadamard
multiply per Winograd point, inverse transform. Multiplication count drops by
m*r/t per channel -- e.g. F(4,4): 16 -> 7 multiplies per 4 outputs (2.29x).

grid = (B, S / bS, C / bC) over pre-extracted tiles (B, S, t, C); everything
is elementwise over (bS, bC) so channels sit on the 128-lane axis (NHWC
argument again) and the sublane axis carries tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.transforms import CookToom
from repro.kernels.runtime import resolve_interpret


def _kernel(bt_ref, at_ref, x_ref, u_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)                     # (bS, t, C)
    v = jnp.tensordot(bt_ref[...], x, axes=(1, 1)).transpose(1, 0, 2)
    y = v * u_ref[...][None]                             # Hadamard per channel
    out = jnp.tensordot(at_ref[...], y, axes=(1, 1)).transpose(1, 0, 2)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "block_s", "block_c",
                                             "interpret"))
def conv1d_ct_fused(
    tiles: jax.Array,      # (B, S, t, C) pre-extracted causal tiles
    u: jax.Array,          # (t, C) Cook-Toom-domain depthwise taps
    *,
    ct: CookToom,
    block_s: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, S, m, C) output tiles. S % block_s == 0, C % block_c == 0.
    `interpret=None` resolves via the shared REPRO_PALLAS_COMPILE-aware rule
    (kernels.runtime)."""
    interpret = resolve_interpret(interpret)
    b, s, t, c = tiles.shape
    assert t == ct.t and u.shape == (t, c)
    assert s % block_s == 0 and c % block_c == 0, (tiles.shape, block_s, block_c)
    bt = jnp.asarray(ct.BT, jnp.float32)
    at = jnp.asarray(ct.AT, jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(b, s // block_s, c // block_c),
        in_specs=[
            pl.BlockSpec(bt.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec(at.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, block_s, t, block_c),
                         lambda i, j, k: (i, j, 0, k)),
            pl.BlockSpec((t, block_c), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, block_s, ct.m, block_c),
                               lambda i, j, k: (i, j, 0, k)),
        out_shape=jax.ShapeDtypeStruct((b, s, ct.m, c), tiles.dtype),
        interpret=interpret,
    )(bt, at, tiles, u)
