"""Fused Mamba-1 selective-scan Pallas kernel.

The XLA lowering of the chunked selective scan materializes every
associative-scan level as an HBM round-trip of the (B, chunk, d_in, N) state
transient -- measured 78 TB/device on the falcon train_4k cell even after the
in-chunk discretization restructure (EXPERIMENTS.md section Perf). The state
expansion (N=16) times the log2(chunk) scan levels is inherent to expressing
the recurrence in XLA ops.

This kernel is the structural fix, and the TPU analogue of the paper's core
move (keep the transformed domain in registers/VMEM, never touch memory in
the expanded domain):

  grid = (B, D / bD, L / chunk)   -- L innermost, sequential

  per step: load dt/xs (1, chunk, bD) and B/C (1, chunk, N) tiles, carry the
  (bD, N) fp32 state in a VMEM scratch across L steps, run the within-chunk
  associative scan entirely in VMEM, write back only y (1, chunk, bD).

HBM traffic therefore = inputs + outputs = B*L*(2 bD + 2N)*bytes per D-block,
i.e. the N-fold state expansion and the log-levels never leave VMEM. At
falcon train shapes that is ~130 GB/device/step vs 78 TB -- a ~600x cut on
the scan's share (the roofline accounting for the TPU path is derived
analytically in EXPERIMENTS.md; this container is CPU-only so the kernel
validates in interpret mode).

Channels ride the 128-lane axis (bD a multiple of 128), N on sublanes --
the paper's channels-innermost argument, applied to the SSM state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_F32 = jnp.float32


def _kernel(a_ref, dt_ref, xs_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            n_l: int):
    l_step = pl.program_id(2)

    @pl.when(l_step == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a_mat = a_ref[...]                                # (bD, N) fp32
    dt = dt_ref[0].astype(_F32)                       # (chunk, bD)
    xs = xs_ref[0].astype(_F32)                       # (chunk, bD)
    bmat = b_ref[0].astype(_F32)                      # (chunk, N)
    cmat = c_ref[0].astype(_F32)                      # (chunk, N)

    a_c = jnp.exp(dt[:, :, None] * a_mat[None])       # (chunk, bD, N)
    bx = (dt * xs)[:, :, None] * bmat[:, None, :]     # (chunk, bD, N)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_acc, b_acc = jax.lax.associative_scan(combine, (a_c, bx), axis=0)
    h_all = a_acc * h_ref[...][None] + b_acc          # (chunk, bD, N)
    y_ref[0] = jnp.einsum("lds,ls->ld", h_all, cmat).astype(y_ref.dtype)
    h_ref[...] = h_all[-1]

    @pl.when(l_step == n_l - 1)
    def _final():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(
    dt: jax.Array,        # (B, L, D) fp32/bf16
    xs: jax.Array,        # (B, L, D)
    bmat: jax.Array,      # (B, L, N)
    cmat: jax.Array,      # (B, L, N)
    a_mat: jax.Array,     # (D, N) fp32 (A = -exp(a_log))
    *,
    chunk: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, L, D) fp32, h_last (B, D, N) fp32).

    L % chunk == 0 and D % block_d == 0 (ops.py pads).
    """
    b, l, d = dt.shape
    n = a_mat.shape[-1]
    assert l % chunk == 0 and d % block_d == 0, (dt.shape, chunk, block_d)
    n_l = l // chunk
    grid = (b, d // block_d, n_l)
    y, h_last = pl.pallas_call(
        functools.partial(_kernel, n_l=n_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, n), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), _F32),
            jax.ShapeDtypeStruct((b, d, n), _F32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), _F32)],
        interpret=interpret,
    )(a_mat.astype(_F32), dt, xs, bmat, cmat)
    return y, h_last
