"""Streamed Pallas depthwise and fused separable (depthwise -> pointwise)
convolution kernels.

Depthwise layers are memory-bound (Zhang et al. 2020; Hao et al. 2022): the
per-channel transform-domain work is a Hadamard product, so the win lives
entirely in layout and fusion -- exactly what the halo-streaming machinery
from kernels/winograd.py provides. Both kernels reuse its structure: the
input BlockSpec reads overlapping halo strips of the padded NHWC input
(element-offset indexing), the gather into overlapping-tile layout happens
in VMEM, and the output BlockSpec scatters non-overlapping NHWC spatial
blocks. Halo blocking comes from the same plan-time chooser
(core/winograd.py:stream_geometry via stream_geometry_depthwise).

`depthwise_streamed` -- grid (N, nHb, nWb, C/bC). One pass, no reduction
axis: per step, transform the halo strip (B^T (.) B), multiply elementwise
by the (P, bC) Winograd-domain taps, inverse-transform (A^T (.) A), run the
fused bias+activation epilogue, and scatter the NHWC block. The only HBM
tensors are the padded input and the output.

`depthwise_strided_streamed` -- the stride-2 depthwise kernel (MobileNet
reduction blocks): same structure with the halo strip covering the
full-resolution input and four phase tile tensors gathered in VMEM; the
phase Hadamard products accumulate in the transform domain (shared A^T),
one inverse transform, one store.

`separable_streamed` -- the fused MobileNet block: depthwise k x k ->
bias+activation -> pointwise 1x1 -> bias+activation, in ONE kernel. Grid
(N, nHb, nWb, M/bM, C/bC) with C innermost, mirroring the dense streaming
kernel's (M, C) sweep: on the first M step of each strip the depthwise
output block for channel slice cb is computed in VMEM and cached (the
z-cache below, the analogue of the dense kernel's transformed-input cache);
every step then runs one (S, bC) x (bC, bM) pointwise GEMM into the fp32
accumulator; the last C step applies the pointwise epilogue and stores the
NHWC block. The depthwise -> pointwise intermediate NEVER touches HBM --
that round trip (write + re-read per pointwise M block + separate epilogue
passes) is precisely what the unfused baseline pays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.transforms import CookToom
from repro.kernels.runtime import apply_activation, resolve_interpret


def _depthwise_block(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, strip, taps,
                     bias, *, bh: int, bw: int, activation: str, scale=None):
    """Shared depthwise compute: halo strip (Hs, Ws, bC) -> spatial block
    (bh*mh, bw*mw, bC*mult), all in VMEM/registers. `taps` is the (P, bC)
    or (P, bC, mult) Winograd-domain filter slice (channel multiplier > 1
    fans each input channel out to `mult` outputs, o = c*mult + j -- the
    lax feature_group_count ordering); `bias` the (bC*mult,) epilogue bias
    or None; `scale` the (bC*mult,) int8-dequant scale (applied before the
    bias, after the inverse transform) or None."""
    mh, th = at_h_ref.shape
    mw, tw = at_w_ref.shape
    bc = strip.shape[-1]
    if taps.ndim == 2:                                  # mult-1 callers
        taps = taps[:, :, None]
    mult = taps.shape[-1]
    # VMEM gather: halo strip -> (tw, th, bh, bw, bC) overlapping tiles,
    # offset-major (th + tw static strided slices, as in the dense kernel).
    rows = jnp.stack([strip[r:r + (bh - 1) * mh + 1:mh]
                      for r in range(th)], 0)           # (th, bh, Ws, bC)
    xt = jnp.stack([rows[:, :, q:q + (bw - 1) * mw + 1:mw]
                    for q in range(tw)], 0)             # (tw, th, bh, bw, bC)
    # input transform B^T (.) B: contract tile axes, (bh, bw, bC) rides.
    v = jnp.tensordot(bt_h_ref[...], xt, axes=(1, 1))   # (i, tw, bh, bw, bC)
    v = jnp.tensordot(bt_w_ref[...], v, axes=(1, 1))    # (j, i, bh, bw, bC)
    # depthwise phase 2: Hadamard over channels -- the channel GEMM of the
    # dense kernel degenerates to an elementwise multiply per Winograd
    # point; the transformed input broadcasts over the multiplier axis.
    u = taps.astype(jnp.float32).reshape(th, tw, bc, mult)
    u = u.transpose(1, 0, 2, 3)                         # (tw, th, bC, mult)
    y = v[..., None] * u[:, :, None, None, :, :]        # (j, i, bh, bw, bC, m)
    # output transform A^T (.) A.
    out = jnp.tensordot(at_h_ref[...], y, axes=(1, 1))  # (mi, j, bh, bw, bC, m)
    out = jnp.tensordot(at_w_ref[...], out,
                        axes=(1, 1))                    # (mj, mi, bh, bw, bC, m)
    if scale is not None:
        out = out * scale.reshape(bc, mult)[None, None, None, None]
    if bias is not None:
        out = out + bias.reshape(bc, mult)[None, None, None, None]
    out = apply_activation(out, activation)
    # un-tile to the (bh*mh, bw*mw, bC*mult) NHWC spatial block, in VMEM.
    out = out.transpose(2, 1, 3, 0, 4, 5)               # (bh, mi, bw, mj, bC, m)
    return out.reshape(bh * mh, bw * mw, bc * mult)


def _depthwise_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref, u_ref,
                      bias_ref, scale_ref, o_ref, *, bh: int, bw: int,
                      activation: str, has_bias: bool, has_scale: bool):
    strip = x_ref[0].astype(jnp.float32)                # (Hs, Ws, bC)
    bias = bias_ref[0] if has_bias else None
    scale = scale_ref[0] if has_scale else None
    o_ref[0] = _depthwise_block(
        bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, strip, u_ref[...], bias,
        bh=bh, bw=bw, activation=activation, scale=scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "ct_h", "ct_w", "bh", "bw", "block_c", "activation", "interpret"))
def depthwise_streamed(
    xp: jax.Array,           # (N, Hp, Wp, Cp) halo-padded NHWC input
    u: jax.Array,            # (P, Cp, mult) Winograd-domain depthwise taps
    bias: jax.Array | None,  # (1, Cp*mult) fp32 epilogue bias, or None
    scale: jax.Array | None = None,  # (1, Cp*mult) int8-dequant scale, or None
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    bh: int,
    bw: int,
    block_c: int = 128,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Halo-streaming depthwise transform+Hadamard+inverse+epilogue.

    `xp` must be padded so Hp = nHb*bh*mh + (th - mh) and
    Wp = nWb*bw*mw + (tw - mw) for integer strip counts (ops.py pads from
    the plan's StreamGeometry). The taps carry the channel multiplier as a
    trailing axis; output channel o = c*mult + j (the lax
    feature_group_count ordering). Returns
    (N, nHb*bh*mh, nWb*bw*mw, Cp*mult); the caller crops the geometry
    surplus.
    """
    interpret = resolve_interpret(interpret)
    n, hp, wp, c = xp.shape
    p, c2, mult = u.shape
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    sh, sw = bh * mh, bw * mw
    hs, ws = sh + th - mh, sw + tw - mw
    assert p == th * tw and c == c2, (xp.shape, u.shape)
    assert c % block_c == 0, (xp.shape, block_c)
    n_hb, rh = divmod(hp - (th - mh), sh)
    n_wb, rw = divmod(wp - (tw - mw), sw)
    assert rh == 0 and rw == 0, (xp.shape, (bh, bw), (mh, mw))
    grid = (n, n_hb, n_wb, c // block_c)

    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, c * mult), jnp.float32)
    has_scale = scale is not None
    if scale is None:
        scale = jnp.ones((1, c * mult), jnp.float32)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda n_, i, j, cb: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_depthwise_kernel, bh=bh, bw=bw,
                          activation=activation, has_bias=has_bias,
                          has_scale=has_scale),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            pl.BlockSpec((1, hs, ws, block_c),
                         lambda n_, i, j, cb: (n_, i * sh, j * sw,
                                               cb * block_c),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((p, block_c, mult), lambda n_, i, j, cb: (0, cb, 0)),
            pl.BlockSpec((1, block_c * mult), lambda n_, i, j, cb: (0, cb)),
            pl.BlockSpec((1, block_c * mult), lambda n_, i, j, cb: (0, cb)),
        ],
        out_specs=pl.BlockSpec((1, sh, sw, block_c * mult),
                               lambda n_, i, j, cb: (n_, i, j, cb)),
        out_shape=jax.ShapeDtypeStruct((n, n_hb * sh, n_wb * sw, c * mult),
                                       xp.dtype),
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, xp, u, bias, scale)


# ---------------------------------------------------------------------------
# Stride-2 streamed depthwise kernel (transform-domain phase decomposition)
# ---------------------------------------------------------------------------

def _depthwise_strided_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref,
                              u_ref, bias_ref, scale_ref, o_ref, *, bh: int,
                              bw: int, activation: str, has_bias: bool,
                              has_scale: bool):
    from repro.kernels.winograd import phase_gather_tiles
    strip = x_ref[0].astype(jnp.float32)             # (Hs, Ws, bC)
    mh, th = at_h_ref.shape
    mw, tw = at_w_ref.shape
    bc = strip.shape[-1]
    p = th * tw
    # Four phase sub-grids from one full-resolution halo strip; each phase's
    # Hadamard product accumulates in the transform domain (shared A^T), so
    # there is ONE inverse transform and one store.
    acc = None
    for idx, (ph, qh) in enumerate(((0, 0), (0, 1), (1, 0), (1, 1))):
        xt = phase_gather_tiles(strip, th, tw, mh, mw, bh, bw, ph, qh)
        v = jnp.tensordot(bt_h_ref[...], xt, axes=(1, 1))
        v = jnp.tensordot(bt_w_ref[...], v, axes=(1, 1))  # (j, i, bh, bw, bC)
        u = u_ref[idx * p:(idx + 1) * p].astype(jnp.float32)
        u = u.reshape(th, tw, bc).transpose(1, 0, 2)
        y = v * u[:, :, None, None, :]
        acc = y if acc is None else acc + y
    out = jnp.tensordot(at_h_ref[...], acc, axes=(1, 1))
    out = jnp.tensordot(at_w_ref[...], out, axes=(1, 1))  # (mj, mi, bh, bw, bC)
    if has_scale:
        out = out * scale_ref[0][None, None, None, None, :]
    if has_bias:
        out = out + bias_ref[0][None, None, None, None, :]
    out = apply_activation(out, activation)
    out = out.transpose(2, 1, 3, 0, 4)               # (bh, mi, bw, mj, bC)
    o_ref[0] = out.reshape(bh * mh, bw * mw, bc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "ct_h", "ct_w", "bh", "bw", "block_c", "activation", "interpret"))
def depthwise_strided_streamed(
    xp: jax.Array,           # (N, Hp, Wp, Cp) halo-padded full-res input
    u: jax.Array,            # (4P, Cp) phase-major Winograd-domain taps
    bias: jax.Array | None,  # (1, Cp) fp32 epilogue bias, or None
    scale: jax.Array | None = None,  # (1, Cp) fp32 int8-dequant scale, or None
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    bh: int,
    bw: int,
    block_c: int = 128,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Stride-2 streamed depthwise conv via transform-domain phase
    decomposition: the MobileNet reduction-block depthwise layer as one
    halo-streaming kernel (fused epilogue, no phase tensors in HBM).

    `xp` must be padded so Hp = nHb*2*bh*mh + 2*(th - mh) and likewise for
    Wp (ops.py pads from the plan's StreamGeometry). Returns the stride-2
    output grid (N, nHb*bh*mh, nWb*bw*mw, Cp); the caller crops.
    """
    interpret = resolve_interpret(interpret)
    n, hp, wp, c = xp.shape
    p4, c2 = u.shape
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    so_h, so_w = bh * mh, bw * mw
    hs = 2 * (so_h + th - mh)
    ws = 2 * (so_w + tw - mw)
    assert p4 == 4 * th * tw and c == c2, (xp.shape, u.shape)
    assert c % block_c == 0, (xp.shape, block_c)
    n_hb, rh = divmod(hp - 2 * (th - mh), 2 * so_h)
    n_wb, rw = divmod(wp - 2 * (tw - mw), 2 * so_w)
    assert rh == 0 and rw == 0, (xp.shape, (bh, bw), (mh, mw))
    grid = (n, n_hb, n_wb, c // block_c)

    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, c), jnp.float32)
    has_scale = scale is not None
    if scale is None:
        scale = jnp.ones((1, c), jnp.float32)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda n_, i, j, cb: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_depthwise_strided_kernel, bh=bh, bw=bw,
                          activation=activation, has_bias=has_bias,
                          has_scale=has_scale),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            pl.BlockSpec((1, hs, ws, block_c),
                         lambda n_, i, j, cb: (n_, i * 2 * so_h,
                                               j * 2 * so_w, cb * block_c),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((p4, block_c), lambda n_, i, j, cb: (0, cb)),
            pl.BlockSpec((1, block_c), lambda n_, i, j, cb: (0, cb)),
            pl.BlockSpec((1, block_c), lambda n_, i, j, cb: (0, cb)),
        ],
        out_specs=pl.BlockSpec((1, so_h, so_w, block_c),
                               lambda n_, i, j, cb: (n_, i, j, cb)),
        out_shape=jax.ShapeDtypeStruct((n, n_hb * so_h, n_wb * so_w, c),
                                       xp.dtype),
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, xp, u, bias, scale)


# ---------------------------------------------------------------------------
# Fused separable block: depthwise -> epilogue -> pointwise -> epilogue
# ---------------------------------------------------------------------------

def _separable_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref, udw_ref,
                      upw_ref, bdw_ref, bpw_ref, o_ref, acc_ref, z_ref, *,
                      n_c: int, bh: int, bw: int, block_c: int,
                      inner_activation: str, activation: str,
                      has_bias_dw: bool, has_bias_pw: bool):
    m_step = pl.program_id(3)
    c_step = pl.program_id(4)

    @pl.when(c_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mh, th = at_h_ref.shape
    mw, tw = at_w_ref.shape
    sh, sw = bh * mh, bw * mw

    # Depthwise stage runs once per (strip, C block) -- the first M step
    # fills the z cache with the post-epilogue depthwise output, later M
    # steps reuse it (the analogue of the dense kernel's transformed-input
    # cache). The intermediate lives only in this VMEM scratch.
    @pl.when(m_step == 0)
    def _dw():
        strip = x_ref[0].astype(jnp.float32)            # (Hs, Ws, bC)
        bias = bdw_ref[0] if has_bias_dw else None
        z = _depthwise_block(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, strip,
                             udw_ref[...], bias, bh=bh, bw=bw,
                             activation=inner_activation)
        z_ref[c_step] = z.reshape(sh * sw, block_c)

    # pointwise stage: one (S, bC) x (bC, bM) GEMM per step, fp32 accumulate.
    acc_ref[...] += jnp.dot(z_ref[c_step], upw_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(c_step == n_c - 1)
    def _store():
        out = acc_ref[...]
        if has_bias_pw:
            out = out + bpw_ref[0][None, :]
        out = apply_activation(out, activation)
        o_ref[0] = out.reshape(sh, sw, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "ct_h", "ct_w", "bh", "bw", "block_c", "block_m", "inner_activation",
    "activation", "interpret"))
def separable_streamed(
    xp: jax.Array,            # (N, Hp, Wp, Cp) halo-padded NHWC input
    u_dw: jax.Array,          # (P, Cp) Winograd-domain depthwise taps
    u_pw: jax.Array,          # (Cp, Mp) pointwise filter matrix
    bias_dw: jax.Array | None,   # (1, Cp) fp32 depthwise bias, or None
    bias_pw: jax.Array | None,   # (1, Mp) fp32 pointwise bias, or None
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    bh: int,
    bw: int,
    block_c: int = 128,
    block_m: int = 128,
    inner_activation: str = "none",
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused separable block over the halo-padded input: depthwise Winograd
    + bias/activation + pointwise 1x1 + bias/activation in one kernel; the
    depthwise -> pointwise intermediate never leaves VMEM. Returns
    (N, nHb*bh*mh, nWb*bw*mw, Mp); the caller crops the geometry surplus.
    """
    interpret = resolve_interpret(interpret)
    n, hp, wp, c = xp.shape
    p, c2 = u_dw.shape
    c3, m = u_pw.shape
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    sh, sw = bh * mh, bw * mw
    hs, ws = sh + th - mh, sw + tw - mw
    assert p == th * tw and c == c2 == c3, (xp.shape, u_dw.shape, u_pw.shape)
    assert c % block_c == 0 and m % block_m == 0, (xp.shape, u_pw.shape,
                                                   (block_c, block_m))
    n_hb, rh = divmod(hp - (th - mh), sh)
    n_wb, rw = divmod(wp - (tw - mw), sw)
    assert rh == 0 and rw == 0, (xp.shape, (bh, bw), (mh, mw))
    n_c = c // block_c
    grid = (n, n_hb, n_wb, m // block_m, n_c)

    has_bias_dw = bias_dw is not None
    has_bias_pw = bias_pw is not None
    if bias_dw is None:
        bias_dw = jnp.zeros((1, c), jnp.float32)
    if bias_pw is None:
        bias_pw = jnp.zeros((1, m), jnp.float32)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda n_, i, j, mb, cb: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_separable_kernel, n_c=n_c, bh=bh, bw=bw,
                          block_c=block_c, inner_activation=inner_activation,
                          activation=activation, has_bias_dw=has_bias_dw,
                          has_bias_pw=has_bias_pw),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            pl.BlockSpec((1, hs, ws, block_c),
                         lambda n_, i, j, mb, cb: (n_, i * sh, j * sw,
                                                   cb * block_c),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((p, block_c), lambda n_, i, j, mb, cb: (0, cb)),
            pl.BlockSpec((block_c, block_m),
                         lambda n_, i, j, mb, cb: (cb, mb)),
            pl.BlockSpec((1, block_c), lambda n_, i, j, mb, cb: (0, cb)),
            pl.BlockSpec((1, block_m), lambda n_, i, j, mb, cb: (0, mb)),
        ],
        out_specs=pl.BlockSpec((1, sh, sw, block_m),
                               lambda n_, i, j, mb, cb: (n_, i, j, mb)),
        out_shape=jax.ShapeDtypeStruct((n, n_hb * sh, n_wb * sw, m),
                                       xp.dtype),
        scratch_shapes=[pltpu.VMEM((sh * sw, block_m), jnp.float32),
                        # depthwise-output cache: filled on the first M step
                        # of each strip, reused by the rest of the (M, C)
                        # sweep -- the fused block's only "intermediate".
                        pltpu.VMEM((n_c, sh * sw, block_c), jnp.float32)],
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, xp, u_dw, u_pw, bias_dw, bias_pw)
