"""Fused region-wise multi-channel Winograd convolution Pallas kernels.

TPU-native adaptation of the paper's three-phase scheme. The paper stages
(input transform -> scatter to matrices in memory -> GEMMs -> gather -> output
transform) through L1/L2; on TPU we instead *fuse* all three phases in VMEM.

Three kernels live here:

`winograd_streamed` -- the halo-aware region-streaming kernel (the planned
hot path). Nothing but the NHWC input and the NHWC output ever touches HBM:

  grid = (N,  nHb,  nWb,  M / bM,  C / bC)     # C innermost: accumulation

  per step:
    1. the input BlockSpec reads an *overlapping* halo strip of the padded
       NHWC input directly from HBM (element-offset / Unblocked indexing:
       strip (i, j) starts at (i * bh * mh, j * bw * mw) and extends k - 1
       rows/cols past the next strip's origin). The gather into the
       (bR, th, tw, bC) overlapping-tile layout happens in VMEM -- a fixed
       pattern of static slices -- so the ~(t/m)^2 read-amplified tile tensor
       the pre-streaming path materialized in HBM never exists;
    2. apply B^T (.) B -- small matmuls over the tile axes, vectorized over
       (bR, bC); channels stay on the 128-lane axis (the paper's NHWC/NEON
       argument, 128 lanes wide instead of 4); then one *batched* dot_general
       over the P = th*tw Winograd points: (P, bR, bC) x (P, bC, bM) ->
       accumulate (P, bR, bM) fp32 in VMEM. This is the paper's "array of
       GEMMs", batched so the MXU pipeline never drains between points;
    3. on the last C step, apply A^T (.) A, run the fused epilogue
       (bias add + none/relu/gelu), and scatter the (bh*mh, bw*mw, bM)
       spatial block straight into the NHWC output -- no post-kernel
       un-tiling transpose/reshape pass.

`winograd_strided_streamed` -- the stride-2 variant via transform-domain
phase decomposition: the halo strip covers the full-resolution input (origin
stride and extent doubled), the VMEM gather extracts FOUR phase tile
tensors (x[p::2, q::2] sub-grids), each is transformed with the shared
F(m, (k+1)/2) B^T (the filter was zero-padded to even size at plan time),
and the four phase GEMM banks accumulate into ONE (P, bR, bM) accumulator
-- the cross-phase sum happens in the transform domain, so there is a
single inverse transform and NHWC store with the fused epilogue.

`winograd_fused` -- the pre-streaming kernel over pre-extracted tiles
(grid (R/bR, M/bM, C/bC)), kept as the A/B baseline the benchmarks measure
the streaming win against (benchmarks/per_layer.py, BENCH_PR2.json) and for
callers that already hold a tile tensor.

The Winograd-domain tensors (the paper's scattered 'A'/'C' matrices) never
touch HBM in either kernel; the streaming kernel additionally keeps the
overlapping-tile tensor and the separate bias/activation round trips out of
HBM. The HBM-bytes accounting is in EXPERIMENTS.md section Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.transforms import CookToom
from repro.kernels.runtime import apply_activation, resolve_interpret


def _apply_pair(mat_h, mat_w, x):
    """einsum('it,brtuc,ju->bricj'-free): y[b,i,j,c] = sum_tu H[i,t] W[j,u] x[b,t,u,c].

    x: (bR, th, tw, bC). Contractions kept as dots on the small tile axes so
    the (bR, bC) payload axes ride along untouched (lane dim = channels).
    """
    # contract th: (i,t) x (b,t,u,c) -> (b,i,u,c)
    y = jnp.tensordot(mat_h, x, axes=(1, 1)).transpose(1, 0, 2, 3)
    # contract tw: (j,u) x (b,i,u,c) -> (b,i,j,c)
    y = jnp.tensordot(mat_w, y, axes=(1, 2)).transpose(1, 2, 0, 3)
    return y


# ---------------------------------------------------------------------------
# Halo-aware region-streaming kernel
# ---------------------------------------------------------------------------

def _streamed_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref, u_ref,
                     bias_ref, scale_ref, o_ref, acc_ref, v_ref, *, n_c: int,
                     bh: int, bw: int, block_c: int, activation: str,
                     has_bias: bool, has_scale: bool):
    m_step = pl.program_id(3)
    c_step = pl.program_id(4)

    @pl.when(c_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mh, th = at_h_ref.shape
    mw, tw = at_w_ref.shape
    br = bh * bw

    # The strip's block index carries the channel slice, so the halo DMA
    # recurs per (M sweep, C block); the gather+transform below runs only
    # once per (strip, C block) -- the first M step fills the v cache,
    # later M steps reuse it.
    @pl.when(m_step == 0)
    def _transform():
        strip = x_ref[0].astype(jnp.float32)         # (Hs, Ws, bC)
        # VMEM gather: halo strip -> (th, tw, bh, bw, bC) overlapping tiles,
        # offset-major: one strided slice per in-tile offset (th + tw static
        # slices total, independent of the region-block size), unrolled at
        # trace time. Offset-major means the tile axes land leading, which
        # is exactly the layout the transform contractions below want -- no
        # region-major transpose of the big tensor ever happens.
        rows = jnp.stack([strip[r:r + (bh - 1) * mh + 1:mh]
                          for r in range(th)], 0)         # (th, bh, Ws, bC)
        x = jnp.stack([rows[:, :, q:q + (bw - 1) * mw + 1:mw]
                       for q in range(tw)], 0)            # (tw, th, bh, bw, bC)
        # input transform B^T (.) B: contract tile axes, (bh, bw, bC) rides.
        v = jnp.tensordot(bt_h_ref[...], x, axes=(1, 1))  # (i, tw, bh, bw, bC)
        v = jnp.tensordot(bt_w_ref[...], v, axes=(1, 1))  # (j, i, bh, bw, bC)
        v_ref[c_step] = v.transpose(1, 0, 2, 3, 4).reshape(
            th * tw, br, block_c)                         # (P, bR, bC)

    u = u_ref[...]                                   # (P, bC, bM)
    # batched point-GEMM: the paper's t^2 GEMMs as one dot_general.
    acc_ref[...] += jax.lax.dot_general(
        v_ref[c_step], u.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (P, bR, bM)

    @pl.when(c_step == n_c - 1)
    def _store():
        bm_ = acc_ref.shape[-1]
        y = acc_ref[...].reshape(th, tw, bh, bw, bm_)
        # output transform A^T (.) A, same contraction pattern.
        out = jnp.tensordot(at_h_ref[...], y, axes=(1, 0))   # (mi, tw, bh, bw, bM)
        out = jnp.tensordot(at_w_ref[...], out, axes=(1, 1)) # (mj, mi, bh, bw, bM)
        # fused epilogue: int8 dequantization (per-output-channel scale,
        # commutes with the inverse transform) + bias + activation on the
        # fp32 accumulator, in VMEM.
        if has_scale:
            out = out * scale_ref[0][None, None, None, None, :]
        if has_bias:
            out = out + bias_ref[0][None, None, None, None, :]
        out = apply_activation(out, activation)
        # NHWC scatter: un-tile to (bh*mh, bw*mw) in VMEM and write the
        # spatial block straight into the NHWC output.
        out = out.transpose(2, 1, 3, 0, 4)               # (bh, mi, bw, mj, bM)
        o_ref[0] = out.reshape(bh * mh, bw * mw, bm_).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "ct_h", "ct_w", "bh", "bw", "block_c", "block_m", "activation",
    "interpret"))
def winograd_streamed(
    xp: jax.Array,           # (N, Hp, Wp, Cp) halo-padded NHWC input
    u: jax.Array,            # (P, Cp, Mp) Winograd-domain filter (P = th*tw)
    bias: jax.Array | None,  # (1, Mp) fp32 epilogue bias, or None
    scale: jax.Array | None = None,  # (1, Mp) fp32 int8-dequant scale, or None
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    bh: int,
    bw: int,
    block_c: int = 128,
    block_m: int = 128,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Halo-streaming transform+GEMM+inverse+epilogue over the padded input.

    `xp` must be padded so Hp = nHb*bh*mh + (th - mh) and
    Wp = nWb*bw*mw + (tw - mw) for integer strip counts nHb/nWb (ops.py pads
    from the plan's StreamGeometry). Returns (N, nHb*bh*mh, nWb*bw*mw, Mp)
    NHWC output; the caller crops the geometry surplus.
    """
    interpret = resolve_interpret(interpret)
    n, hp, wp, c = xp.shape
    p, c2, m = u.shape
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    sh, sw = bh * mh, bw * mw                        # strip origin stride
    hs, ws = sh + th - mh, sw + tw - mw              # halo strip extent
    assert p == th * tw and c == c2, (xp.shape, u.shape)
    assert c % block_c == 0 and m % block_m == 0, (xp.shape, u.shape,
                                                   (block_c, block_m))
    n_hb, rh = divmod(hp - (th - mh), sh)
    n_wb, rw = divmod(wp - (tw - mw), sw)
    assert rh == 0 and rw == 0, (xp.shape, (bh, bw), (mh, mw))
    n_c = c // block_c
    grid = (n, n_hb, n_wb, m // block_m, n_c)

    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, m), jnp.float32)
    has_scale = scale is not None
    if scale is None:
        scale = jnp.ones((1, m), jnp.float32)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda n_, i, j, mb, cb: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_streamed_kernel, n_c=n_c, bh=bh, bw=bw,
                          block_c=block_c, activation=activation,
                          has_bias=has_bias, has_scale=has_scale),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            # overlapping halo strips: element-offset indexing; strip (i, j)
            # origin is (i*sh, j*sw), extent (hs, ws) with hs > sh, ws > sw.
            pl.BlockSpec((1, hs, ws, block_c),
                         lambda n_, i, j, mb, cb: (n_, i * sh, j * sw,
                                                   cb * block_c),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((p, block_c, block_m),
                         lambda n_, i, j, mb, cb: (0, cb, mb)),
            pl.BlockSpec((1, block_m), lambda n_, i, j, mb, cb: (0, mb)),
            pl.BlockSpec((1, block_m), lambda n_, i, j, mb, cb: (0, mb)),
        ],
        out_specs=pl.BlockSpec((1, sh, sw, block_m),
                               lambda n_, i, j, mb, cb: (n_, i, j, mb)),
        out_shape=jax.ShapeDtypeStruct((n, n_hb * sh, n_wb * sw, m), xp.dtype),
        scratch_shapes=[pltpu.VMEM((p, bh * bw, block_m), jnp.float32),
                        # transformed-input cache: filled on the first M
                        # step of each strip, reused by the rest of the
                        # (M, C) sweep.
                        pltpu.VMEM((n_c, p, bh * bw, block_c), jnp.float32)],
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, xp, u, bias, scale)


# ---------------------------------------------------------------------------
# Stride-2 halo-streaming kernel (transform-domain phase decomposition)
# ---------------------------------------------------------------------------

def phase_gather_tiles(strip, th: int, tw: int, mh: int, mw: int, bh: int,
                       bw: int, ph: int, qh: int, *, stride: int = 2):
    """VMEM gather of ONE phase's overlapping tiles from a full-resolution
    halo strip: phase (ph, qh) element (a, b) of output tile (i, j) lives at
    strip[stride*(i*mh + a) + ph, stride*(j*mw + b) + qh]. Same static
    strided-slice structure as the stride-1 gather (th + tw slices per
    phase), so the read-amplified phase tensors never exist in HBM.
    Returns (tw, th, bh, bw, bC). Shared by the dense and depthwise strided
    streaming kernels."""
    rows = jnp.stack(
        [strip[stride * r + ph:
               stride * r + ph + (bh - 1) * stride * mh + 1: stride * mh]
         for r in range(th)], 0)                     # (th, bh, Ws, bC)
    return jnp.stack(
        [rows[:, :, stride * q + qh:
              stride * q + qh + (bw - 1) * stride * mw + 1: stride * mw]
         for q in range(tw)], 0)                     # (tw, th, bh, bw, bC)


def _strided_streamed_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref,
                             u_ref, bias_ref, scale_ref, o_ref, acc_ref,
                             v_ref, *, n_c: int, bh: int, bw: int,
                             block_c: int, activation: str, has_bias: bool,
                             has_scale: bool):
    m_step = pl.program_id(3)
    c_step = pl.program_id(4)

    @pl.when(c_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mh, th = at_h_ref.shape
    mw, tw = at_w_ref.shape
    br = bh * bw
    p = th * tw

    # Four phase sub-grids are gathered from ONE full-resolution halo strip
    # and transformed with the shared B^T (all phases use the same F(m, r_ph)
    # set -- the filter was zero-padded to even size at plan time). The
    # transformed phases stack into a (4P, bR, bC) tensor cached across the
    # M sweep, exactly like the stride-1 kernel's transformed-input cache.
    @pl.when(m_step == 0)
    def _transform():
        strip = x_ref[0].astype(jnp.float32)         # (Hs, Ws, bC)
        vs = []
        for ph in (0, 1):
            for qh in (0, 1):
                xt = phase_gather_tiles(strip, th, tw, mh, mw, bh, bw,
                                        ph, qh)
                v = jnp.tensordot(bt_h_ref[...], xt, axes=(1, 1))
                v = jnp.tensordot(bt_w_ref[...], v, axes=(1, 1))
                vs.append(v.transpose(1, 0, 2, 3, 4).reshape(p, br, block_c))
        v_ref[c_step] = jnp.concatenate(vs, 0)       # (4P, bR, bC)

    u = u_ref[...]                                   # (4P, bC, bM)
    # batched phase-GEMMs: 4P point-GEMMs as one dot_general; the phase sum
    # happens in the transform domain (one shared A^T), so the accumulator
    # stays (P, bR, bM) -- four GEMM banks, ONE inverse transform.
    y = jax.lax.dot_general(
        v_ref[c_step], u.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (4P, bR, bM)
    acc_ref[...] += y.reshape(4, p, br, y.shape[-1]).sum(0)

    @pl.when(c_step == n_c - 1)
    def _store():
        bm_ = acc_ref.shape[-1]
        y = acc_ref[...].reshape(th, tw, bh, bw, bm_)
        out = jnp.tensordot(at_h_ref[...], y, axes=(1, 0))
        out = jnp.tensordot(at_w_ref[...], out, axes=(1, 1))
        if has_scale:
            out = out * scale_ref[0][None, None, None, None, :]
        if has_bias:
            out = out + bias_ref[0][None, None, None, None, :]
        out = apply_activation(out, activation)
        out = out.transpose(2, 1, 3, 0, 4)           # (bh, mi, bw, mj, bM)
        o_ref[0] = out.reshape(bh * mh, bw * mw, bm_).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "ct_h", "ct_w", "bh", "bw", "block_c", "block_m", "activation",
    "interpret"))
def winograd_strided_streamed(
    xp: jax.Array,           # (N, Hp, Wp, Cp) halo-padded full-res input
    u: jax.Array,            # (4P, Cp, Mp) phase-major Winograd-domain filter
    bias: jax.Array | None,  # (1, Mp) fp32 epilogue bias, or None
    scale: jax.Array | None = None,  # (1, Mp) fp32 int8-dequant scale, or None
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    bh: int,
    bw: int,
    block_c: int = 128,
    block_m: int = 128,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Stride-2 halo-streaming Winograd conv via transform-domain phase
    decomposition: four phase input-transforms + GEMM banks per strip, one
    accumulator, one inverse transform, one NHWC store with fused epilogue.

    `xp` must be padded so Hp = nHb*2*bh*mh + 2*(th - mh) and likewise for
    Wp (ops.py pads from the plan's StreamGeometry; 2*(th - mh) = k - 1 is
    the stride-2 halo). Returns the (N, nHb*bh*mh, nWb*bw*mw, Mp) stride-2
    output grid; the caller crops the geometry surplus.
    """
    interpret = resolve_interpret(interpret)
    n, hp, wp, c = xp.shape
    p4, c2, m = u.shape
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    so_h, so_w = bh * mh, bw * mw                    # output strip extents
    hs = 2 * (so_h + th - mh)                        # input halo strip extents
    ws = 2 * (so_w + tw - mw)
    assert p4 == 4 * th * tw and c == c2, (xp.shape, u.shape)
    assert c % block_c == 0 and m % block_m == 0, (xp.shape, u.shape,
                                                   (block_c, block_m))
    n_hb, rh = divmod(hp - 2 * (th - mh), 2 * so_h)
    n_wb, rw = divmod(wp - 2 * (tw - mw), 2 * so_w)
    assert rh == 0 and rw == 0, (xp.shape, (bh, bw), (mh, mw))
    n_c = c // block_c
    grid = (n, n_hb, n_wb, m // block_m, n_c)

    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, m), jnp.float32)
    has_scale = scale is not None
    if scale is None:
        scale = jnp.ones((1, m), jnp.float32)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda n_, i, j, mb, cb: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_strided_streamed_kernel, n_c=n_c, bh=bh, bw=bw,
                          block_c=block_c, activation=activation,
                          has_bias=has_bias, has_scale=has_scale),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            # full-resolution halo strips: origin stride doubles (strip
            # (i, j) starts at (2*i*so_h, 2*j*so_w)), extent k-1 past the
            # next strip's origin -- same element-offset structure as the
            # stride-1 kernel, scaled by the input stride.
            pl.BlockSpec((1, hs, ws, block_c),
                         lambda n_, i, j, mb, cb: (n_, i * 2 * so_h,
                                                   j * 2 * so_w,
                                                   cb * block_c),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((p4, block_c, block_m),
                         lambda n_, i, j, mb, cb: (0, cb, mb)),
            pl.BlockSpec((1, block_m), lambda n_, i, j, mb, cb: (0, mb)),
            pl.BlockSpec((1, block_m), lambda n_, i, j, mb, cb: (0, mb)),
        ],
        out_specs=pl.BlockSpec((1, so_h, so_w, block_m),
                               lambda n_, i, j, mb, cb: (n_, i, j, mb)),
        out_shape=jax.ShapeDtypeStruct((n, n_hb * so_h, n_wb * so_w, m),
                                       xp.dtype),
        scratch_shapes=[pltpu.VMEM((th * tw, bh * bw, block_m), jnp.float32),
                        pltpu.VMEM((n_c, p4, bh * bw, block_c), jnp.float32)],
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, xp, u, bias, scale)


# ---------------------------------------------------------------------------
# Pre-extracted-tiles kernel (A/B baseline for the streaming path)
# ---------------------------------------------------------------------------

def _winograd_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref, u_ref,
                     o_ref, acc_ref, *, n_c: int):
    c_step = pl.program_id(2)

    @pl.when(c_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bR, th, tw, bC)
    br, th, tw, bc = x.shape
    v = _apply_pair(bt_h_ref[...], bt_w_ref[...],
                    x.astype(jnp.float32))           # (bR, th, tw, bC)
    v = v.transpose(1, 2, 0, 3).reshape(th * tw, br, bc)  # (P, bR, bC)

    u = u_ref[...]                                   # (P, bC, bM)
    # batched point-GEMM: the paper's x^2 GEMMs as one dot_general.
    acc_ref[...] += jax.lax.dot_general(
        v, u.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (P, bR, bM)

    @pl.when(c_step == n_c - 1)
    def _store():
        bm_ = acc_ref.shape[-1]
        y = acc_ref[...].reshape(th, tw, br, bm_).transpose(2, 0, 1, 3)
        out = _apply_pair(at_h_ref[...], at_w_ref[...], y)  # (bR, mh, mw, bM)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct_h", "ct_w", "block_r",
                                             "block_c", "block_m", "interpret"))
def winograd_fused(
    tiles: jax.Array,        # (R, th, tw, C) pre-extracted input tiles
    u: jax.Array,            # (P, C, M) Winograd-domain filter (P = th*tw)
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    block_r: int = 128,
    block_c: int = 128,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused transform+GEMM+inverse over pre-extracted tiles.

    Returns (R, mh, mw, M) spatial output tiles. R, C, M must be multiples of
    the block sizes (ops.py pads). `interpret=None` resolves via the shared
    REPRO_PALLAS_COMPILE-aware rule (kernels.runtime), so direct callers
    compile on TPU just like the ops.py wrappers.
    """
    interpret = resolve_interpret(interpret)
    r_, th, tw, c = tiles.shape
    p, c2, m = u.shape
    assert (th, tw) == (ct_h.t, ct_w.t) and p == th * tw and c == c2
    assert r_ % block_r == 0 and c % block_c == 0 and m % block_m == 0, (
        tiles.shape, u.shape, (block_r, block_c, block_m))
    n_c = c // block_c
    grid = (r_ // block_r, m // block_m, n_c)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape, lambda i, j, k: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_winograd_kernel, n_c=n_c),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            pl.BlockSpec((block_r, th, tw, block_c),
                         lambda i, j, k: (i, 0, 0, k)),
            pl.BlockSpec((p, block_c, block_m),
                         lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((block_r, ct_h.m, ct_w.m, block_m),
                               lambda i, j, k: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((r_, ct_h.m, ct_w.m, m), tiles.dtype),
        scratch_shapes=[pltpu.VMEM((p, block_r, block_m), jnp.float32)],
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, tiles, u)
