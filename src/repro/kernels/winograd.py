"""Fused region-wise multi-channel Winograd convolution Pallas kernel.

TPU-native adaptation of the paper's three-phase scheme. The paper stages
(input transform -> scatter to matrices in memory -> GEMMs -> gather -> output
transform) through L1/L2; on TPU we instead *fuse* all three phases in VMEM:

  grid = (R / bR,  M / bM,  C / bC)        # C innermost: accumulation axis

  per step:
    1. load a (bR, th, tw, bC) block of pre-extracted input tiles,
       apply B^T (.) B  -- a fixed pattern of small matmuls over the tile
       axes, vectorized over (bR, bC); channels stay on the 128-lane axis
       (the paper's NHWC/NEON argument, 128 lanes wide instead of 4);
    2. one *batched* dot_general over the P = th*tw Winograd points:
       (P, bR, bC) x (P, bC, bM) -> accumulate (P, bR, bM) fp32 in VMEM.
       This is the paper's "array of GEMMs", batched so the MXU pipeline
       never drains between points;
    3. on the last C step, apply A^T (.) A and write the (bR, mh, mw, bM)
       spatial output block.

The Winograd-domain tensors (the paper's scattered 'A'/'C' matrices) never
touch HBM -- this fusion is the main beyond-paper optimization and is measured
in EXPERIMENTS.md section Perf.

Tile extraction (overlapping windows) happens outside the kernel: XLA lowers
it to strided slices, and it is the only part of the algorithm that cannot be
expressed as a non-overlapping BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.transforms import CookToom


def _apply_pair(mat_h, mat_w, x):
    """einsum('it,brtuc,ju->bricj'-free): y[b,i,j,c] = sum_tu H[i,t] W[j,u] x[b,t,u,c].

    x: (bR, th, tw, bC). Contractions kept as dots on the small tile axes so
    the (bR, bC) payload axes ride along untouched (lane dim = channels).
    """
    # contract th: (i,t) x (b,t,u,c) -> (b,i,u,c)
    y = jnp.tensordot(mat_h, x, axes=(1, 1)).transpose(1, 0, 2, 3)
    # contract tw: (j,u) x (b,i,u,c) -> (b,i,j,c)
    y = jnp.tensordot(mat_w, y, axes=(1, 2)).transpose(1, 2, 0, 3)
    return y


def _winograd_kernel(bt_h_ref, bt_w_ref, at_h_ref, at_w_ref, x_ref, u_ref,
                     o_ref, acc_ref, *, n_c: int):
    c_step = pl.program_id(2)

    @pl.when(c_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bR, th, tw, bC)
    br, th, tw, bc = x.shape
    v = _apply_pair(bt_h_ref[...], bt_w_ref[...],
                    x.astype(jnp.float32))           # (bR, th, tw, bC)
    v = v.transpose(1, 2, 0, 3).reshape(th * tw, br, bc)  # (P, bR, bC)

    u = u_ref[...]                                   # (P, bC, bM)
    # batched point-GEMM: the paper's x^2 GEMMs as one dot_general.
    acc_ref[...] += jax.lax.dot_general(
        v, u.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (P, bR, bM)

    @pl.when(c_step == n_c - 1)
    def _store():
        bm_ = acc_ref.shape[-1]
        y = acc_ref[...].reshape(th, tw, br, bm_).transpose(2, 0, 1, 3)
        out = _apply_pair(at_h_ref[...], at_w_ref[...], y)  # (bR, mh, mw, bM)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct_h", "ct_w", "block_r",
                                             "block_c", "block_m", "interpret"))
def winograd_fused(
    tiles: jax.Array,        # (R, th, tw, C) pre-extracted input tiles
    u: jax.Array,            # (P, C, M) Winograd-domain filter (P = th*tw)
    *,
    ct_h: CookToom,
    ct_w: CookToom,
    block_r: int = 128,
    block_c: int = 128,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused transform+GEMM+inverse over pre-extracted tiles.

    Returns (R, mh, mw, M) spatial output tiles. R, C, M must be multiples of
    the block sizes (ops.py pads).
    """
    r_, th, tw, c = tiles.shape
    p, c2, m = u.shape
    assert (th, tw) == (ct_h.t, ct_w.t) and p == th * tw and c == c2
    assert r_ % block_r == 0 and c % block_c == 0 and m % block_m == 0, (
        tiles.shape, u.shape, (block_r, block_c, block_m))
    n_c = c // block_c
    grid = (r_ // block_r, m // block_m, n_c)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    whole = lambda arr: pl.BlockSpec(arr.shape, lambda i, j, k: (0,) * arr.ndim)
    return pl.pallas_call(
        functools.partial(_winograd_kernel, n_c=n_c),
        grid=grid,
        in_specs=[
            whole(bt_h), whole(bt_w), whole(at_h), whole(at_w),
            pl.BlockSpec((block_r, th, tw, block_c),
                         lambda i, j, k: (i, 0, 0, k)),
            pl.BlockSpec((p, block_c, block_m),
                         lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((block_r, ct_h.m, ct_w.m, block_m),
                               lambda i, j, k: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((r_, ct_h.m, ct_w.m, m), tiles.dtype),
        scratch_shapes=[pltpu.VMEM((p, block_r, block_m), jnp.float32)],
        interpret=interpret,
    )(bt_h, bt_w, at_h, at_w, tiles, u)
