"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function computes the same contract as its kernels/ counterpart using
only jax.numpy / lax primitives -- no Pallas, no blocking, no padding tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import winograd as _wg
from repro.core.transforms import CookToom


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def winograd_fused(tiles: jax.Array, u: jax.Array, *, ct_h: CookToom,
                   ct_w: CookToom) -> jax.Array:
    """(R, th, tw, C), (P, C, M) -> (R, mh, mw, M)."""
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    x = tiles.astype(jnp.float32)
    v = jnp.einsum("it,rtuc,ju->rijc", bt_h, x, bt_w)
    v = v.reshape(v.shape[0], ct_h.t * ct_w.t, -1).transpose(1, 0, 2)
    y = jnp.einsum("prc,pcm->prm", v, u.astype(jnp.float32))
    y = y.transpose(1, 0, 2).reshape(-1, ct_h.t, ct_w.t, y.shape[-1])
    out = jnp.einsum("it,rtum,ju->rijm", at_h, y, at_w)
    return out.astype(tiles.dtype)


def conv1d_ct_fused(tiles: jax.Array, u: jax.Array, *, ct: CookToom) -> jax.Array:
    """(B, S, t, C), (t, C) -> (B, S, m, C)."""
    bt = jnp.asarray(ct.BT, jnp.float32)
    at = jnp.asarray(ct.AT, jnp.float32)
    v = jnp.einsum("it,bstc->bsic", bt, tiles.astype(jnp.float32))
    y = v * u.astype(jnp.float32)[None, None]
    return jnp.einsum("ot,bstc->bsoc", at, y).astype(tiles.dtype)


def conv2d_direct(x: jax.Array, w: jax.Array, *, stride=1,
                  padding="SAME") -> jax.Array:
    """End-to-end convolution oracle for the ops.py wrappers."""
    stride = (stride, stride) if isinstance(stride, int) else stride
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def selective_scan(dt: jax.Array, xs: jax.Array, bmat: jax.Array,
                   cmat: jax.Array, a_mat: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Sequential-oracle Mamba-1 selective scan.

    dt, xs: (B, L, D); bmat, cmat: (B, L, N); a_mat: (D, N).
    y_t = C_t h_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.
    Returns (y (B, L, D) f32, h_last (B, D, N) f32).
    """
    f32 = jnp.float32
    dt, xs = dt.astype(f32), xs.astype(f32)
    bmat, cmat = bmat.astype(f32), cmat.astype(f32)
    b, l, d = dt.shape
    n = a_mat.shape[-1]

    def step(h, inputs):
        dti, xi, bi, ci = inputs                     # (B, D), (B, N)
        a_bar = jnp.exp(dti[..., None] * a_mat[None])      # (B, D, N)
        h = a_bar * h + (dti * xi)[..., None] * bi[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ci)
        return h, y

    h0 = jnp.zeros((b, d, n), f32)
    h_last, ys = jax.lax.scan(
        step, h0, (dt.transpose(1, 0, 2), xs.transpose(1, 0, 2),
                   bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h_last


def depthwise_causal_conv1d_direct(x: jax.Array, w: jax.Array) -> jax.Array:
    """(B, L, C) x (r, C) -> (B, L, C) causal oracle."""
    r = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(r):
        out = out + xp[:, k:k + x.shape[1]] * w[k][None, None]
    return out
