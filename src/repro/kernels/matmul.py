"""Blocked MXU matmul Pallas kernel with fused bias+activation epilogue.

The GEMM that the paper's im2row baseline (and the unfused Winograd GEMM
phase) bottoms out in. Grid = (M/bm, N/bn, K/bk) with the K axis innermost so
the fp32 VMEM accumulator carries across K steps; A/B panels are staged
HBM->VMEM by BlockSpec, C is written once on the final K step -- with the
optional bias add + activation applied to the fp32 accumulator in that same
store, so conv layers using the im2col path never round-trip the output
through HBM for their elementwise epilogue.

The B panel may arrive in a reduced storage dtype (bf16 cast or int8
per-output-column quantized weights): the dot widens it to fp32 in VMEM, and
the int8 dequantization is one (1, N) `scale` row multiplied into the
accumulator in the same store step as the bias -- the low-precision panel is
what travels HBM->VMEM.

Block defaults are MXU-aligned (128) on the matmul dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import apply_activation, resolve_interpret


def _matmul_kernel(a_ref, b_ref, bias_ref, scale_ref, o_ref, acc_ref, *,
                   n_k: int, activation: str, has_bias: bool,
                   has_scale: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        y = acc_ref[...]
        if has_scale:
            y = y * scale_ref[...]                   # (1, bn) dequant row
        if has_bias:
            y = y + bias_ref[...]                    # (1, bn) broadcast
        o_ref[...] = apply_activation(y, activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "activation",
                                             "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, bias: jax.Array | None = None,
           scale: jax.Array | None = None,
           activation: str = "none",
           interpret: bool | None = None) -> jax.Array:
    """C[M, N] = act(scale * (A[M, K] @ B[K, N]) + bias), fp32 accumulation.

    M, K, N must be multiples of the block sizes (ops.py pads). `bias` is a
    (1, N) fp32 row or None; `scale` a (1, N) fp32 per-output-column
    dequantization row (int8 B panels) or None; `activation` is
    none/relu/gelu, applied to the accumulator in the kernel's store step.
    B may be fp32, bf16, or int8 -- the dot widens it to fp32.
    """
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape)
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, n), jnp.float32)
    assert bias.shape == (1, n), (bias.shape, b.shape)
    has_scale = scale is not None
    if scale is None:
        scale = jnp.ones((1, n), jnp.float32)
    assert scale.shape == (1, n), (scale.shape, b.shape)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k, activation=activation,
                          has_bias=has_bias, has_scale=has_scale),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, bias, scale)
