"""Shared kernel-runtime policy: interpret-mode resolution and the fused
epilogue vocabulary.

Every Pallas entry point (kernels/*.py and the ops.py wrappers) resolves its
`interpret` argument through `resolve_interpret`, so direct kernel callers and
the wrapped paths follow the same REPRO_PALLAS_COMPILE-aware rule: interpret
off-TPU (this container is CPU-only), compile on TPU or when the env var
forces it.

`apply_activation` is the epilogue vocabulary shared by the Winograd and GEMM
kernels (bias add + none/relu/relu6/gelu) and by the pure-JAX executors, so
every conv backend exposes the same fused-epilogue contract.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

#: Epilogue activations the fused kernels support (static compile-time
#: choice). relu6 is the MobileNet-v2 nonlinearity (clipped ReLU).
ACTIVATIONS = ("none", "relu", "relu6", "gelu")


def pick_block(dim: int, target: int, quantum: int = 8) -> int:
    """Block size <= target; tiny dims round up to the VPU quantum. The one
    blocking-granularity rule shared by the kernel wrappers (ops.py) and the
    plan-time geometry choosers (core/winograd.py)."""
    return target if dim >= target else -(-dim // quantum) * quantum


def default_interpret() -> bool:
    """Pallas interpret-mode default: False on TPU or when
    REPRO_PALLAS_COMPILE is set, True elsewhere (CPU/GPU hosts)."""
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def apply_activation(y: jax.Array, activation: str) -> jax.Array:
    """Elementwise epilogue activation; `y` is the fp32 accumulator."""
    if activation == "none":
        return y
    if activation == "relu":
        return jax.nn.relu(y)
    if activation == "relu6":
        return jnp.minimum(jax.nn.relu(y), 6.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(
        f"unknown epilogue activation {activation!r}; expected {ACTIVATIONS}")


def epilogue_jnp(y: jax.Array, bias: jax.Array | None,
                 activation: str) -> jax.Array:
    """XLA-side bias+activation for executors without a fused kernel
    epilogue (XLA fuses this into the producing op's consumers). Same
    contract as the in-kernel epilogues: fp32 math, output in y's dtype."""
    if bias is None and activation == "none":
        return y
    out = y.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return apply_activation(out, activation).astype(y.dtype)
