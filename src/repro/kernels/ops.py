"""Public jit'd wrappers around the Pallas kernels.

These mirror the pure-JAX entry points in repro.core (same signatures, same
semantics) and handle all padding/blocking so callers never see alignment
constraints. `interpret` defaults to the shared REPRO_PALLAS_COMPILE-aware
rule in repro.kernels.runtime: interpret off-TPU (this container is CPU-only;
on a real TPU pass interpret=False or set REPRO_PALLAS_COMPILE=1).

The planned Winograd path streams regions end-to-end inside the kernel
(winograd_conv2d_planned -> kernels.winograd.winograd_streamed): the only
per-call HBM tensors are the padded NHWC input and the NHWC output, with the
bias+activation epilogue fused into the kernel's store step. The pre-streaming
executor that materialized the (R, th, tw, C) overlapping-tile tensor and
un-tiled the output with a separate transpose pass is kept as
winograd_conv2d_planned_materialized -- the A/B baseline for
benchmarks/per_layer.py and BENCH_PR2.json.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import im2col as _im2col
from repro.core import winograd as _wg
from repro.core.transforms import DEFAULT_OUTPUT_TILE, cook_toom
from repro.kernels import conv1d_ct as _k_conv1d
from repro.kernels import matmul as _k_matmul
from repro.kernels import winograd as _k_winograd
from repro.kernels.runtime import default_interpret as _default_interpret
from repro.kernels.runtime import epilogue_jnp as _epilogue_jnp
from repro.kernels.runtime import pick_block as _block
from repro.kernels.runtime import resolve_interpret as _resolve_interpret


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad) if pad[axis][1] else x


def _pad_bias(bias: jax.Array | None, m_pad: int) -> jax.Array | None:
    """(M,) epilogue bias -> (1, Mp) fp32 for the kernel's bias BlockSpec."""
    if bias is None:
        return None
    return _pad_axis(bias.astype(jnp.float32).reshape(1, -1), 1, m_pad)


# ---------------------------------------------------------------------------
# Winograd conv2d -- halo-streaming planned path
# ---------------------------------------------------------------------------

def winograd_conv2d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    stream: _wg.StreamGeometry,
    c_out: int,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned streaming Pallas Winograd conv.

    `u` is the pre-transformed, pre-padded (P, Cp, Mp) filter (fp32, or a
    bf16/int8 reduced-precision copy -- the kernel widens at the dot);
    `scale` is the plan's (1, Mp) int8 dequantization row or None. All
    geometry (conv padding, halo strip origins, edge-block padding,
    VMEM-budgeted block sizes) was derived once at plan time. The per-call
    work is one NHWC pad, the kernel, and one crop -- no tile
    materialization, no post-kernel un-tiling, no separate bias/activation
    passes.
    """
    c = x.shape[3]
    xp = jnp.pad(x, ((0, 0),
                     (geometry.lo_h, geometry.hi_h + stream.pad_h),
                     (geometry.lo_w, geometry.hi_w + stream.pad_w),
                     (0, stream.c_pad - c)))
    y = _k_winograd.winograd_streamed(
        xp, u, _pad_bias(bias, stream.m_pad), scale, ct_h=ct_h, ct_w=ct_w,
        bh=stream.bh, bw=stream.bw, block_c=stream.block_c,
        block_m=stream.block_m, activation=activation, interpret=interpret)
    return y[:, :geometry.out_h, :geometry.out_w, :c_out]


def winograd_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int | None = None,
    padding: _wg.Padding = "SAME",
    bias: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed F(m x m, k x k) convolution, NHWC x HWIO -> NHWC.

    Unplanned compatibility path: derives the filter transform, geometry and
    halo blocking inline, then runs the streaming planned executor. Plan once
    with repro.core.plan.plan_conv2d to skip the derivation on every call.
    """
    n, h, wdt, c = x.shape
    kh, kw, _, mout = w.shape
    if kh == 1 or kw == 1:
        # 1xN / Nx1 / 1x1 layers route through the pure-JAX 1D path (its GEMM
        # is a single matmul XLA already maps to the MXU).
        mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        y = _wg.winograd_conv2d(x, w, output_tile=mt, padding=padding)
        return _epilogue_jnp(y, bias, activation)
    mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
    ct_h, ct_w = cook_toom(mt, kh), cook_toom(mt, kw)
    u = _wg.transform_filter_2d(w, ct_h, ct_w)           # (th, tw, C, M)
    u = u.reshape(ct_h.t * ct_w.t, c, mout)

    geometry = _wg.conv2d_geometry(h, wdt, kh, kw, ct_h.m, ct_w.m, padding)
    stream = _wg.stream_geometry(geometry.n_h, geometry.n_w, c, mout,
                                 ct_h, ct_w)
    u = pad_winograd_filter(u, stream.block_c, stream.block_m)
    return winograd_conv2d_planned(
        x, u, ct_h=ct_h, ct_w=ct_w, geometry=geometry, stream=stream,
        c_out=mout, bias=bias, activation=activation, interpret=interpret)


def winograd_strided_conv2d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    stream: _wg.StreamGeometry,
    c_out: int,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned stride-2 streaming Pallas Winograd conv (transform-
    domain phase decomposition). `u` is the pre-transformed (4P, Cp, Mp)
    phase-major filter (fp32/bf16/int8); `scale` the (1, Mp) int8 dequant
    row or None; the halo geometry is in full-resolution input units, so
    the edge-block padding is 2x the plan's output-tile surplus."""
    c = x.shape[3]
    xp = jnp.pad(x, ((0, 0),
                     (geometry.lo_h, geometry.hi_h + 2 * stream.pad_h),
                     (geometry.lo_w, geometry.hi_w + 2 * stream.pad_w),
                     (0, stream.c_pad - c)))
    y = _k_winograd.winograd_strided_streamed(
        xp, u, _pad_bias(bias, stream.m_pad), scale, ct_h=ct_h, ct_w=ct_w,
        bh=stream.bh, bw=stream.bw, block_c=stream.block_c,
        block_m=stream.block_m, activation=activation, interpret=interpret)
    return y[:, :geometry.out_h, :geometry.out_w, :c_out]


def depthwise_strided_conv2d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    stream: _wg.StreamGeometry,
    c_out: int,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned stride-2 streamed Pallas depthwise conv: `u` is the
    (4P, Cp) phase-major taps (fp32/bf16/int8); `scale` the (1, Cp) int8
    dequant row or None; halo blocking comes from the plan."""
    from repro.kernels import depthwise as _k_depthwise
    c = x.shape[3]
    xp = jnp.pad(x, ((0, 0),
                     (geometry.lo_h, geometry.hi_h + 2 * stream.pad_h),
                     (geometry.lo_w, geometry.hi_w + 2 * stream.pad_w),
                     (0, stream.c_pad - c)))
    y = _k_depthwise.depthwise_strided_streamed(
        xp, u, _pad_bias(bias, stream.c_pad), scale, ct_h=ct_h, ct_w=ct_w,
        bh=stream.bh, bw=stream.bw, block_c=stream.block_c,
        activation=activation, interpret=interpret)
    return y[:, :geometry.out_h, :geometry.out_w, :c_out]


# ---------------------------------------------------------------------------
# Winograd conv2d -- pre-streaming (materialized-tiles) baseline
# ---------------------------------------------------------------------------

def winograd_blocks(r_tot: int, c: int, mout: int, *, block_r: int = 128,
                    block_c: int = 128, block_m: int = 128
                    ) -> tuple[int, int, int]:
    """(block_r, block_c, block_m) for the materialized-tiles kernel."""
    return _block(r_tot, block_r), _block(c, block_c), _block(mout, block_m)


def pad_winograd_filter(u: jax.Array, block_c: int, block_m: int) -> jax.Array:
    """Pad a (P, C, M) Winograd-domain filter to the kernel's block grid.
    Done once at plan time so apply() never touches the weights."""
    p, c, mout = u.shape
    return _pad_axis(_pad_axis(u, 1, _round_up(c, block_c)),
                     2, _round_up(mout, block_m))


def winograd_conv2d_planned_materialized(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    blocks: tuple[int, int, int],
    c_in: int,
    c_out: int,
    interpret: bool | None = None,
) -> jax.Array:
    """The pre-streaming planned executor, kept as the A/B baseline: extracts
    the (R, th, tw, C) overlapping-tile tensor in HBM, runs the tiles-domain
    kernel, then un-tiles the output with a transpose/reshape pass. Every
    step the streaming path removes is visible here."""
    interpret = _resolve_interpret(interpret)
    n, h, wdt, c = x.shape
    br, bc, bm = blocks
    nh, nw = geometry.n_h, geometry.n_w
    xp = jnp.pad(x, ((0, 0), (geometry.lo_h, geometry.hi_h),
                     (geometry.lo_w, geometry.hi_w), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct_h.t, ct_h.m, nh)
    tiles = _wg._extract_tiles_1d(tiles, 3, ct_w.t, ct_w.m, nw)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
        n * nh * nw, ct_h.t, ct_w.t, c)                  # (R, th, tw, C)

    r_tot = tiles.shape[0]
    tiles = _pad_axis(tiles, 0, _round_up(r_tot, br))
    tiles = _pad_axis(tiles, 3, _round_up(c_in, bc))

    y = _k_winograd.winograd_fused(
        tiles, u, ct_h=ct_h, ct_w=ct_w, block_r=br, block_c=bc, block_m=bm,
        interpret=interpret)                             # (Rp, mh, mw, Mp)
    y = y[:r_tot, :, :, :c_out].reshape(n, nh, nw, ct_h.m, ct_w.m, c_out)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(
        n, nh * ct_h.m, nw * ct_w.m, c_out)
    return y[:, :geometry.out_h, :geometry.out_w]


# ---------------------------------------------------------------------------
# Depthwise / fused separable streamed paths
# ---------------------------------------------------------------------------

def depthwise_conv2d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    stream: _wg.StreamGeometry,
    c_out: int,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned streaming Pallas depthwise conv: `u` is the
    pre-transformed, pre-padded (P, Cp, mult) taps (fp32/bf16/int8; mult =
    channel multiplier; output channel o = c*mult + j, the lax ordering);
    `scale` the (1, Cp*mult) int8 dequant row or None; conv padding, halo
    blocking and channel blocks come from the plan. Per-call work is one
    NHWC pad, the kernel, one crop."""
    from repro.kernels import depthwise as _k_depthwise
    c = x.shape[3]
    mult = u.shape[2]
    xp = jnp.pad(x, ((0, 0),
                     (geometry.lo_h, geometry.hi_h + stream.pad_h),
                     (geometry.lo_w, geometry.hi_w + stream.pad_w),
                     (0, stream.c_pad - c)))
    y = _k_depthwise.depthwise_streamed(
        xp, u, _pad_bias(bias, stream.c_pad * mult), scale, ct_h=ct_h,
        ct_w=ct_w, bh=stream.bh, bw=stream.bw, block_c=stream.block_c,
        activation=activation, interpret=interpret)
    return y[:, :geometry.out_h, :geometry.out_w, :c_out]


def separable_conv2d_planned(
    x: jax.Array,
    u_dw: jax.Array,
    u_pw: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    stream: _wg.StreamGeometry,
    c_out: int,
    bias_dw: jax.Array | None = None,
    bias_pw: jax.Array | None = None,
    inner_activation: str = "none",
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned fused separable block (depthwise Winograd +
    epilogue + pointwise 1x1 + epilogue in one streamed kernel; the
    intermediate never touches HBM). `u_dw` is the (P, Cp) depthwise taps,
    `u_pw` the (Cp, Mp) pointwise matrix, both pre-padded at plan time."""
    from repro.kernels import depthwise as _k_depthwise
    c = x.shape[3]
    xp = jnp.pad(x, ((0, 0),
                     (geometry.lo_h, geometry.hi_h + stream.pad_h),
                     (geometry.lo_w, geometry.hi_w + stream.pad_w),
                     (0, stream.c_pad - c)))
    y = _k_depthwise.separable_streamed(
        xp, u_dw, u_pw, _pad_bias(bias_dw, stream.c_pad),
        _pad_bias(bias_pw, stream.m_pad), ct_h=ct_h, ct_w=ct_w,
        bh=stream.bh, bw=stream.bw, block_c=stream.block_c,
        block_m=stream.block_m, inner_activation=inner_activation,
        activation=activation, interpret=interpret)
    return y[:, :geometry.out_h, :geometry.out_w, :c_out]


# ---------------------------------------------------------------------------
# im2col conv2d (baseline)
# ---------------------------------------------------------------------------

def im2col_blocks(mm: int, kk: int, mout: int, *, block: int = 128
                  ) -> tuple[int, int, int]:
    """(bm, bk, bn) for the blocked GEMM -- plan-time."""
    return _block(mm, block), _block(kk, block), _block(mout, block)


def pad_im2col_filter(b: jax.Array, bk: int, bn: int) -> jax.Array:
    """Pad the (khkwC, M) filter matrix to the GEMM block grid -- plan-time."""
    kk, mout = b.shape
    return _pad_axis(_pad_axis(b, 0, _round_up(kk, bk)),
                     1, _round_up(mout, bn))


def im2col_conv2d_planned(
    x: jax.Array,
    b: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: tuple[int, int],
    padding: _wg.Padding,
    geometry: _im2col.Im2RowGeometry,
    blocks: tuple[int, int, int],
    c_out: int,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned Pallas im2row conv: `b` is the pre-reshaped,
    pre-padded (Kp, Np) filter matrix (fp32/bf16/int8); `scale` the (1, Np)
    int8 dequant row or None; geometry and block sizes come from the plan.
    The bias+activation epilogue (and the dequant multiply) is fused into
    the GEMM kernel's store step."""
    interpret = _resolve_interpret(interpret)
    n = x.shape[0]
    bm_, bk_, bn_ = blocks
    a, (oh, ow) = _im2col.im2row(x, kh, kw, stride, padding, geometry)
    mm, kk = a.shape
    a = _pad_axis(_pad_axis(a, 0, _round_up(mm, bm_)), 1, _round_up(kk, bk_))
    y = _k_matmul.matmul(a, b, bm=bm_, bn=bn_, bk=bk_,
                         bias=_pad_bias(bias, b.shape[1]), scale=scale,
                         activation=activation, interpret=interpret)
    return y[:mm, :c_out].reshape(n, oh, ow, c_out).astype(x.dtype)


def im2col_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: _wg.Padding = "SAME",
    block: int = 128,
    bias: jax.Array | None = None,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed im2row + GEMM baseline (unplanned compatibility path)."""
    n, h, wdt, c = x.shape
    kh, kw, _, mout = w.shape
    stride = (stride, stride) if isinstance(stride, int) else stride
    geometry = _im2col.im2row_geometry(h, wdt, kh, kw, stride, padding)
    mm = n * geometry.oh * geometry.ow
    blocks = im2col_blocks(mm, kh * kw * c, mout, block=block)
    b = pad_im2col_filter(w.reshape(kh * kw * c, mout), blocks[1], blocks[2])
    return im2col_conv2d_planned(
        x, b, kh=kh, kw=kw, stride=stride, padding=padding, geometry=geometry,
        blocks=blocks, c_out=mout, bias=bias, activation=activation,
        interpret=interpret)


# ---------------------------------------------------------------------------
# Transform-domain contenders of the measured auto_tuned race
# ---------------------------------------------------------------------------

def fft_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    padding: _wg.Padding = "SAME",
    bias: jax.Array | None = None,
    activation: str = "none",
) -> jax.Array:
    """Overlap-tiled rfft2 convolution (unplanned compatibility path).

    Derives the tile geometry and the conjugated filter spectrum inline,
    then runs the planned executor (core.fft.fft_conv2d_pretransformed).
    Plan once with plan_conv2d(algorithm="fft") to pre-transform the filter
    and skip the derivation on every call.
    """
    from repro.core import fft as _fft
    n, h, wdt, c = x.shape
    kh, kw = w.shape[0], w.shape[1]
    fftg = _fft.choose_fft_geometry(h, wdt, kh, kw)
    u = _fft.fft_transform_filter(w, fftg.fft_h, fftg.fft_w)
    y = _fft.fft_conv2d_pretransformed(x, u, fftg, padding=padding)
    return _epilogue_jnp(y, bias, activation)


def winograd_f63_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    padding: _wg.Padding = "SAME",
    bias: jax.Array | None = None,
    activation: str = "none",
) -> jax.Array:
    """Large-tile F(6x6, 3x3) convolution with the power-of-two row-scaled
    transforms (unplanned compatibility path; 3x3 stride-1 only). Plan once
    with plan_conv2d(algorithm="winograd_f63") to pre-transform the filter.
    """
    from repro.core.transforms import scaled_cook_toom
    kh, kw = w.shape[0], w.shape[1]
    if (kh, kw) != (3, 3):
        raise ValueError(f"winograd_f63 covers 3x3 filters only, got "
                         f"{kh}x{kw}")
    ct_h, ct_w = scaled_cook_toom(6, 3), scaled_cook_toom(6, 3)
    u = _wg.transform_filter_2d(w, ct_h, ct_w)
    y = _wg.winograd_conv2d_pretransformed(x, u, ct_h, ct_w, padding=padding)
    return _epilogue_jnp(y, bias, activation)


# ---------------------------------------------------------------------------
# Depthwise causal Cook-Toom conv1d (Mamba short conv)
# ---------------------------------------------------------------------------

def conv1d_ct_blocks(n_tiles: int, c: int, *, block_s: int = 256,
                     block_c: int = 128) -> tuple[int, int]:
    """(block_s, block_c) for the depthwise conv1d kernel -- plan-time."""
    return _block(n_tiles, block_s), _block(c, block_c)


def ct_depthwise_causal_conv1d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct,
    n_tiles: int,
    pad_hi: int,
    blocks: tuple[int, int],
    c_in: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Planned executor: `u` is the pre-transformed, pre-padded (t, Cp)
    Cook-Toom-domain taps; tile count, padding and block sizes come from the
    plan (core.plan.plan_depthwise_conv1d)."""
    interpret = _resolve_interpret(interpret)
    b, length, c = x.shape
    bs, bc = blocks
    xp = jnp.pad(x, ((0, 0), (ct.r - 1, pad_hi), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct.t, ct.m, n_tiles)  # (B, nt, t, C)
    tiles = _pad_axis(tiles, 1, _round_up(n_tiles, bs))
    tiles = _pad_axis(tiles, 3, _round_up(c_in, bc))
    y = _k_conv1d.conv1d_ct_fused(tiles, u, ct=ct, block_s=bs, block_c=bc,
                                  interpret=interpret)
    y = y[:, :n_tiles, :, :c_in].reshape(b, n_tiles * ct.m, c_in)
    return y[:, :length]


def ct_depthwise_causal_conv1d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int = 4,
    block_s: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, L, C) x (r, C) -> (B, L, C), causal.

    Unplanned compatibility path: derives cook_toom, tile counts, padding
    and blocking inline, then runs the planned executor. Hold a
    repro.core.plan.plan_depthwise_conv1d plan to make these decisions once.
    """
    r, c = w.shape
    b, length, _ = x.shape
    ct = cook_toom(output_tile, r)
    nt = -(-length // ct.m)
    u = jnp.einsum("ij,jc->ic", jnp.asarray(ct.G, w.dtype), w)
    blocks = conv1d_ct_blocks(nt, c, block_s=block_s, block_c=block_c)
    u = _pad_axis(u, 1, _round_up(c, blocks[1]))
    return ct_depthwise_causal_conv1d_planned(
        x, u, ct=ct, n_tiles=nt, pad_hi=nt * ct.m - length, blocks=blocks,
        c_in=c, interpret=interpret)


def matmul(a: jax.Array, b: jax.Array, *, block: int = 128,
           bias: jax.Array | None = None, activation: str = "none",
           interpret: bool | None = None) -> jax.Array:
    """Padding-tolerant blocked matmul with optional fused epilogue."""
    interpret = _resolve_interpret(interpret)
    m, k = a.shape
    _, n = b.shape
    bm_, bk_, bn_ = _block(m, block), _block(k, block), _block(n, block)
    ap = _pad_axis(_pad_axis(a, 0, _round_up(m, bm_)), 1, _round_up(k, bk_))
    bp = _pad_axis(_pad_axis(b, 0, _round_up(k, bk_)), 1, _round_up(n, bn_))
    return _k_matmul.matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_,
                            bias=_pad_bias(bias, bp.shape[1]),
                            activation=activation,
                            interpret=interpret)[:m, :n]
