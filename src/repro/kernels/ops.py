"""Public jit'd wrappers around the Pallas kernels.

These mirror the pure-JAX entry points in repro.core (same signatures, same
semantics) and handle all padding/blocking so callers never see alignment
constraints. `interpret` defaults to True off-TPU (this container is CPU-only;
on a real TPU pass interpret=False or set REPRO_PALLAS_COMPILE=1).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import im2col as _im2col
from repro.core import winograd as _wg
from repro.core.transforms import DEFAULT_OUTPUT_TILE, cook_toom
from repro.kernels import conv1d_ct as _k_conv1d
from repro.kernels import matmul as _k_matmul
from repro.kernels import winograd as _k_winograd


def _default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _block(dim: int, target: int, quantum: int = 8) -> int:
    """Pick a block size <= target; tiny dims round up to the VPU quantum."""
    return target if dim >= target else _round_up(dim, quantum)


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad) if pad[axis][1] else x


# ---------------------------------------------------------------------------
# Winograd conv2d
# ---------------------------------------------------------------------------

def winograd_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int | None = None,
    padding: _wg.Padding = "SAME",
    block_r: int = 128,
    block_c: int = 128,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed F(m x m, k x k) convolution, NHWC x HWIO -> NHWC."""
    if interpret is None:
        interpret = _default_interpret()
    n, h, wdt, c = x.shape
    kh, kw, _, mout = w.shape
    if kh == 1 or kw == 1:
        # 1xN / Nx1 / 1x1 layers route through the pure-JAX 1D path (its GEMM
        # is a single matmul XLA already maps to the MXU).
        mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        return _wg.winograd_conv2d(x, w, output_tile=mt, padding=padding)
    mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
    ct_h, ct_w = cook_toom(mt, kh), cook_toom(mt, kw)
    u = _wg.transform_filter_2d(w, ct_h, ct_w)           # (th, tw, C, M)
    u = u.reshape(ct_h.t * ct_w.t, c, mout)

    lo_h, hi_h, nh = _wg._pad_amounts(h, kh, ct_h.m, padding)
    lo_w, hi_w, nw = _wg._pad_amounts(wdt, kw, ct_w.m, padding)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct_h.t, ct_h.m, nh)
    tiles = _wg._extract_tiles_1d(tiles, 3, ct_w.t, ct_w.m, nw)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
        n * nh * nw, ct_h.t, ct_w.t, c)                  # (R, th, tw, C)

    r_tot = tiles.shape[0]
    br = _block(r_tot, block_r)
    bc = _block(c, block_c)
    bm = _block(mout, block_m)
    tiles = _pad_axis(tiles, 0, _round_up(r_tot, br))
    tiles = _pad_axis(tiles, 3, _round_up(c, bc))
    u = _pad_axis(_pad_axis(u, 1, _round_up(c, bc)), 2, _round_up(mout, bm))

    y = _k_winograd.winograd_fused(
        tiles, u, ct_h=ct_h, ct_w=ct_w, block_r=br, block_c=bc, block_m=bm,
        interpret=interpret)                             # (Rp, mh, mw, Mp)
    y = y[:r_tot, :, :, :mout].reshape(n, nh, nw, ct_h.m, ct_w.m, mout)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(n, nh * ct_h.m, nw * ct_w.m, mout)
    out_h = h if padding == "SAME" else h - kh + 1
    out_w = wdt if padding == "SAME" else wdt - kw + 1
    return y[:, :out_h, :out_w]


# ---------------------------------------------------------------------------
# im2col conv2d (baseline)
# ---------------------------------------------------------------------------

def im2col_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: _wg.Padding = "SAME",
    block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed im2row + GEMM baseline."""
    if interpret is None:
        interpret = _default_interpret()
    n = x.shape[0]
    kh, kw, c, mout = w.shape
    stride = (stride, stride) if isinstance(stride, int) else stride
    a, (oh, ow) = _im2col.im2row(x, kh, kw, stride, padding)
    b = w.reshape(kh * kw * c, mout)
    mm, kk = a.shape
    bm_ = _block(mm, block)
    bk_ = _block(kk, block)
    bn_ = _block(mout, block)
    a = _pad_axis(_pad_axis(a, 0, _round_up(mm, bm_)), 1, _round_up(kk, bk_))
    b = _pad_axis(_pad_axis(b, 0, _round_up(kk, bk_)), 1, _round_up(mout, bn_))
    y = _k_matmul.matmul(a, b, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return y[:mm, :mout].reshape(n, oh, ow, mout).astype(x.dtype)


# ---------------------------------------------------------------------------
# Depthwise causal Cook-Toom conv1d (Mamba short conv)
# ---------------------------------------------------------------------------

def ct_depthwise_causal_conv1d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int = 4,
    block_s: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, L, C) x (r, C) -> (B, L, C), causal."""
    if interpret is None:
        interpret = _default_interpret()
    r, c = w.shape
    b, length, _ = x.shape
    ct = cook_toom(output_tile, r)
    nt = -(-length // ct.m)
    xp = jnp.pad(x, ((0, 0), (r - 1, nt * ct.m - length), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct.t, ct.m, nt)    # (B, nt, t, C)
    u = jnp.einsum("ij,jc->ic", jnp.asarray(ct.G, w.dtype), w)

    bs = _block(nt, block_s)
    bc = _block(c, block_c)
    tiles = _pad_axis(tiles, 1, _round_up(nt, bs))
    tiles = _pad_axis(tiles, 3, _round_up(c, bc))
    u = _pad_axis(u, 1, _round_up(c, bc))
    y = _k_conv1d.conv1d_ct_fused(tiles, u, ct=ct, block_s=bs, block_c=bc,
                                  interpret=interpret)
    y = y[:, :nt, :, :c].reshape(b, nt * ct.m, c)
    return y[:, :length]


def matmul(a: jax.Array, b: jax.Array, *, block: int = 128,
           interpret: bool | None = None) -> jax.Array:
    """Padding-tolerant blocked matmul."""
    if interpret is None:
        interpret = _default_interpret()
    m, k = a.shape
    _, n = b.shape
    bm_, bk_, bn_ = _block(m, block), _block(k, block), _block(n, block)
    ap = _pad_axis(_pad_axis(a, 0, _round_up(m, bm_)), 1, _round_up(k, bk_))
    bp = _pad_axis(_pad_axis(b, 0, _round_up(k, bk_)), 1, _round_up(n, bn_))
    return _k_matmul.matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_,
                            interpret=interpret)[:m, :n]
