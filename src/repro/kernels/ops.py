"""Public jit'd wrappers around the Pallas kernels.

These mirror the pure-JAX entry points in repro.core (same signatures, same
semantics) and handle all padding/blocking so callers never see alignment
constraints. `interpret` defaults to True off-TPU (this container is CPU-only;
on a real TPU pass interpret=False or set REPRO_PALLAS_COMPILE=1).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import im2col as _im2col
from repro.core import winograd as _wg
from repro.core.transforms import DEFAULT_OUTPUT_TILE, cook_toom
from repro.kernels import conv1d_ct as _k_conv1d
from repro.kernels import matmul as _k_matmul
from repro.kernels import winograd as _k_winograd


def _default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _block(dim: int, target: int, quantum: int = 8) -> int:
    """Pick a block size <= target; tiny dims round up to the VPU quantum."""
    return target if dim >= target else _round_up(dim, quantum)


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad) if pad[axis][1] else x


# ---------------------------------------------------------------------------
# Winograd conv2d
# ---------------------------------------------------------------------------

def winograd_blocks(r_tot: int, c: int, mout: int, *, block_r: int = 128,
                    block_c: int = 128, block_m: int = 128
                    ) -> tuple[int, int, int]:
    """Pick (block_r, block_c, block_m) for the fused kernel -- plan-time."""
    return _block(r_tot, block_r), _block(c, block_c), _block(mout, block_m)


def pad_winograd_filter(u: jax.Array, block_c: int, block_m: int) -> jax.Array:
    """Pad a (P, C, M) Winograd-domain filter to the kernel's block grid.
    Done once at plan time so apply() never touches the weights."""
    p, c, mout = u.shape
    return _pad_axis(_pad_axis(u, 1, _round_up(c, block_c)),
                     2, _round_up(mout, block_m))


def winograd_conv2d_planned(
    x: jax.Array,
    u: jax.Array,
    *,
    ct_h,
    ct_w,
    geometry: _wg.Conv2DGeometry,
    blocks: tuple[int, int, int],
    c_in: int,
    c_out: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned Pallas Winograd conv: `u` is the pre-transformed,
    pre-padded (P, Cp, Mp) filter and all geometry/blocking decisions were
    made at plan time. Only per-call input work happens here."""
    if interpret is None:
        interpret = _default_interpret()
    n, h, wdt, c = x.shape
    br, bc, bm = blocks
    nh, nw = geometry.n_h, geometry.n_w
    xp = jnp.pad(x, ((0, 0), (geometry.lo_h, geometry.hi_h),
                     (geometry.lo_w, geometry.hi_w), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct_h.t, ct_h.m, nh)
    tiles = _wg._extract_tiles_1d(tiles, 3, ct_w.t, ct_w.m, nw)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
        n * nh * nw, ct_h.t, ct_w.t, c)                  # (R, th, tw, C)

    r_tot = tiles.shape[0]
    tiles = _pad_axis(tiles, 0, _round_up(r_tot, br))
    tiles = _pad_axis(tiles, 3, _round_up(c_in, bc))

    y = _k_winograd.winograd_fused(
        tiles, u, ct_h=ct_h, ct_w=ct_w, block_r=br, block_c=bc, block_m=bm,
        interpret=interpret)                             # (Rp, mh, mw, Mp)
    y = y[:r_tot, :, :, :c_out].reshape(n, nh, nw, ct_h.m, ct_w.m, c_out)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(
        n, nh * ct_h.m, nw * ct_w.m, c_out)
    return y[:, :geometry.out_h, :geometry.out_w]


def winograd_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int | None = None,
    padding: _wg.Padding = "SAME",
    block_r: int = 128,
    block_c: int = 128,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed F(m x m, k x k) convolution, NHWC x HWIO -> NHWC.

    Unplanned compatibility path: derives the filter transform, geometry and
    block sizes inline, then runs the planned executor. Plan once with
    repro.core.plan.plan_conv2d to skip the derivation on every call.
    """
    n, h, wdt, c = x.shape
    kh, kw, _, mout = w.shape
    if kh == 1 or kw == 1:
        # 1xN / Nx1 / 1x1 layers route through the pure-JAX 1D path (its GEMM
        # is a single matmul XLA already maps to the MXU).
        mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        return _wg.winograd_conv2d(x, w, output_tile=mt, padding=padding)
    mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
    ct_h, ct_w = cook_toom(mt, kh), cook_toom(mt, kw)
    u = _wg.transform_filter_2d(w, ct_h, ct_w)           # (th, tw, C, M)
    u = u.reshape(ct_h.t * ct_w.t, c, mout)

    geometry = _wg.conv2d_geometry(h, wdt, kh, kw, ct_h.m, ct_w.m, padding)
    r_tot = n * geometry.n_h * geometry.n_w
    blocks = winograd_blocks(r_tot, c, mout, block_r=block_r,
                             block_c=block_c, block_m=block_m)
    u = pad_winograd_filter(u, blocks[1], blocks[2])
    return winograd_conv2d_planned(
        x, u, ct_h=ct_h, ct_w=ct_w, geometry=geometry, blocks=blocks,
        c_in=c, c_out=mout, interpret=interpret)


# ---------------------------------------------------------------------------
# im2col conv2d (baseline)
# ---------------------------------------------------------------------------

def im2col_blocks(mm: int, kk: int, mout: int, *, block: int = 128
                  ) -> tuple[int, int, int]:
    """(bm, bk, bn) for the blocked GEMM -- plan-time."""
    return _block(mm, block), _block(kk, block), _block(mout, block)


def pad_im2col_filter(b: jax.Array, bk: int, bn: int) -> jax.Array:
    """Pad the (khkwC, M) filter matrix to the GEMM block grid -- plan-time."""
    kk, mout = b.shape
    return _pad_axis(_pad_axis(b, 0, _round_up(kk, bk)),
                     1, _round_up(mout, bn))


def im2col_conv2d_planned(
    x: jax.Array,
    b: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: tuple[int, int],
    padding: _wg.Padding,
    geometry: _im2col.Im2RowGeometry,
    blocks: tuple[int, int, int],
    c_out: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Execute a planned Pallas im2row conv: `b` is the pre-reshaped,
    pre-padded (Kp, Np) filter matrix; geometry and block sizes come from
    the plan."""
    if interpret is None:
        interpret = _default_interpret()
    n = x.shape[0]
    bm_, bk_, bn_ = blocks
    a, (oh, ow) = _im2col.im2row(x, kh, kw, stride, padding, geometry)
    mm, kk = a.shape
    a = _pad_axis(_pad_axis(a, 0, _round_up(mm, bm_)), 1, _round_up(kk, bk_))
    y = _k_matmul.matmul(a, b, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return y[:mm, :c_out].reshape(n, oh, ow, c_out).astype(x.dtype)


def im2col_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: _wg.Padding = "SAME",
    block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas-backed im2row + GEMM baseline (unplanned compatibility path)."""
    n, h, wdt, c = x.shape
    kh, kw, _, mout = w.shape
    stride = (stride, stride) if isinstance(stride, int) else stride
    geometry = _im2col.im2row_geometry(h, wdt, kh, kw, stride, padding)
    mm = n * geometry.oh * geometry.ow
    blocks = im2col_blocks(mm, kh * kw * c, mout, block=block)
    b = pad_im2col_filter(w.reshape(kh * kw * c, mout), blocks[1], blocks[2])
    return im2col_conv2d_planned(
        x, b, kh=kh, kw=kw, stride=stride, padding=padding, geometry=geometry,
        blocks=blocks, c_out=mout, interpret=interpret)


# ---------------------------------------------------------------------------
# Depthwise causal Cook-Toom conv1d (Mamba short conv)
# ---------------------------------------------------------------------------

def ct_depthwise_causal_conv1d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int = 4,
    block_s: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, L, C) x (r, C) -> (B, L, C), causal."""
    if interpret is None:
        interpret = _default_interpret()
    r, c = w.shape
    b, length, _ = x.shape
    ct = cook_toom(output_tile, r)
    nt = -(-length // ct.m)
    xp = jnp.pad(x, ((0, 0), (r - 1, nt * ct.m - length), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, ct.t, ct.m, nt)    # (B, nt, t, C)
    u = jnp.einsum("ij,jc->ic", jnp.asarray(ct.G, w.dtype), w)

    bs = _block(nt, block_s)
    bc = _block(c, block_c)
    tiles = _pad_axis(tiles, 1, _round_up(nt, bs))
    tiles = _pad_axis(tiles, 3, _round_up(c, bc))
    u = _pad_axis(u, 1, _round_up(c, bc))
    y = _k_conv1d.conv1d_ct_fused(tiles, u, ct=ct, block_s=bs, block_c=bc,
                                  interpret=interpret)
    y = y[:, :nt, :, :c].reshape(b, nt * ct.m, c)
    return y[:, :length]


def matmul(a: jax.Array, b: jax.Array, *, block: int = 128,
           interpret: bool | None = None) -> jax.Array:
    """Padding-tolerant blocked matmul."""
    if interpret is None:
        interpret = _default_interpret()
    m, k = a.shape
    _, n = b.shape
    bm_, bk_, bn_ = _block(m, block), _block(k, block), _block(n, block)
    ap = _pad_axis(_pad_axis(a, 0, _round_up(m, bm_)), 1, _round_up(k, bk_))
    bp = _pad_axis(_pad_axis(b, 0, _round_up(k, bk_)), 1, _round_up(n, bn_))
    return _k_matmul.matmul(ap, bp, bm=bm_, bn=bn_, bk=bk_,
                            interpret=interpret)[:m, :n]
