"""Whisper conv stem (implemented, though stubbed at the dry-run boundary).

The brief mandates that dry-run input_specs() provide precomputed frame
embeddings; this module is the actual stem for smoke tests and examples, and
it is where the paper's 1D algorithm meets the audio arch: conv1 (k=3, s=1)
runs the Cook-Toom F(m,3) path, conv2 (k=3, s=2) runs the polyphase
decomposition into stride-1 Cook-Toom convolutions (core.dispatch.conv1d).

Deployment path: `stem_graph()` expresses the stem as layer IR, so the stem
routes through the same graph compiler as the CNN zoo --
`repro.core.compile.compile(params, stem_graph(d), input_shape=(B, T,
n_mels))` -- including NetworkPlan.save/load artifacts. The legacy
`plan_stem` is a deprecation shim over that compiler.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dispatch import conv1d
from repro.models.config import ArchConfig
from repro.models.layers import truncated_normal_init


def init_stem(key, cfg: ArchConfig, n_mels: int = 80, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "conv1_w": truncated_normal_init(k1, (3, n_mels, d), (3 * n_mels) ** -0.5,
                                         dtype),
        "conv1_b": jnp.zeros((d,), dtype),
        "conv2_w": truncated_normal_init(k2, (3, d, d), (3 * d) ** -0.5, dtype),
        "conv2_b": jnp.zeros((d,), dtype),
    }


def stem_graph(d_model: int):
    """The stem as layer IR: two conv1d nodes (k=3 stride 1, k=3 stride 2),
    each with a fused bias+gelu epilogue. Feed this to
    repro.core.compile.compile(params, stem_graph(d), input_shape=...) --
    the audio stem and the CNN zoo share one compiler."""
    from repro.core.compile import LayerIR
    return (
        LayerIR(id="input", op="input"),
        LayerIR(id="conv1", op="conv1d", inputs=("input",),
                attrs=dict(k=3, c_out=d_model, stride=1, padding="SAME",
                           activation="gelu", w_path=("conv1_w",),
                           b_path=("conv1_b",))),
        LayerIR(id="conv2", op="conv1d", inputs=("conv1",),
                attrs=dict(k=3, c_out=d_model, stride=2, padding="SAME",
                           activation="gelu", w_path=("conv2_w",),
                           b_path=("conv2_b",))),
    )


def plan_stem(params: dict, mel_shape: tuple[int, ...],
              algorithm: str = "auto"):
    """DEPRECATED shim over the graph compiler: returns
    repro.core.compile.compile(params, stem_graph(d), input_shape=
    mel_shape) -- a NetworkPlan keeping the old dict interface
    (plans["conv1"], plans["conv2"]). New code should call compile()
    directly and use NetworkPlan.apply/save/load."""
    from repro.core.compile import compile as _compile, warn_deprecated
    warn_deprecated(
        "models.audio.plan_stem",
        "repro.core.compile.compile(params, audio.stem_graph(d), "
        "input_shape=mel_shape)")
    d_model = params["conv1_w"].shape[2]
    return _compile(params, stem_graph(d_model),
                    input_shape=mel_shape, algorithm=algorithm)


def stem(params: dict, mel: jax.Array, algorithm: str = "auto",
         plans=None) -> jax.Array:
    """mel: (B, T, n_mels) -> frame embeddings (B, T // 2, d_model).

    With `plans` (a NetworkPlan from plan_stem / compile, or a legacy dict
    of Conv1DPlans -- both support ["conv1"]/["conv2"] indexing) the
    convolutions run pre-planned with fused bias+gelu epilogues and no
    per-call filter transform or geometry work. Biases come from the
    `params` passed to THIS call, preserving the legacy contract; callers
    on the compile() API use NetworkPlan.apply directly."""
    if plans is not None:
        x = plans["conv1"].apply(mel, bias=params["conv1_b"],
                                 activation="gelu")
        return plans["conv2"].apply(x, bias=params["conv2_b"],
                                    activation="gelu")
    x = conv1d(mel, params["conv1_w"], stride=1, padding="SAME",
               algorithm=algorithm)
    x = jax.nn.gelu(x + params["conv1_b"])
    x = conv1d(x, params["conv2_w"], stride=2, padding="SAME",
               algorithm=algorithm)
    return jax.nn.gelu(x + params["conv2_b"])
