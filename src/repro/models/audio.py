"""Whisper conv stem (implemented, though stubbed at the dry-run boundary).

The brief mandates that dry-run input_specs() provide precomputed frame
embeddings; this module is the actual stem for smoke tests and examples, and
it is where the paper's 1D algorithm meets the audio arch: conv1 (k=3, s=1)
runs the Cook-Toom F(m,3) path, conv2 (k=3, s=2) runs the polyphase
decomposition into stride-1 Cook-Toom convolutions (core.dispatch.conv1d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dispatch import conv1d
from repro.core.plan import Conv1DPlan, plan_conv1d
from repro.models.config import ArchConfig
from repro.models.layers import truncated_normal_init


def init_stem(key, cfg: ArchConfig, n_mels: int = 80, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "conv1_w": truncated_normal_init(k1, (3, n_mels, d), (3 * n_mels) ** -0.5,
                                         dtype),
        "conv1_b": jnp.zeros((d,), dtype),
        "conv2_w": truncated_normal_init(k2, (3, d, d), (3 * d) ** -0.5, dtype),
        "conv2_b": jnp.zeros((d,), dtype),
    }


def plan_stem(params: dict, mel_shape: tuple[int, ...],
              algorithm: str = "auto") -> dict[str, Conv1DPlan]:
    """Plan both stem convolutions for a fixed (B, T, n_mels) input shape:
    filter transforms (incl. the per-phase polyphase sub-filters of conv2)
    and all tiling geometry happen here, once, at weight-load time."""
    b, t, n_mels = mel_shape
    p1 = plan_conv1d((b, t, n_mels), params["conv1_w"], stride=1,
                     padding="SAME", algorithm=algorithm)
    p2 = plan_conv1d((b, t, params["conv2_w"].shape[1]), params["conv2_w"],
                     stride=2, padding="SAME", algorithm=algorithm)
    return {"conv1": p1, "conv2": p2}


def stem(params: dict, mel: jax.Array, algorithm: str = "auto",
         plans: dict[str, Conv1DPlan] | None = None) -> jax.Array:
    """mel: (B, T, n_mels) -> frame embeddings (B, T // 2, d_model).

    With `plans` (from plan_stem) both convolutions run their pre-built
    Conv1DPlans -- no per-call filter transform or geometry work -- and the
    bias+gelu epilogue goes through the plan's fused path (in-kernel on the
    Pallas executors, one XLA op otherwise)."""
    if plans is not None:
        x = plans["conv1"].apply(mel, bias=params["conv1_b"],
                                 activation="gelu")
        return plans["conv2"].apply(x, bias=params["conv2_b"],
                                    activation="gelu")
    x = conv1d(mel, params["conv1_w"], stride=1, padding="SAME",
               algorithm=algorithm)
    x = jax.nn.gelu(x + params["conv1_b"])
    x = conv1d(x, params["conv2_w"], stride=2, padding="SAME",
               algorithm=algorithm)
    return jax.nn.gelu(x + params["conv2_b"])
