"""Token-choice top-k Mixture-of-Experts with capacity-bounded scatter dispatch.

Design notes (compile-safety at 512 devices drove these choices):

* Dispatch is *scatter-based*, not GShard-style one-hot-einsum: the (T, E, C)
  one-hot dispatch tensor of the einsum formulation is O(tokens x experts x
  capacity) and does not fit HBM at our shapes; the scatter formulation only
  materializes the (E, C, D) expert buffer, which shards over the expert axis.
* All shapes are static: capacity C = ceil(T / E) * top_k * capacity_factor.
  Tokens routed past an expert's capacity are dropped (standard Switch
  semantics); the router aux loss pushes the distribution flat.
* Expert FFNs run as one batched einsum (E, C, D) x (E, D, F) so the expert
  axis can shard over the `model` mesh axis (expert parallelism). When
  n_experts does not divide the model axis (granite's 40 on 16), shard_mode
  "tp" shards F instead and replicates the small expert axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, MoEConfig
from repro.models.layers import truncated_normal_init

_F32 = jnp.float32


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    p = {
        "router": truncated_normal_init(ks[0], (d, e), scale_in, _F32),
        "up": truncated_normal_init(ks[1], (e, d, f), scale_in, dtype),
        "down": truncated_normal_init(ks[2], (e, f, d), scale_out, dtype),
    }
    if cfg.act == "swiglu":
        p["gate"] = truncated_normal_init(ks[3], (e, d, f), scale_in, dtype)
    return p


def _expert_ffn(p, xs: jax.Array, act: str) -> jax.Array:
    """(E, C, D) -> (E, C, D) batched over experts."""
    up = jnp.einsum("ecd,edf->ecf", xs, p["up"].astype(xs.dtype),
                    preferred_element_type=_F32).astype(xs.dtype)
    if act == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", xs, p["gate"].astype(xs.dtype),
                          preferred_element_type=_F32)
        h = (jax.nn.silu(gate) .astype(xs.dtype)) * up
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(up.astype(_F32))).astype(xs.dtype)
    else:
        h = jax.nn.gelu(up.astype(_F32)).astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(xs.dtype),
                      preferred_element_type=_F32).astype(xs.dtype)


def moe_block(p, x: jax.Array, cfg: ArchConfig,
              dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    dropless=True sets capacity = T so no token can overflow (exact token-
    choice routing). This is the *serving* semantics: capacity dropping is a
    batch-composition-dependent approximation (a token's output changes with
    its batch neighbours -- even acausally), acceptable under the training
    aux-loss but not in inference, where prefill+decode must reproduce the
    full forward pass bit-for-contract. Training keeps the capacity bound
    (static scatter buffer (E, C, D) stays O(T * cap_factor) not O(T * E)).
    """
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.matmul(xf.astype(_F32), p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)      # (T, k)
    if m.top_k > 1:                                            # renormalize
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    if dropless:
        capacity = t
    else:
        capacity = int(m.capacity_factor * t * 1.0 / m.n_experts) * 1 + 1
        capacity = max(capacity, 4)

    y = jnp.zeros((t, d), x.dtype)
    for k in range(m.top_k):
        eid = expert_ids[:, k]                                  # (T,)
        gv = gate_vals[:, k].astype(x.dtype)                    # (T,)
        onehot = jax.nn.one_hot(eid, m.n_experts, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0)[jnp.arange(t), eid] - 1  # (T,)
        keep = pos < capacity
        pos_c = jnp.where(keep, pos, capacity)                  # overflow slot
        # scatter tokens into the (E, C+1, D) buffer (slot C is the dropout
        # bin); buffer shards over E (ep) or D (tp).
        buf = jnp.zeros((m.n_experts, capacity + 1, d), x.dtype)
        buf = buf.at[eid, pos_c].set(xf)
        out = _expert_ffn(p, buf[:, :capacity], cfg.act)        # (E, C, D)
        out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))
        gathered = out[eid, pos_c]                              # (T, D)
        y = y + gathered * (gv * keep.astype(x.dtype))[:, None]

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], m.n_experts, dtype=_F32),
                  axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    return y.reshape(b, s, d), aux
