"""Mamba-1 (S6) block: gated selective state-space layer.

The short depthwise causal conv (k = d_conv) is where the paper's technique
lands in this family: it routes through a cached region-wise 1D Cook-Toom
plan (core.plan.plan_depthwise_conv1d -> core.winograd /
kernels.conv1d_ct), cutting the conv multiply count by m*r/t (F(4,4): 2.29x)
with the transform set, tile counts and padding decided once per shape.
`SSMConfig.conv_algorithm` switches between cook_toom and the direct conv
for the A/B benchmarks.

Selective scan: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t + D x_t.
Implemented as a *chunked* linear recurrence: sequential lax.scan over chunks
of `scan_chunk` tokens carrying (B, d_inner, N) state, associative_scan inside
each chunk -- bounds the materialized (chunk, d_inner, N) tensors so the 500k
context dry-run fits, while keeping within-chunk parallelism for the VPU.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.plan import plan_depthwise_conv1d
from repro.models.config import ArchConfig
from repro.models.layers import dense, truncated_normal_init

_F32 = jnp.float32


def _use_pallas_scan() -> bool:
    """Route the selective scan through the fused Pallas kernel. On by
    default on TPU (where it is the structural fix for the SSM memory wall,
    EXPERIMENTS.md section Perf falcon iteration 3); opt-in elsewhere via
    REPRO_PALLAS_SCAN=1 (interpret mode -- tests use this)."""
    if os.environ.get("REPRO_PALLAS_SCAN"):
        return True
    return jax.default_backend() == "tpu"


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or cfg.d_model // 16
    return s, d_in, dt_rank


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    s, d_in, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": truncated_normal_init(ks[0], (d, 2 * d_in), d ** -0.5, dtype),
        "conv_w": truncated_normal_init(ks[1], (s.d_conv, d_in),
                                        s.d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": truncated_normal_init(ks[2], (d_in, dt_rank + 2 * s.d_state),
                                        d_in ** -0.5, dtype),
        "dt_proj": truncated_normal_init(ks[3], (dt_rank, d_in),
                                         dt_rank ** -0.5, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (d_in,), _F32,
                jnp.log(1e-3), jnp.log(1e-1))), 1e-4, None))).astype(_F32),
        # S4D-real init: A = -(1 .. N), stored as log(-A).
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, s.d_state + 1, dtype=_F32)),
            (d_in, s.d_state)).copy(),
        "d_skip": jnp.ones((d_in,), _F32),
        "out_proj": truncated_normal_init(ks[5], (d_in, d), d_in ** -0.5, dtype),
    }
    return p


def _chunked_selective_scan(dt, xs, bmat, cmat, a_mat, chunk: int):
    """Linear recurrence h_t = exp(dt_t A) h_{t-1} + (dt_t B_t x_t),
    contracted with C inside each chunk.

    Perf-critical structure (EXPERIMENTS.md section Perf, falcon/jamba cells):

      * Discretization happens INSIDE the chunk body: the (B, L, d_in, N)
        tensors a_bar / bx never exist at full sequence length -- only
        (B, chunk, d_in, N) transients. At falcon train_4k shapes the full-
        length form is 2 x 17 GB/device/layer of HBM traffic (plus remat
        copies); in-chunk it is 2 x 17/nc GB live, streamed.
      * chunk_step is jax.checkpoint'd: the backward pass recomputes the
        chunk's state trajectory instead of stacking (nc, B, chunk, d_in, N)
        scan residuals (which alone exceeded a v5e's 16 GB HBM).
      * Only the (B, d_in, N) carry crosses chunk boundaries.

    dt, xs: (B, L, d_in) f32/any; bmat, cmat: (B, L, N); a_mat: (d_in, N).
    L % chunk == 0. Returns y: (B, L, d_in) f32, final_state: (B, d_in, N) f32.
    """
    b, l, d_in = dt.shape
    n = a_mat.shape[-1]
    nc = l // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1))

    dt_c, xs_c, b_c, c_c = map(to_chunks, (dt, xs, bmat, cmat))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk_step(h, inputs):
        dtc, xc, bc, cc = inputs              # (B, chunk, d_in) / (B, chunk, N)
        ac = jnp.exp(dtc[..., None] * a_mat[None, None])   # (B, chunk, d_in, N)
        bxc = (dtc * xc)[..., None] * bc[:, :, None, :]
        # prefix products within the chunk, seeded by the carried state.
        a_acc, b_acc = jax.lax.associative_scan(combine, (ac, bxc), axis=1)
        h_all = a_acc * h[:, None] + b_acc    # (B, chunk, d_in, N)
        y = jnp.einsum("blds,bls->bld", h_all, cc)
        return h_all[:, -1], y

    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    h_last, y = jax.lax.scan(chunk_step, h0, (dt_c, xs_c, b_c, c_c))
    return y.transpose(1, 0, 2, 3).reshape(b, l, d_in), h_last


# ---------------------------------------------------------------------------
# Fused-kernel scan path: Pallas forward (state in VMEM, HBM traffic =
# inputs + outputs), recompute-based backward through the XLA chunked
# formulation (the two agree to 1e-5 -- tests/test_selective_scan.py).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _selective_scan_fused(dt, xs, bmat, cmat, a_mat, chunk):
    from repro.kernels.selective_scan import selective_scan
    d = dt.shape[-1]
    block_d = 512 if (d > 512 and d % 512 == 0) else d
    return selective_scan(dt, xs, bmat, cmat, a_mat,
                          chunk=min(chunk, dt.shape[1]), block_d=block_d,
                          interpret=jax.default_backend() != "tpu")


def _ssf_fwd(dt, xs, bmat, cmat, a_mat, chunk):
    out = _selective_scan_fused(dt, xs, bmat, cmat, a_mat, chunk)
    return out, (dt, xs, bmat, cmat, a_mat)


def _ssf_bwd(chunk, res, cts):
    dt, xs, bmat, cmat, a_mat = res
    _, vjp = jax.vjp(
        lambda *args: _chunked_selective_scan(*args, chunk=chunk),
        dt, xs, bmat, cmat, a_mat)
    return vjp(cts)


_selective_scan_fused.defvjp(_ssf_fwd, _ssf_bwd)


def mamba_block(p, x: jax.Array, cfg: ArchConfig,
                return_state: bool = False):
    """x: (B, L, D) -> (B, L, D). Training / prefill path.

    With return_state, also returns the decode cache {"conv", "ssm"} at the
    final position (prefill).
    """
    s, d_in, dt_rank = _dims(cfg)
    b, l, _ = x.shape
    xz = dense(x, p["in_proj"])                        # (B, L, 2*d_in)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs_raw = xs                                        # pre-conv (decode cache)

    if s.conv_algorithm == "cook_toom":
        # Planned path: the F(m, r) transform set, tile count, padding and
        # blocking come from the process-level plan cache (decided once per
        # (L, C) shape); only the tap transform + input work are per-call.
        conv_plan = plan_depthwise_conv1d(xs.shape,
                                          p["conv_w"].astype(xs.dtype))
        xs = conv_plan.apply(xs)
    else:
        pad = jnp.pad(xs, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        xs = sum(pad[:, k:k + l] * p["conv_w"][k].astype(xs.dtype)[None, None]
                 for k in range(s.d_conv))
    xs = jax.nn.silu((xs + p["conv_b"].astype(xs.dtype)).astype(_F32)).astype(x.dtype)

    proj = dense(xs, p["x_proj"])                      # (B, L, dt_rank + 2N)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dense(dt, p["dt_proj"]).astype(_F32)
                         + p["dt_bias"])               # (B, L, d_in)
    a = -jnp.exp(p["a_log"])                           # (d_in, N)

    chunk = min(s.scan_chunk, l)
    if l % chunk:
        chunk = l                                       # tiny smoke shapes
    # discretization (a_bar = exp(dt A), b_bar x = dt B_t x_t) happens inside
    # the chunk scan -- see _chunked_selective_scan.
    scan_fn = (_selective_scan_fused if _use_pallas_scan()
               else functools.partial(_chunked_selective_scan, chunk=chunk))
    args = (dt, xs.astype(_F32), bmat.astype(_F32), cmat.astype(_F32), a)
    y, h_last = (scan_fn(*args, chunk) if scan_fn is _selective_scan_fused
                 else scan_fn(*args))
    y = (y + xs.astype(_F32) * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(_F32)).astype(x.dtype)
    out = dense(y, p["out_proj"])
    if not return_state:
        return out
    conv_cache = xs_raw[:, -(s.d_conv - 1):]            # (B, k-1, d_in)
    if l < s.d_conv - 1:
        conv_cache = jnp.pad(conv_cache, ((0, 0), (s.d_conv - 1 - l, 0), (0, 0)))
    return out, {"conv": conv_cache, "ssm": h_last}


# ---------------------------------------------------------------------------
# Single-token decode (recurrent form) -- O(1) per token, the reason the
# long_500k shape is runnable for the SSM/hybrid archs.
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    s, d_in, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, s.d_state), _F32),
    }


def mamba_decode_step(p, x: jax.Array, cache: dict,
                      cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """x: (B, 1, D) -> (B, 1, D), updating {conv, ssm} cache."""
    s, d_in, dt_rank = _dims(cfg)
    b = x.shape[0]
    xz = dense(x[:, 0], p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                   # (B, d_in)

    window = jnp.concatenate([cache["conv"], xs[:, None]], axis=1)  # (B,k,d_in)
    conv_out = jnp.sum(window * p["conv_w"].astype(xs.dtype)[None], axis=1)
    new_conv = window[:, 1:]
    xs = jax.nn.silu((conv_out + p["conv_b"].astype(xs.dtype))
                     .astype(_F32)).astype(x.dtype)

    proj = dense(xs, p["x_proj"])
    dt, bvec, cvec = jnp.split(proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dense(dt, p["dt_proj"]).astype(_F32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    a_bar = jnp.exp(dt[..., None] * a[None])            # (B, d_in, N)
    bx = (dt * xs.astype(_F32))[..., None] * bvec.astype(_F32)[:, None, :]
    h = a_bar * cache["ssm"] + bx                       # (B, d_in, N)
    y = jnp.einsum("bds,bs->bd", h, cvec.astype(_F32))
    y = (y + xs.astype(_F32) * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(_F32)).astype(x.dtype)
    out = dense(y, p["out_proj"])[:, None]
    return out, {"conv": new_conv, "ssm": h}
