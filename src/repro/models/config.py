"""Architecture configuration dataclasses.

One frozen dataclass describes every architecture in the assigned pool (dense,
MoE, SSM, hybrid, enc-dec, early-fusion VLM backbones) plus the paper's CNNs.
Configs are data, models are functions (models/transformer.py).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence

Activation = Literal["swiglu", "gelu", "squared_relu"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1          # MoE on layers where idx % every_k == 0
    capacity_factor: float = 1.25
    #: "ep" shards the expert axis over the model mesh axis; "tp" shards the
    #: per-expert FFN dim instead (used when n_experts % mesh_model != 0).
    shard_mode: Literal["ep", "tp"] = "ep"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block hyperparameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default d_model // 16
    #: chunk length for the blockwise associative scan (memory/parallelism
    #: trade-off; see DESIGN.md).
    scan_chunk: int = 256
    #: route the depthwise conv through the Cook-Toom kernel (the paper's
    #: technique applied to this arch family) vs direct conv.
    conv_algorithm: Literal["cook_toom", "direct"] = "cook_toom"


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder (conv stem stubbed at the input boundary)."""
    n_layers: int
    n_ctx: int = 1500                 # post-conv frame count
    #: the conv stem itself (k=3 stride 1 + k=3 stride 2) is implemented in
    #: models/audio.py and exercised by tests/examples; for dry-run
    #: input_specs() the brief mandates precomputed frame embeddings.


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    act: Activation = "swiglu"
    qkv_bias: bool = False
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    #: hybrid (jamba): one attention layer per `attn_every` layers, rest Mamba.
    attn_every: Optional[int] = None
    encoder: Optional[EncoderConfig] = None
    rope_theta: float = 10_000.0
    pos_emb: Literal["rope", "learned", "none"] = "rope"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 32_768
    #: layers are scanned in repeating units of this many layers (jamba's
    #: period is 8); n_layers % scan_unit == 0.
    scan_unit: int = 1
    #: sub-quadratic attention available => long_500k shape is runnable.
    subquadratic: bool = False
    #: vocab chunk for the memory-bounded cross-entropy (see transformer.py).
    logits_chunk: int = 512

    def __post_init__(self):
        if self.n_layers % self.scan_unit:
            raise ValueError("n_layers must divide into scan units")
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def n_units(self) -> int:
        return self.n_layers // self.scan_unit

    def layer_kind(self, idx_in_unit: int) -> str:
        """'attn' | 'mamba' for position idx within a scan unit."""
        if self.family in ("ssm",):
            return "mamba"
        if self.attn_every:
            # jamba places its attention layer in the middle of each period.
            return "attn" if idx_in_unit == self.attn_every // 2 else "mamba"
        return "attn"

    def layer_is_moe(self, idx_in_unit: int) -> bool:
        return self.moe is not None and idx_in_unit % self.moe.every_k_layers == 0

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_unit = 0
        for i in range(self.scan_unit):
            kind = self.layer_kind(i)
            if kind == "attn":
                per_unit += d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
                    self.n_heads * hd * d
            else:
                s = self.ssm
                d_in = s.expand * d
                dt_rank = s.dt_rank or d // 16
                per_unit += d * 2 * d_in + s.d_conv * d_in + \
                    d_in * (dt_rank + 2 * s.d_state) + dt_rank * d_in + \
                    d_in * s.d_state + d_in * d
            if self.layer_is_moe(i):
                m = self.moe
                mult = 3 if self.act == "swiglu" else 2
                per_unit += m.n_experts * mult * d * m.d_ff_expert + d * m.n_experts
            else:
                mult = 3 if self.act == "swiglu" else 2
                per_unit += mult * d * self.d_ff
        total += per_unit * self.n_units
        if self.encoder:
            per_enc = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
                self.n_heads * hd * d + 2 * d * self.d_ff
            total += per_enc * self.encoder.n_layers
        return total

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params
        m = self.moe
        mult = 3 if self.act == "swiglu" else 2
        inactive_per_moe_layer = (m.n_experts - m.top_k) * mult * \
            self.d_model * m.d_ff_expert
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.scan_unit)) \
            * self.n_units
        return self.n_params - inactive_per_moe_layer * n_moe_layers
