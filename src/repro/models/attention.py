"""GQA attention with optional QKV bias, qk-norm, RoPE, KV cache decode, and
cross-attention (enc-dec). Pure functions over parameter dicts.

Shapes: activations (B, S, D); heads are split out only inside this module.
KV cache layout: {"k": (B, L_max, Hkv, hd), "v": ..., } with a scalar
`cache_pos` carried by the caller (serving runtime).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, dense, rms_norm, truncated_normal_init

_F32 = jnp.float32
_NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "wq": truncated_normal_init(ks[0], (d, cfg.n_heads * hd), scale, dtype),
        "wk": truncated_normal_init(ks[1], (d, cfg.n_kv_heads * hd), scale, dtype),
        "wv": truncated_normal_init(ks[2], (d, cfg.n_kv_heads * hd), scale, dtype),
        "wo": truncated_normal_init(ks[3], (cfg.n_heads * hd, d),
                                    (cfg.n_heads * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qkv(p, x, cfg: ArchConfig, positions, *, rope: bool):
    hd = cfg.head_dim
    q = _split_heads(dense(x, p["wq"], p.get("bq")), cfg.n_heads, hd)
    k = _split_heads(dense(x, p["wk"], p.get("bk")), cfg.n_kv_heads, hd)
    v = _split_heads(dense(x, p["wv"], p.get("bv")), cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask: Optional[jax.Array], n_rep: int) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Sk,Hkv,hd); GQA via head grouping (no KV
    materialization at H width -- keeps decode memory-bound term minimal)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    q = q.reshape(b, sq, hkv, n_rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q.astype(_F32), k.astype(_F32))
    scores = scores * (hd ** -0.5)
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v.astype(_F32))
    return out.reshape(b, sq, h, hd).astype(v.dtype)


def self_attention(p, x, cfg: ArchConfig, *, causal: bool = True,
                   positions=None) -> jax.Array:
    """Full self-attention over (B, S, D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _qkv(p, x, cfg, positions, rope=True)
    mask = None
    if causal:
        mask = (jnp.arange(s)[:, None] >= jnp.arange(s)[None, :])[None]
    out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    return dense(out.reshape(b, s, -1), p["wo"])


def cross_attention(p, x, kv_cache: dict, cfg: ArchConfig) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = _split_heads(dense(x, p["wq"], p.get("bq")), cfg.n_heads, hd)
    out = _sdpa(q, kv_cache["k"], kv_cache["v"], None,
                cfg.n_heads // cfg.n_kv_heads)
    return dense(out.reshape(b, s, -1), p["wo"])


def encode_cross_kv(p, enc_out, cfg: ArchConfig) -> dict:
    hd = cfg.head_dim
    return {
        "k": _split_heads(dense(enc_out, p["wk"], p.get("bk")), cfg.n_kv_heads, hd),
        "v": _split_heads(dense(enc_out, p["wv"], p.get("bv")), cfg.n_kv_heads, hd),
    }


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_self_attention(p, x, cache: dict, cache_pos: jax.Array,
                          cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """One-token decode step. x: (B, 1, D); cache k/v: (B, L_max, Hkv, hd);
    cache_pos: scalar int32 -- number of tokens already in the cache."""
    b, s, _ = x.shape
    assert s == 1
    positions = jnp.full((b, 1), cache_pos, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions, rope=True)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, cache_pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, cache_pos, 0, 0))
    l_max = k.shape[1]
    mask = (jnp.arange(l_max)[None, None, :] <= cache_pos)   # (1,1,L)
    out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    y = dense(out.reshape(b, 1, -1), p["wo"])
    return y, {"k": k, "v": v}
