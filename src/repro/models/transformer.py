"""Model assembly: decoder LMs (dense / MoE / SSM / hybrid) and enc-dec.

Structure:
  * Per-layer parameters are *stacked over scan units* (leading axis
    cfg.n_units) and the layer stack runs under jax.lax.scan + jax.checkpoint.
    This keeps the lowered HLO O(1 scan-unit) -- essential for compiling 96
    layers x 512 devices in the dry-run -- and bounds activation live range to
    one unit (remat policy saves only the residual stream).
  * The loss head is *vocab-chunked*: logits are computed (B, chunk, V) a
    chunk at a time under a scan and immediately reduced to per-token loss, so
    the (B, S, V) logits tensor never materializes (319 TB for qwen1.5 at the
    train_4k shape).
  * Activation sharding constraints are injected through
    repro.distributed.context (no-ops off-mesh), keeping model code
    mesh-agnostic.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed import context as dist
from repro.models import attention as attn
from repro.models import mamba as ssm
from repro.models import moe as moe_lib
from repro.models.config import ArchConfig
from repro.models.layers import (dense, init_mlp, layer_norm, mlp, rms_norm,
                                 truncated_normal_init)

_F32 = jnp.float32
Params = Any


def _norm(x, p, cfg: ArchConfig):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def _init_norm(cfg: ArchConfig, dtype, with_bias=False):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_unit(key, cfg: ArchConfig, dtype) -> dict:
    """One scan unit = cfg.scan_unit consecutive layers (dict keyed by idx)."""
    unit = {}
    for i in range(cfg.scan_unit):
        key, k1, k2, k3 = jax.random.split(key, 4)
        kind = cfg.layer_kind(i)
        layer = {"ln1": _init_norm(cfg, dtype)}
        if kind == "attn":
            layer["attn"] = attn.init_attention(k1, cfg, dtype)
        else:
            layer["mamba"] = ssm.init_mamba(k1, cfg, dtype)
        if cfg.layer_is_moe(i):
            layer["ln2"] = _init_norm(cfg, dtype)
            layer["moe"] = moe_lib.init_moe(k2, cfg, dtype)
        elif cfg.d_ff > 0:
            layer["ln2"] = _init_norm(cfg, dtype)
            layer["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)
        # d_ff == 0 (pure Mamba families): the mixer is the whole layer.
        if cfg.encoder is not None:
            layer["ln_x"] = _init_norm(cfg, dtype)
            layer["xattn"] = attn.init_attention(k3, cfg, dtype)
        unit[f"layer_{i}"] = layer
    return unit


def _init_encoder(key, cfg: ArchConfig, dtype) -> dict:
    enc = cfg.encoder
    layers = []
    for _ in range(enc.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({
            "ln1": _init_norm(cfg, dtype, with_bias=True),
            "attn": attn.init_attention(k1, cfg, dtype),
            "ln2": _init_norm(cfg, dtype, with_bias=True),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    key, kp = jax.random.split(key)
    return {
        "layers": stacked,
        "pos_emb": truncated_normal_init(kp, (enc.n_ctx, cfg.d_model), 0.02,
                                         dtype),
        "ln_f": _init_norm(cfg, dtype, with_bias=True),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    key, k_emb, k_head, k_enc = jax.random.split(key, 4)
    units = []
    for _ in range(cfg.n_units):
        key, ku = jax.random.split(key)
        units.append(_init_unit(ku, cfg, dtype))
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    params = {
        "embed": truncated_normal_init(k_emb, (cfg.vocab, cfg.d_model),
                                       cfg.d_model ** -0.5, dtype),
        "blocks": blocks,
        "ln_f": _init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = truncated_normal_init(
            k_head, (cfg.vocab, cfg.d_model), cfg.d_model ** -0.5, dtype)
    if cfg.encoder is not None:
        params["encoder"] = _init_encoder(k_enc, cfg, dtype)
    if cfg.pos_emb == "learned":
        key, kp = jax.random.split(key)
        params["pos_emb"] = truncated_normal_init(
            kp, (cfg.max_seq, cfg.d_model), 0.02, dtype)
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.key(0))


# ---------------------------------------------------------------------------
# Forward: one scan unit
# ---------------------------------------------------------------------------

def _unit_forward(unit: dict, x: jax.Array, cfg: ArchConfig,
                  cross_kv: Optional[dict] = None,
                  dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """(B, S, D) -> (B, S, D), plus summed MoE aux loss.

    Note (EXPERIMENTS.md section Perf, jamba iteration 2 -- REFUTED): adding
    per-layer jax.checkpoint inside the unit-level nothing_saveable remat
    *doubled* peak temp (104.6 -> 213.6 GB/dev) -- nested remat regions made
    XLA keep both the unit-level and layer-level recompute buffers live.
    Layers therefore run unwrapped inside the unit.
    """

    def one_layer(x, i, layer):
        aux = jnp.zeros((), _F32)
        kind = cfg.layer_kind(i)
        h = _norm(x, layer["ln1"], cfg)
        if kind == "attn":
            h = attn.self_attention(layer["attn"], h, cfg, causal=True)
        else:
            h = ssm.mamba_block(layer["mamba"], h, cfg)
        x = dist.shard_activations(x + h, "residual")
        if cross_kv is not None:
            h = _norm(x, layer["ln_x"], cfg)
            h = attn.cross_attention(layer["xattn"], h, cross_kv, cfg)
            x = x + h
        if cfg.layer_is_moe(i):
            h = _norm(x, layer["ln2"], cfg)
            h, a = moe_lib.moe_block(layer["moe"], h, cfg, dropless=dropless)
            aux = aux + a
            x = dist.shard_activations(x + h, "residual")
        elif cfg.d_ff > 0:
            h = _norm(x, layer["ln2"], cfg)
            h = mlp(h, layer["mlp"], cfg.act)
            x = dist.shard_activations(x + h, "residual")
        return x, aux

    aux = jnp.zeros((), _F32)
    for i in range(cfg.scan_unit):
        x, a = one_layer(x, i, unit[f"layer_{i}"])
        aux = aux + a
    return x, aux


def _run_blocks(params: Params, x: jax.Array, cfg: ArchConfig,
                cross_kv: Optional[dict] = None,
                dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, unit):
        x, aux = carry
        x, a = _unit_forward(unit, x, cfg, cross_kv, dropless=dropless)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), _F32)), params["blocks"])
    return x, aux


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params: Params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, n_ctx, D) precomputed stem embeddings -> (B, n_ctx, D)."""
    enc = params["encoder"]
    x = frames + enc["pos_emb"][None, :frames.shape[1]].astype(frames.dtype)

    def body(x, layer):
        h = _norm(x, layer["ln1"], cfg)
        x = x + attn.self_attention(layer["attn"], h, cfg, causal=False)
        h = _norm(x, layer["ln2"], cfg)
        x = x + mlp(h, layer["mlp"], "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return _norm(x, enc["ln_f"], cfg)


# ---------------------------------------------------------------------------
# Loss (vocab-chunked cross-entropy)
# ---------------------------------------------------------------------------

def _chunked_xent(x: jax.Array, head: jax.Array, labels: jax.Array,
                  chunk: int) -> jax.Array:
    """x: (B, S, D), head: (V, D), labels: (B, S) -> scalar mean loss.

    Scans over sequence chunks so only (B, chunk, V) logits are live.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, inputs):
        xi, li = inputs                                  # (B, chunk, D/int)
        logits = jnp.einsum("bcd,vd->bcv", xi.astype(_F32),
                            head.astype(_F32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(_F32)
        return tot + jnp.sum((lse - gold) * valid), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), _F32), (xc, lc))
    n_valid = jnp.maximum(jnp.sum((labels >= 0).astype(_F32)), 1.0)
    return tot / n_valid


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def embed_tokens(params: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.pos_emb == "learned":
        pos = jnp.arange(tokens.shape[1])
        x = x + params["pos_emb"][pos][None].astype(x.dtype)
    return dist.shard_activations(x, "residual")


def forward(params: Params, batch: dict, cfg: ArchConfig) -> jax.Array:
    """Training loss. batch: {tokens, labels[, frames]} -> scalar."""
    cross_kv = None
    if cfg.encoder is not None:
        enc_out = encode(params, batch["frames"], cfg)
        # cross K/V computed once from the first unit's xattn params is NOT
        # correct per-layer; each layer projects its own K/V inside the scan.
        cross_kv = {"enc_out": enc_out}
    x = embed_tokens(params, batch["tokens"], cfg)
    if cross_kv is not None:
        x, aux = _run_blocks_encdec(params, x, cross_kv["enc_out"], cfg)
    else:
        x, aux = _run_blocks(params, x, cfg)
    x = _norm(x, params["ln_f"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    loss = _chunked_xent(x, head, batch["labels"], cfg.logits_chunk)
    return loss + 0.01 * aux


def _run_blocks_encdec(params, x, enc_out, cfg, dropless: bool = False):
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, unit):
        x, aux = carry
        kv = attn.encode_cross_kv(unit["layer_0"]["xattn"], enc_out, cfg)
        x, a = _unit_forward(unit, x, cfg, cross_kv=kv, dropless=dropless)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), _F32)), params["blocks"])
    return x, aux


def forward_logits(params: Params, tokens: jax.Array, cfg: ArchConfig,
                   frames: jax.Array | None = None) -> jax.Array:
    """Full logits (small inputs only -- smoke tests / examples). Inference
    semantics: MoE routing is dropless (see moe.moe_block)."""
    if cfg.encoder is not None:
        enc_out = encode(params, frames, cfg)
        x = embed_tokens(params, tokens, cfg)
        x, _ = _run_blocks_encdec(params, x, enc_out, cfg, dropless=True)
    else:
        x = embed_tokens(params, tokens, cfg)
        x, _ = _run_blocks(params, x, cfg, dropless=True)
    x = _norm(x, params["ln_f"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", x.astype(_F32), head.astype(_F32))


# ---------------------------------------------------------------------------
# Decode (single-token serve step with caches)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Stacked per-unit caches. Attention layers get KV caches; Mamba layers
    get (conv, ssm) state; enc-dec layers additionally carry read-only
    cross-attention K/V filled at prefill. Keyed like the parameter tree."""
    unit_cache = {}
    for i in range(cfg.scan_unit):
        if cfg.layer_kind(i) == "attn":
            c = dict(attn.init_kv_cache(cfg, batch, max_len, dtype))
        else:
            c = dict(ssm.init_mamba_cache(cfg, batch, dtype))
        if cfg.encoder is not None:
            xc = attn.init_kv_cache(cfg, batch, cfg.encoder.n_ctx, dtype)
            c["xk"], c["xv"] = xc["k"], xc["v"]
        unit_cache[f"layer_{i}"] = c
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_units,) + x.shape).copy(),
        unit_cache)


def abstract_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                          dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, batch, max_len, dtype))


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                cache_pos: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """One decode step. tokens: (B, 1) int32 -> logits (B, V), updated cache.

    cache_pos: scalar int32, number of tokens already decoded/prefilled.
    Cross-attention K/V (enc-dec) live read-only in the cache ("xk"/"xv").
    """
    x = params["embed"][tokens]
    if cfg.pos_emb == "learned":
        x = x + params["pos_emb"][cache_pos][None, None].astype(x.dtype)
    x = x.astype(params["embed"].dtype)

    def body(x, inputs):
        unit, ucache = inputs
        new_cache = {}
        for i in range(cfg.scan_unit):
            layer = unit[f"layer_{i}"]
            lcache = dict(ucache[f"layer_{i}"])
            xk = lcache.pop("xk", None)
            xv = lcache.pop("xv", None)
            h = _norm(x, layer["ln1"], cfg)
            if cfg.layer_kind(i) == "attn":
                h, nc = attn.decode_self_attention(layer["attn"], h, lcache,
                                                   cache_pos, cfg)
            else:
                h, nc = ssm.mamba_decode_step(layer["mamba"], h, lcache, cfg)
            x = dist.shard_activations(x + h, "decode")
            if xk is not None:
                nc = dict(nc)
                nc["xk"], nc["xv"] = xk, xv
                h = _norm(x, layer["ln_x"], cfg)
                x = x + attn.cross_attention(layer["xattn"], h,
                                             {"k": xk, "v": xv}, cfg)
            new_cache[f"layer_{i}"] = nc
            if cfg.layer_is_moe(i):
                h = _norm(x, layer["ln2"], cfg)
                h, _ = moe_lib.moe_block(layer["moe"], h, cfg, dropless=True)
                x = x + h
            elif cfg.d_ff > 0:
                h = _norm(x, layer["ln2"], cfg)
                h = mlp(h, layer["mlp"], cfg.act)
                x = x + h
            x = dist.shard_activations(x, "decode")
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = _norm(x, params["ln_f"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(_F32), head.astype(_F32))
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Prefill: run the full prompt, emit logits for the last position and a
# populated decode cache (the inference-prefill shape of the dry-run).
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig,
            max_len: int, frames: jax.Array | None = None,
            dropless: bool = True) -> tuple[jax.Array, dict]:
    """tokens: (B, S) -> (last-token logits (B, V), decode cache at pos=S).

    Cache emission rides on the layer scan: each unit returns its K/V (or
    final SSM state) as scan ys.

    dropless: exact MoE routing (serving semantics). The 32k-prefill dry-run
    cells pass dropless=False -- at 1M tokens the dropless (E, T, D) scatter
    buffer would dwarf HBM, so bulk prefill accepts capacity-bounded routing
    (documented approximation, EXPERIMENTS.md section Dry-run).
    """
    b, s = tokens.shape
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params, frames, cfg)
    x = embed_tokens(params, tokens, cfg)

    def body(x, unit):
        cache_unit = {}
        for i in range(cfg.scan_unit):
            layer = unit[f"layer_{i}"]
            h = _norm(x, layer["ln1"], cfg)
            if cfg.layer_kind(i) == "attn":
                q, k, v = attn._qkv(layer["attn"], h, cfg, jnp.arange(s),
                                    rope=True)
                mask = (jnp.arange(s)[:, None] >= jnp.arange(s)[None, :])[None]
                o = attn._sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
                h = dense(o.reshape(b, s, -1), layer["attn"]["wo"])
                kpad = jnp.zeros((b, max_len - s) + k.shape[2:], k.dtype)
                cache_unit[f"layer_{i}"] = {
                    "k": jnp.concatenate([k, kpad], axis=1),
                    "v": jnp.concatenate([v, kpad], axis=1)}
            else:
                h, st = ssm.mamba_block(layer["mamba"], h, cfg,
                                        return_state=True)
                cache_unit[f"layer_{i}"] = st
            x = dist.shard_activations(x + h, "residual")
            if enc_out is not None:
                kv = attn.encode_cross_kv(layer["xattn"], enc_out, cfg)
                cache_unit[f"layer_{i}"]["xk"] = kv["k"]
                cache_unit[f"layer_{i}"]["xv"] = kv["v"]
                h = _norm(x, layer["ln_x"], cfg)
                x = x + attn.cross_attention(layer["xattn"], h, kv, cfg)
            if cfg.layer_is_moe(i):
                h = _norm(x, layer["ln2"], cfg)
                h, _ = moe_lib.moe_block(layer["moe"], h, cfg,
                                         dropless=dropless)
                x = dist.shard_activations(x + h, "residual")
            elif cfg.d_ff > 0:
                h = _norm(x, layer["ln2"], cfg)
                h = mlp(h, layer["mlp"], cfg.act)
                x = dist.shard_activations(x + h, "residual")
        return x, cache_unit

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = _norm(x[:, -1:], params["ln_f"], cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(_F32), head.astype(_F32))
    return logits[:, 0], cache
