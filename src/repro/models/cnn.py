"""The paper's evaluation networks -- VGG-16/19, GoogleNet (Inception-v1),
Inception-v3, SqueezeNet -- plus the depthwise-separable MobileNet-v1
family, built on the unified conv dispatcher.

Every convolution goes through repro.core.dispatch.conv2d, so a whole network
can be flipped between the paper's region-wise multi-channel Winograd scheme
and the im2row baseline with one `algorithm=` argument -- exactly the paper's
two benchmark configurations (Table 1 / Fig 3: fast scheme on suitable
layers, im2row elsewhere vs im2row everywhere).

Networks are expressed as layer-spec lists; `init_cnn` / `cnn_forward`
interpret them. Inference-only (the paper measures single-batch latency).

Deployment path (the paper's section-4 insight): the spec lists lower into
the graph compiler -- `repro.core.compile.compile(params, specs, res=...)`
-- whose fusion passes reconstitute the separable / inverted-residual
execution units and whose NetworkPlan executes with zero per-call filter or
geometry work and serializes to a deployment artifact (save/load). The
legacy `plan_cnn` / `cnn_forward(plans=...)` entry points are deprecation
shims over that compiler.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Literal, Sequence

import jax
import jax.numpy as jnp

from repro.core.dispatch import Algorithm, winograd_suitable
from repro.core.plan import ConvPlan, algorithm_supported
from repro.models.layers import (conv2d_layer, dense_head, init_conv2d,
                                 pool2d)

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Conv:
    name: str
    kh: int
    kw: int
    c_out: int
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    groups: int = 1                    # feature_group_count (must divide the
                                       # incoming channel count at this spot)
    activation: str | None = None      # epilogue override ("relu6", ...);
                                       # None falls back to the relu flag

    @property
    def act(self) -> str:
        return self.activation or ("relu" if self.relu else "none")


@dataclasses.dataclass(frozen=True)
class SeparableConv:
    """MobileNet depthwise-separable unit: k x k depthwise conv (groups =
    C_in, channel multiplier 1) + 1x1 pointwise conv, bias+ReLU after each.
    Lowers to the unfused dw -> pw conv chain; the compiler's fuse pass
    (repro.core.compile) rewrites it to ONE separable node, so the Pallas
    path fuses the whole block into a single streamed kernel."""

    name: str
    k: int
    c_out: int
    stride: int = 1
    padding: str = "SAME"


@dataclasses.dataclass(frozen=True)
class InvertedResidual:
    """MobileNet-v2 inverted residual unit (Sandler et al. 2018): 1x1
    expand (xfactor, relu6) -> kxk depthwise (stride s, relu6) -> 1x1
    linear projection, residual add when stride 1 and C_in == C_out.
    Lowers to the unfused expand -> dw -> project [-> add] chain; the
    compiler's fuse pass rewrites it to ONE inverted-residual node whose
    depthwise+project pair rides the separable-block machinery, so the
    Pallas path fuses it into a single streamed kernel; stride-2 blocks
    route the depthwise half through the strided Winograd executors."""

    name: str
    c_out: int
    stride: int = 1
    expand: int = 6                    # expansion factor t
    k: int = 3


@dataclasses.dataclass(frozen=True)
class Pool:
    kind: Literal["max", "avg"]
    k: int
    stride: int
    padding: str = "VALID"


@dataclasses.dataclass(frozen=True)
class Concat:
    """Parallel branches (inception); each branch is a spec list."""
    branches: Sequence[Sequence[Any]]


@dataclasses.dataclass(frozen=True)
class GlobalAvgPool:
    pass


@dataclasses.dataclass(frozen=True)
class Dense:
    name: str
    n_out: int
    relu: bool = True


# ---------------------------------------------------------------------------
# interpreter
# ---------------------------------------------------------------------------

def _out_size(size, k, stride, padding):
    if padding == "SAME":
        return -(-size // stride)
    return (size - k) // stride + 1


def init_cnn(key, specs, c_in: int, dtype=_F32, res: int = 224) -> dict:
    """Eagerly initializes every layer, tracking (h, w, c) through the spec
    walk so Dense weights get their flattened input dim up front (lazy init
    under jit leaks tracers across compilations)."""
    params: dict = {}

    def walk(specs, h, w, c, key):
        for spec in specs:
            if isinstance(spec, Conv):
                key, k1 = jax.random.split(key)
                params[spec.name] = init_conv2d(k1, spec.kh, spec.kw, c,
                                                spec.c_out, dtype,
                                                groups=spec.groups)
                h = _out_size(h, spec.kh, spec.stride, spec.padding)
                w = _out_size(w, spec.kw, spec.stride, spec.padding)
                c = spec.c_out
            elif isinstance(spec, SeparableConv):
                key, k1, k2 = jax.random.split(key, 3)
                params[spec.name] = {
                    "dw": init_conv2d(k1, spec.k, spec.k, c, c, dtype,
                                      groups=c),
                    "pw": init_conv2d(k2, 1, 1, c, spec.c_out, dtype)}
                h = _out_size(h, spec.k, spec.stride, spec.padding)
                w = _out_size(w, spec.k, spec.stride, spec.padding)
                c = spec.c_out
            elif isinstance(spec, InvertedResidual):
                key, k1, k2, k3 = jax.random.split(key, 4)
                ce = c * spec.expand
                p = {"dw": init_conv2d(k2, spec.k, spec.k, ce, ce, dtype,
                                       groups=ce),
                     "pw": init_conv2d(k3, 1, 1, ce, spec.c_out, dtype)}
                if spec.expand != 1:
                    p["exp"] = init_conv2d(k1, 1, 1, c, ce, dtype)
                params[spec.name] = p
                h = _out_size(h, spec.k, spec.stride, "SAME")
                w = _out_size(w, spec.k, spec.stride, "SAME")
                c = spec.c_out
            elif isinstance(spec, Pool):
                h = _out_size(h, spec.k, spec.stride, spec.padding)
                w = _out_size(w, spec.k, spec.stride, spec.padding)
            elif isinstance(spec, Concat):
                outs = []
                for br in spec.branches:
                    key, kb = jax.random.split(key)
                    outs.append(walk(br, h, w, c, kb))
                h, w = outs[0][0], outs[0][1]
                c = sum(o[2] for o in outs)
            elif isinstance(spec, GlobalAvgPool):
                h = w = 1
            elif isinstance(spec, Dense):
                key, k1 = jax.random.split(key)
                n_in = h * w * c
                params[spec.name] = {
                    "w": (n_in ** -0.5) * jax.random.normal(
                        k1, (n_in, spec.n_out), dtype)}
                h = w = 1
                c = spec.n_out
        return h, w, c

    walk(specs, res, res, c_in, key)
    return params


def _layer_algorithm(spec: Conv, algorithm: Algorithm,
                     c_in: int | None = None) -> Algorithm:
    """Forced winograd/Pallas settings fall back to im2col on layers their
    executor does not cover (unsuitable filter/stride, grouped constraints)
    -- the paper's mixed policy applied to a forced global setting. The
    coverage rules live in ONE place: plan.algorithm_supported."""
    if algorithm_supported(algorithm, spec.kh, spec.kw, spec.stride,
                           groups=spec.groups, c_in=c_in, c_out=spec.c_out):
        return algorithm
    return "im2col"


def plan_cnn(params: dict, specs, *, res: int, c_in: int = 3, batch: int = 1,
             algorithm: Algorithm = "auto"):
    """DEPRECATED shim over the graph compiler: returns
    repro.core.compile.compile(params, specs, res=...), a NetworkPlan. The
    NetworkPlan keeps the old dict interface (plans[name], .values(), ...)
    over its per-layer plans, and cnn_forward(plans=...) delegates to
    NetworkPlan.apply -- but new code should call compile() directly and
    use NetworkPlan.apply/save/load. All fusion decisions (separable
    blocks, inverted residuals) now live in the compiler's pattern-rewrite
    passes, not here."""
    from repro.core.compile import compile as _compile, warn_deprecated
    warn_deprecated(
        "models.cnn.plan_cnn",
        "repro.core.compile.compile(params, specs, res=...)")
    return _compile(params, specs, res=res, c_in=c_in, batch=batch,
                    algorithm=algorithm)


def _pool(x, spec: Pool):
    return pool2d(x, spec.kind, spec.k, spec.stride, spec.padding)


def cnn_forward(params: dict, x: jax.Array, specs,
                algorithm: Algorithm = "auto",
                layer_times: dict | None = None,
                plans: dict[str, ConvPlan] | None = None) -> jax.Array:
    """Run the network. `algorithm` selects the conv scheme globally ("auto"
    = the paper's mixed policy). `plans` is DEPRECATED: compile the network
    with repro.core.compile.compile and call net.apply(x) directly instead.
    The shim keeps the exact legacy contract -- the spec walk below executes
    each pre-built plan by name (a NetworkPlan from plan_cnn supports the
    old dict interface) while biases and dense-head weights come from the
    `params` passed to THIS call, not from compile-time constants.
    layer_times: optional dict to collect per-layer conv descriptors for
    the benchmark harness (unplanned path)."""
    if plans is not None:
        from repro.core.compile import warn_deprecated
        warn_deprecated("models.cnn.cnn_forward(plans=...)",
                        "repro.core.compile.compile(...).apply(x)")

    def walk(x, specs):
        for spec in specs:
            if isinstance(spec, Conv):
                if layer_times is not None:
                    layer_times[spec.name] = dict(
                        kh=spec.kh, kw=spec.kw, c_in=x.shape[-1],
                        c_out=spec.c_out, h=x.shape[1], w=x.shape[2],
                        stride=spec.stride, groups=spec.groups,
                        suitable=winograd_suitable(spec.kh, spec.kw, spec.stride))
                x = conv2d_layer(
                    params[spec.name], x, activation=spec.act,
                    plan=plans.get(spec.name) if plans else None,
                    stride=spec.stride, padding=spec.padding,
                    groups=spec.groups,
                    algorithm=_layer_algorithm(spec, algorithm, x.shape[-1]))
            elif isinstance(spec, SeparableConv):
                p = params[spec.name]
                c = x.shape[-1]
                if layer_times is not None:
                    layer_times[f"{spec.name}_dw"] = dict(
                        kh=spec.k, kw=spec.k, c_in=c, c_out=c,
                        h=x.shape[1], w=x.shape[2], stride=spec.stride,
                        groups=c,
                        suitable=winograd_suitable(spec.k, spec.k,
                                                   spec.stride))
                    layer_times[f"{spec.name}_pw"] = dict(
                        kh=1, kw=1, c_in=c, c_out=spec.c_out,
                        h=_out_size(x.shape[1], spec.k, spec.stride,
                                    spec.padding),
                        w=_out_size(x.shape[2], spec.k, spec.stride,
                                    spec.padding),
                        stride=1, groups=1, suitable=False)
                if plans:
                    x = plans[spec.name].apply(
                        x, bias_dw=p["dw"]["b"], bias_pw=p["pw"]["b"])
                else:
                    from repro.core.dispatch import conv2d
                    dw_spec = Conv(spec.name, spec.k, spec.k, c,
                                   stride=spec.stride, padding=spec.padding,
                                   groups=c)
                    x = conv2d(x, p["dw"]["w"], stride=spec.stride,
                               padding=spec.padding, groups=c,
                               algorithm=_layer_algorithm(dw_spec, algorithm,
                                                          c),
                               bias=p["dw"]["b"], activation="relu")
                    pw_spec = Conv(f"{spec.name}_pw", 1, 1, spec.c_out)
                    x = conv2d(x, p["pw"]["w"],
                               algorithm=_layer_algorithm(pw_spec, algorithm,
                                                          c),
                               bias=p["pw"]["b"], activation="relu")
            elif isinstance(spec, InvertedResidual):
                p = params[spec.name]
                c = x.shape[-1]
                ce = c * spec.expand
                if layer_times is not None:
                    layer_times[f"{spec.name}_dw"] = dict(
                        kh=spec.k, kw=spec.k, c_in=ce, c_out=ce,
                        h=x.shape[1], w=x.shape[2], stride=spec.stride,
                        groups=ce,
                        suitable=winograd_suitable(spec.k, spec.k,
                                                   spec.stride))
                if plans:
                    x = plans[spec.name].apply(
                        x, bias_exp=p["exp"]["b"] if "exp" in p else None,
                        bias_dw=p["dw"]["b"], bias_pw=p["pw"]["b"])
                else:
                    from repro.core.dispatch import conv2d
                    h = x
                    if "exp" in p:
                        h = conv2d(h, p["exp"]["w"], bias=p["exp"]["b"],
                                   activation="relu6", algorithm="im2col")
                    dw_spec = Conv(spec.name, spec.k, spec.k, ce,
                                   stride=spec.stride, groups=ce)
                    h = conv2d(h, p["dw"]["w"], stride=spec.stride,
                               groups=ce, bias=p["dw"]["b"],
                               activation="relu6",
                               algorithm=_layer_algorithm(dw_spec, algorithm,
                                                          ce))
                    h = conv2d(h, p["pw"]["w"], bias=p["pw"]["b"],
                               activation="none", algorithm="im2col")
                    x = x + h if (spec.stride == 1
                                  and c == spec.c_out) else h
            elif isinstance(spec, Pool):
                x = _pool(x, spec)
            elif isinstance(spec, Concat):
                x = jnp.concatenate([walk(x, br) for br in spec.branches],
                                    axis=-1)
            elif isinstance(spec, GlobalAvgPool):
                x = jnp.mean(x, axis=(1, 2))
            elif isinstance(spec, Dense):
                x = dense_head(x, params[spec.name]["w"], spec.relu)
        return x
    return walk(x, specs)


# ---------------------------------------------------------------------------
# network definitions
# ---------------------------------------------------------------------------

def _vgg_block(name, n, c):
    return [Conv(f"{name}_{i}", 3, 3, c) for i in range(n)] + \
        [Pool("max", 2, 2)]


def vgg16():
    return (
        _vgg_block("conv1", 2, 64) + _vgg_block("conv2", 2, 128)
        + _vgg_block("conv3", 3, 256) + _vgg_block("conv4", 3, 512)
        + _vgg_block("conv5", 3, 512)
        + [Dense("fc6", 4096), Dense("fc7", 4096), Dense("fc8", 1000, relu=False)]
    )


def vgg19():
    return (
        _vgg_block("conv1", 2, 64) + _vgg_block("conv2", 2, 128)
        + _vgg_block("conv3", 4, 256) + _vgg_block("conv4", 4, 512)
        + _vgg_block("conv5", 4, 512)
        + [Dense("fc6", 4096), Dense("fc7", 4096), Dense("fc8", 1000, relu=False)]
    )


def _fire(name, squeeze, expand):
    return [
        Conv(f"{name}_sq", 1, 1, squeeze),
        Concat([[Conv(f"{name}_e1", 1, 1, expand)],
                [Conv(f"{name}_e3", 3, 3, expand)]]),
    ]


def squeezenet():
    # SqueezeNet 1.0
    s = [Conv("conv1", 7, 7, 96, stride=2), Pool("max", 3, 2)]
    s += _fire("fire2", 16, 64) + _fire("fire3", 16, 64) + _fire("fire4", 32, 128)
    s += [Pool("max", 3, 2)]
    s += _fire("fire5", 32, 128) + _fire("fire6", 48, 192) + \
        _fire("fire7", 48, 192) + _fire("fire8", 64, 256)
    s += [Pool("max", 3, 2)]
    s += _fire("fire9", 64, 256)
    s += [Conv("conv10", 1, 1, 1000), GlobalAvgPool()]
    return s


def _inception_v1(name, c1, c3r, c3, c5r, c5, cp):
    return Concat([
        [Conv(f"{name}_1x1", 1, 1, c1)],
        [Conv(f"{name}_3r", 1, 1, c3r), Conv(f"{name}_3x3", 3, 3, c3)],
        [Conv(f"{name}_5r", 1, 1, c5r), Conv(f"{name}_5x5", 5, 5, c5)],
        [Pool("max", 3, 1, "SAME"), Conv(f"{name}_pp", 1, 1, cp)],
    ])


def googlenet():
    return [
        Conv("conv1", 7, 7, 64, stride=2), Pool("max", 3, 2, "SAME"),
        Conv("conv2r", 1, 1, 64), Conv("conv2", 3, 3, 192),
        Pool("max", 3, 2, "SAME"),
        _inception_v1("i3a", 64, 96, 128, 16, 32, 32),
        _inception_v1("i3b", 128, 128, 192, 32, 96, 64),
        Pool("max", 3, 2, "SAME"),
        _inception_v1("i4a", 192, 96, 208, 16, 48, 64),
        _inception_v1("i4b", 160, 112, 224, 24, 64, 64),
        _inception_v1("i4c", 128, 128, 256, 24, 64, 64),
        _inception_v1("i4d", 112, 144, 288, 32, 64, 64),
        _inception_v1("i4e", 256, 160, 320, 32, 128, 128),
        Pool("max", 3, 2, "SAME"),
        _inception_v1("i5a", 256, 160, 320, 32, 128, 128),
        _inception_v1("i5b", 384, 192, 384, 48, 128, 128),
        GlobalAvgPool(), Dense("fc", 1000, relu=False),
    ]


def _inc3_a(name, cp):
    return Concat([
        [Conv(f"{name}_1x1", 1, 1, 64)],
        [Conv(f"{name}_5r", 1, 1, 48), Conv(f"{name}_5x5", 5, 5, 64)],
        [Conv(f"{name}_3r", 1, 1, 64), Conv(f"{name}_3a", 3, 3, 96),
         Conv(f"{name}_3b", 3, 3, 96)],
        [Pool("avg", 3, 1, "SAME"), Conv(f"{name}_pp", 1, 1, cp)],
    ])


def _inc3_b(name, c7):
    return Concat([
        [Conv(f"{name}_1x1", 1, 1, 192)],
        [Conv(f"{name}_7r", 1, 1, c7), Conv(f"{name}_1x7a", 1, 7, c7),
         Conv(f"{name}_7x1a", 7, 1, 192)],
        [Conv(f"{name}_7rr", 1, 1, c7), Conv(f"{name}_7x1b", 7, 1, c7),
         Conv(f"{name}_1x7b", 1, 7, c7), Conv(f"{name}_7x1c", 7, 1, c7),
         Conv(f"{name}_1x7c", 1, 7, 192)],
        [Pool("avg", 3, 1, "SAME"), Conv(f"{name}_pp", 1, 1, 192)],
    ])


def _inc3_c(name):
    return Concat([
        [Conv(f"{name}_1x1", 1, 1, 320)],
        [Conv(f"{name}_3r", 1, 1, 384),
         Concat([[Conv(f"{name}_1x3a", 1, 3, 384)],
                 [Conv(f"{name}_3x1a", 3, 1, 384)]])],
        [Conv(f"{name}_dr", 1, 1, 448), Conv(f"{name}_d3", 3, 3, 384),
         Concat([[Conv(f"{name}_1x3b", 1, 3, 384)],
                 [Conv(f"{name}_3x1b", 3, 1, 384)]])],
        [Pool("avg", 3, 1, "SAME"), Conv(f"{name}_pp", 1, 1, 192)],
    ])


def inception_v3():
    return [
        Conv("conv1", 3, 3, 32, stride=2, padding="VALID"),
        Conv("conv2", 3, 3, 32, padding="VALID"),
        Conv("conv3", 3, 3, 64),
        Pool("max", 3, 2),
        Conv("conv4", 1, 1, 80, padding="VALID"),
        Conv("conv5", 3, 3, 192, padding="VALID"),
        Pool("max", 3, 2),
        _inc3_a("m1", 32), _inc3_a("m2", 64), _inc3_a("m3", 64),
        # reduction A
        Concat([[Conv("rA_3", 3, 3, 384, stride=2, padding="VALID")],
                [Conv("rA_r", 1, 1, 64), Conv("rA_3a", 3, 3, 96),
                 Conv("rA_3b", 3, 3, 96, stride=2, padding="VALID")],
                [Pool("max", 3, 2)]]),
        _inc3_b("m4", 128), _inc3_b("m5", 160), _inc3_b("m6", 160),
        _inc3_b("m7", 192),
        # reduction B
        Concat([[Conv("rB_r1", 1, 1, 192),
                 Conv("rB_3", 3, 3, 320, stride=2, padding="VALID")],
                [Conv("rB_r2", 1, 1, 192), Conv("rB_1x7", 1, 7, 192),
                 Conv("rB_7x1", 7, 1, 192),
                 Conv("rB_3b", 3, 3, 192, stride=2, padding="VALID")],
                [Pool("max", 3, 2)]]),
        _inc3_c("m8"), _inc3_c("m9"),
        GlobalAvgPool(), Dense("fc", 1000, relu=False),
    ]


def _make_divisible(c: float, divisor: int = 8) -> int:
    """The slim/MobileNet channel rounding: nearest multiple of `divisor`
    (floored at `divisor`), bumped up one step if rounding dropped more
    than 10% -- the reference convention both MobileNets use, so scaled
    channel counts match published checkpoints at every width multiplier."""
    v = max(int(c + divisor / 2) // divisor * divisor, divisor)
    if v < 0.9 * c:
        v += divisor
    return v


#: MobileNet-v1 body: (c_out, stride) of each depthwise-separable block
#: (Howard et al. 2017, Table 1), after the stride-2 3x3 stem.
_MOBILENET_V1_BLOCKS = (
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
)


def mobilenet_v1(width_mult: float = 1.0):
    """MobileNet-v1: a stride-2 3x3 stem + 13 depthwise-separable blocks.

    `width_mult` is the paper's width multiplier alpha: every channel count
    is scaled through the slim `make_divisible` rounding. Each
    SeparableConv is planned as one fused unit by plan_cnn."""
    def ch(c: int) -> int:
        return _make_divisible(c * width_mult)

    s = [Conv("conv1", 3, 3, ch(32), stride=2)]
    s += [SeparableConv(f"sep{i + 2}", 3, ch(c), stride=st)
          for i, (c, st) in enumerate(_MOBILENET_V1_BLOCKS)]
    s += [GlobalAvgPool(), Dense("fc", 1000, relu=False)]
    return s


def mobilenet_v1_050():
    """MobileNet-v1 at width multiplier 0.5."""
    return mobilenet_v1(width_mult=0.5)


#: MobileNet-v2 body: (expand t, c_out, repeats n, first-stride s) of each
#: inverted-residual stage (Sandler et al. 2018, Table 2).
_MOBILENET_V2_STAGES = (
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
)


def mobilenet_v2(width_mult: float = 1.0):
    """MobileNet-v2: stride-2 3x3 stem (relu6), 17 inverted-residual blocks,
    1x1 head conv, classifier. Each InvertedResidual is planned as one
    fused unit by plan_cnn; the stride-2 reduction blocks route their
    depthwise half through the strided Winograd executors."""
    def ch(c: int) -> int:
        return _make_divisible(c * width_mult)

    s = [Conv("conv1", 3, 3, ch(32), stride=2, activation="relu6")]
    i = 0
    for t, c, n, st in _MOBILENET_V2_STAGES:
        for j in range(n):
            s.append(InvertedResidual(f"ir{i + 1}", ch(c),
                                      stride=st if j == 0 else 1, expand=t))
            i += 1
    head = ch(1280) if width_mult > 1.0 else 1280
    s += [Conv("conv_head", 1, 1, head, activation="relu6"),
          GlobalAvgPool(), Dense("fc", 1000, relu=False)]
    return s


NETWORKS = {
    "vgg16": (vgg16, 224),
    "vgg19": (vgg19, 224),
    "googlenet": (googlenet, 224),
    "inception_v3": (inception_v3, 299),
    "squeezenet": (squeezenet, 224),
    "mobilenet_v1": (mobilenet_v1, 224),
    "mobilenet_v1_050": (mobilenet_v1_050, 224),
    "mobilenet_v2": (mobilenet_v2, 224),
}
