"""Shared model layers: norms, MLPs, rotary embeddings, initializers.

Pure functions over explicit parameter pytrees (dicts of jnp arrays). All
matmuls keep the contracted operand layouts MXU-friendly (trailing dims are
the model/ff axes) and accumulate in fp32 via preferred_element_type.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any
_F32 = jnp.float32


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                _F32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(_F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(_F32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(_F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(_F32) + bias.astype(_F32)).astype(dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _dense_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x, w.astype(x.dtype),
                      preferred_element_type=_F32).astype(x.dtype)


def _dense_mm_fwd(x, w):
    return _dense_mm(x, w), (x, w)


def _dense_mm_bwd(res, dy):
    """Mixed-precision backward: the cotangent is cast to the weight dtype
    BEFORE the two grad matmuls. Without this, XLA propagates the fp32
    accumulation dtype into the backward pass, and on FSDP-sharded weights
    the per-layer weight all-gather and the gradient all-reduce both travel
    in fp32 -- 2x the wire bytes (measured on the nemotron train_4k cell:
    41.6% of collective bytes were fp32 grad all-reduces; EXPERIMENTS.md
    section Perf). Accumulation across microbatches stays fp32 in the train
    step, which is the standard bf16-grads / fp32-accumulate recipe."""
    x, w = res
    dy = dy.astype(w.dtype)
    dx = jnp.matmul(dy, w.T.astype(dy.dtype),
                    preferred_element_type=_F32).astype(x.dtype)
    contract = x.ndim - 1
    # dw output/accumulation dtype = the weight dtype: the SPMD psum of the
    # per-shard partials (the FSDP gradient all-reduce) then travels in bf16
    # instead of fp32 -- the cast must precede the collective, so it has to
    # be the dot's own output dtype. (On TPU the MXU still accumulates fp32
    # internally and rounds once on output.) fp32 accumulation ACROSS
    # microbatches is preserved by the train step's fp32 grad buffer.
    dw = jax.lax.dot_general(
        x.astype(dy.dtype), dy,
        dimension_numbers=(
            (tuple(range(contract)), tuple(range(contract))), ((), ())),
        preferred_element_type=w.dtype)
    return dx, dw


_dense_mm.defvjp(_dense_mm_fwd, _dense_mm_bwd)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = _dense_mm(x, w)
    if b is not None:
        y = (y.astype(_F32) + b.astype(_F32)).astype(x.dtype)
    return y


def mlp(x: jax.Array, p: Params, act: str) -> jax.Array:
    """SwiGLU ('gate'/'up'/'down') or 2-matrix ('up'/'down') MLP."""
    if act == "swiglu":
        g = dense(x, p["gate"])
        u = dense(x, p["up"])
        h = jax.nn.silu(g.astype(_F32)).astype(x.dtype) * u
    elif act == "gelu":
        h = jax.nn.gelu(dense(x, p["up"]).astype(_F32)).astype(x.dtype)
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(dense(x, p["up"]).astype(_F32))).astype(x.dtype)
    else:
        raise ValueError(act)
    return dense(h, p["down"])


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {"up": truncated_normal_init(ks[0], (d_model, d_ff), scale_in, dtype),
         "down": truncated_normal_init(ks[1], (d_ff, d_model), scale_out, dtype)}
    if act == "swiglu":
        p["gate"] = truncated_normal_init(ks[2], (d_model, d_ff), scale_in, dtype)
    return p


# ---------------------------------------------------------------------------
# Convolution layers (plan/execute split)
# ---------------------------------------------------------------------------

def init_conv2d(key, kh: int, kw: int, c_in: int, c_out: int,
                dtype=jnp.float32, groups: int = 1) -> Params:
    """He-style conv init, HWIO weight + bias. Grouped filters carry
    c_in/groups input channels (groups = c_in is a depthwise conv)."""
    if c_in % groups or c_out % groups:
        raise ValueError(f"groups={groups} must divide c_in={c_in} and "
                         f"c_out={c_out}")
    cg = c_in // groups
    scale = (kh * kw * cg) ** -0.5
    return {"w": scale * jax.random.normal(key, (kh, kw, cg, c_out), dtype),
            "b": jnp.zeros((c_out,), dtype)}


def conv2d_layer(p: Params, x: jax.Array, *, plan=None, relu: bool = True,
                 activation: str | None = None, **conv_kwargs) -> jax.Array:
    """Conv + bias + epilogue activation. `activation` (any name in
    kernels.runtime.ACTIVATIONS, e.g. "relu6" for MobileNet-v2) overrides
    the legacy `relu` flag. With `plan` (any LayerPlan with the ConvPlan
    apply contract, built once at init/weight-load/compile time) execution
    performs no per-call filter transform or geometry work, and the
    bias+activation epilogue rides the plan's fused path (in-kernel on the
    Pallas executors -- the conv output never revisits HBM for the
    elementwise work). Without a plan, falls back to the per-call
    dispatcher (conv_kwargs: stride/padding/algorithm/...)."""
    if activation is None:
        activation = "relu" if relu else "none"
    if plan is not None:
        return plan.apply(x, bias=p["b"], activation=activation)
    from repro.core.dispatch import conv2d
    return conv2d(x, p["w"], bias=p["b"], activation=activation,
                  **conv_kwargs)


def dense_head(x: jax.Array, w: jax.Array, relu: bool = True) -> jax.Array:
    """Classifier head: flatten all non-batch axes, matmul, optional ReLU.
    The one implementation behind both the spec-walk interpreter
    (models.cnn.cnn_forward) and the compiled graph executor
    (repro.core.compile.NetworkPlan.apply), so their Dense semantics cannot
    diverge."""
    y = x.reshape(x.shape[0], -1) @ w
    return jax.nn.relu(y) if relu else y


def pool2d(x: jax.Array, kind: str, k: int, stride: int,
           padding: str) -> jax.Array:
    """Max/avg spatial pooling over NHWC (avg divides by the full window,
    matching lax's SAME-padding convention). Like dense_head, this is the
    ONE pooling implementation shared by the spec-walk interpreter and the
    compiled graph executor."""
    init = -jnp.inf if kind == "max" else 0.0
    op = jax.lax.max if kind == "max" else jax.lax.add
    y = jax.lax.reduce_window(x, init, op, (1, k, k, 1),
                              (1, stride, stride, 1), padding)
    if kind == "avg":
        y = y / (k * k)
    return y


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=_F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions.astype(_F32)[..., None] * freqs      # (..., S, hd/2)
    if angles.ndim == 2:                                     # (S, hd/2)
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
