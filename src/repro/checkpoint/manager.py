"""Async sharded checkpointing with atomic commits and elastic restore.

Layout:
  <dir>/step_<k>.tmp/      -- in-flight write
  <dir>/step_<k>/          -- committed (atomic os.replace of the tmp dir)
      manifest.json        -- step, flat param paths, shapes/dtypes
      arrays.npz           -- one entry per flattened leaf

* Writes run on a background thread (training continues; `wait()` joins).
* Restore reshards to the *current* mesh: leaves are device_put against the
  shardings derived from the live mesh, so a checkpoint written on a 2-pod
  mesh restores onto 1 pod (elastic scale-down) and vice versa.
* keep_last bounds disk usage; partial (.tmp) dirs are ignored on restore,
  so a crash mid-write can never corrupt the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot `tree` at `step`. Non-blocking by default: the host copy
        happens synchronously (consistency), the disk write on a thread."""
        self.wait()
        flat = _flatten(jax.device_get(tree))

        def write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                manifest = {"step": step,
                            "keys": sorted(flat),
                            "shapes": {k: list(v.shape) for k, v in flat.items()},
                            "dtypes": {k: str(v.dtype) for k, v in flat.items()}}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). With `shardings`, leaves are device_put against
        the current mesh (elastic resharding)."""
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}

        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves_with_path))
        out = []
        for (path_k, leaf), sh in zip(leaves_with_path, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path_k)
            arr = flat[key].astype(leaf.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)
