"""Cook-Toom / Winograd transform-matrix generation.

Generates exact (rational-arithmetic) transform matrices for the minimal
bilinear algorithm F(m, r): m correlation outputs of an r-tap filter over an
n = m + r - 1 input window, using n multiplications instead of m * r.

Construction (transposition principle, cf. Blahut ch. 5 / Barabasz et al.):

  Linear convolution of a (len m) and b (len r) via evaluation-interpolation at
  n points (n-1 finite + the point at infinity) is

      c = V^{-1} [(E_m a) . (E_r b)]

  where E_k is the n x k Vandermonde evaluation matrix (infinity row selects
  the leading coefficient) and V = E_n. Correlation is the transpose of
  convolution-by-the-filter, which yields

      y = A^T [(G g) . (B^T d)]

  with  A^T = E_m^T  (m x n),   G = E_r  (n x r),   B^T = V^{-T}  (n x n).

All arithmetic is done in exact fractions; the float matrices returned are the
correctly rounded values. The identity is verified numerically in tests for
every variant used by the system (no hand-copied literature matrices).
"""

from __future__ import annotations

import functools
import math
from fractions import Fraction
from typing import NamedTuple, Sequence

import numpy as np

# Interpolation points, in the order they are consumed. Chosen per the
# Toom-Cook error-analysis literature (small symmetric rationals) to keep the
# fp32 error of the large variants acceptable.
_POINTS: Sequence[Fraction] = tuple(
    Fraction(p)
    for p in (0, 1, -1, 2, -2, Fraction(1, 2), Fraction(-1, 2), 4, -4,
              Fraction(1, 4), Fraction(-1, 4), 8, -8)
)


class CookToom(NamedTuple):
    """Transform set for F(m, r).

    The matrices are stored as nested tuples so the whole object is hashable
    (it is passed as a static argument to jitted Pallas wrappers); the .AT /
    .G / .BT properties expose them as float64 numpy arrays.
    """

    m: int            # outputs per tile
    r: int            # filter taps
    t: int            # input tile size  (= m + r - 1)
    at_rows: tuple    # (m, t) output (inverse) transform -- paper's Z^T
    g_rows: tuple     # (t, r) filter transform           -- paper's W
    bt_rows: tuple    # (t, t) input transform            -- paper's X^T

    @property
    def AT(self) -> np.ndarray:
        return np.array(self.at_rows, dtype=np.float64)

    @property
    def G(self) -> np.ndarray:
        return np.array(self.g_rows, dtype=np.float64)

    @property
    def BT(self) -> np.ndarray:
        return np.array(self.bt_rows, dtype=np.float64)

    @property
    def mult_reduction_1d(self) -> float:
        """Theoretical multiplication reduction for the 1D algorithm."""
        return (self.m * self.r) / self.t

    @property
    def mult_reduction_2d(self) -> float:
        """Theoretical multiplication reduction for the nested 2D algorithm."""
        return (self.m * self.r) ** 2 / self.t**2


def _vandermonde(points: Sequence[Fraction], cols: int) -> list[list[Fraction]]:
    """(len(points)+1) x cols evaluation matrix; final row = point at infinity."""
    rows = [[p**j for j in range(cols)] for p in points]
    rows.append([Fraction(0)] * (cols - 1) + [Fraction(1)])
    return rows


def _invert(mat: list[list[Fraction]]) -> list[list[Fraction]]:
    """Exact Gauss-Jordan inverse over the rationals."""
    n = len(mat)
    a = [row[:] + [Fraction(int(i == j)) for j in range(n)]
         for i, row in enumerate(mat)]
    for col in range(n):
        piv = next(i for i in range(col, n) if a[i][col] != 0)
        a[col], a[piv] = a[piv], a[col]
        inv = Fraction(1) / a[col][col]
        a[col] = [v * inv for v in a[col]]
        for i in range(n):
            if i != col and a[i][col] != 0:
                f = a[i][col]
                a[i] = [vi - f * vc for vi, vc in zip(a[i], a[col])]
    return [row[n:] for row in a]


def _to_rows(mat: list[list[Fraction]]) -> tuple:
    return tuple(tuple(float(v) for v in row) for row in mat)


@functools.lru_cache(maxsize=None)
def cook_toom(m: int, r: int) -> CookToom:
    """Build the F(m, r) transform set.

    Args:
      m: outputs per tile (>= 1).
      r: filter taps (>= 1).
    """
    if m < 1 or r < 1:
        raise ValueError(f"F({m}, {r}): m and r must be >= 1")
    t = m + r - 1
    if t - 1 > len(_POINTS):
        raise ValueError(f"F({m}, {r}) needs {t - 1} finite points; "
                         f"only {len(_POINTS)} configured")
    pts = _POINTS[: t - 1]
    E_m = _vandermonde(pts, m)           # n x m
    E_r = _vandermonde(pts, r)           # n x r
    V = _vandermonde(pts, t)             # n x n
    V_inv = _invert(V)
    # B^T = V^{-T}
    BT = [[V_inv[j][i] for j in range(t)] for i in range(t)]
    AT = [[E_m[j][i] for j in range(t)] for i in range(m)]   # E_m^T
    return CookToom(m=m, r=r, t=t, at_rows=_to_rows(AT), g_rows=_to_rows(E_r),
                    bt_rows=_to_rows(BT))


@functools.lru_cache(maxsize=None)
def scaled_cook_toom(m: int, r: int) -> CookToom:
    """F(m, r) with per-evaluation-point row scaling (Barabasz et al.).

    Large variants such as F(6, 3) mix very small and very large entries in
    B^T, so the fp32 input transform loses relative precision on the rows
    with large dynamic range. Scaling each B^T row p by the power of two
    nearest its max-abs entry -- and compensating exactly by the inverse
    scale on the matching G row -- leaves the bilinear identity unchanged
    (the pointwise product (G g)_p * (B^T d)_p is scale-invariant) while
    equalizing row magnitudes. Power-of-two scales only shift the exponent,
    so the stored matrices stay correctly rounded and the compensation is
    bit-exact in floating point.
    """
    base = cook_toom(m, r)
    bt, g = [list(r_) for r_ in base.bt_rows], [list(r_) for r_ in base.g_rows]
    for p in range(base.t):
        amax = max(abs(v) for v in bt[p])
        if amax == 0:
            continue
        s = 2.0 ** round(math.log2(amax))
        bt[p] = [v / s for v in bt[p]]
        g[p] = [v * s for v in g[p]]
    return CookToom(m=base.m, r=base.r, t=base.t, at_rows=base.at_rows,
                    g_rows=tuple(tuple(row) for row in g),
                    bt_rows=tuple(tuple(row) for row in bt))


#: fp32 relative-error budget (max-norm, vs a float64 direct oracle) the
#: scaled F(6, 3) executor must hold, including on adversarial
#: large-magnitude filters. Asserted in tests/test_fft_f63.py.
F63_FP32_ERROR_BUDGET = 5e-4


def transform_filter_1d(ct: CookToom, g: np.ndarray) -> np.ndarray:
    """(r, ...) -> (t, ...): G @ g along the leading axis."""
    return np.tensordot(ct.G, g, axes=(1, 0))


def correlate_1d_reference(ct: CookToom, d: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Direct F(m, r) on one tile: y = A^T [(G g) . (B^T d)].  Testing only."""
    u = ct.G @ g            # (t,)
    v = ct.BT @ d           # (t,)
    return ct.AT @ (u * v)  # (m,)


# ---------------------------------------------------------------------------
# Variant registry: the named algorithm variants the paper implements, plus
# the ones the assigned architectures need. Names follow F(out, filt).
# ---------------------------------------------------------------------------

#: Default output-tile size per filter size, mirroring the paper's choices
#: (F(4x4, 3x3) / F(2x2, 3x3) for 3x3, small tiles for the big filters where
#: fp32 error would otherwise blow up).
DEFAULT_OUTPUT_TILE: dict[int, int] = {2: 4, 3: 4, 4: 4, 5: 2, 7: 2}


def default_variant(r: int) -> CookToom:
    return cook_toom(DEFAULT_OUTPUT_TILE.get(r, 2), r)
