"""Capability-declaring executor registry: every convolution executor
declares what it can run; algorithm resolution is a registry query.

Before this module, the "which executor may run this layer" rules were
scattered as hard-coded predicates across `core/plan.py` (winograd_suitable,
_winograd_family_suitable, algorithm_supported, per-algorithm raise sites)
and `core/dispatch.py`, so every new executor (grouped, depthwise, streamed
depthwise, ...) had to patch three call sites and invent its own error
message. Now each executor registers ONE `Capability` record -- supported
strides, filter sizes, group kinds, channel-multiplier constraint, layouts,
fusable epilogues, and a cost hint -- and the planner asks the registry:

  * `resolve(algorithm, query)` -> the matching capability for a requested
    algorithm family (or a ValueError that enumerates the registered
    executors that DO cover the layer -- no more "need stride (1, 1)"
    messages that lie once stride-2 executors exist);
  * `select_auto(query)` -> the paper's mixed policy (cheapest fast-scheme
    capability where one matches, the im2row baseline everywhere else);
  * `supported(algorithm, query)` -> the coverage predicate model-level
    fallback policies consult (models/cnn.py:_layer_algorithm);
  * `capability_table()` -> the README algorithm table, generated from the
    records so docs cannot drift from code (doctest'd in tests).

The records are data, not code: `plan.py:_build_spec` still owns *how* each
executor is planned; the registry owns *whether* and *which*.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

#: Filter sizes the exact Cook-Toom generator covers per non-unit axis
#: (2D NxN and 1D 1xN / Nx1) -- the paper's "suitable" filter sizes.
WINOGRAD_FILTER_SIZES = frozenset({2, 3, 4, 5, 7})

#: Odd filter sizes the stride-2 transform-domain phase decomposition
#: covers: the filter is zero-padded to even size k+1 and split into four
#: (k+1)/2-tap phase sub-filters, so (k+1)/2 must be a supported size.
STRIDED_FILTER_SIZES = frozenset(
    k for k in (3, 5, 7) if (k + 1) // 2 in WINOGRAD_FILTER_SIZES)

#: Data layouts the plan/dispatch boundary accepts (NCHW inputs/weights are
#: transposed once at plan time; see plan.plan_conv2d(data_format=...)).
LAYOUTS = ("NHWC", "NCHW")

_KINDS = ("dense", "grouped", "depthwise")

#: Transform-domain compute dtypes an executor may declare, in preference
#: order for display. Input/inverse transforms always run fp32 (the
#: numerically fragile part); a reduced dtype only changes the
#: transform-domain GEMM/Hadamard operand and its plan-time-quantized
#: filter (per-output-channel scales fold into the epilogue).
COMPUTE_DTYPES = ("float32", "bfloat16", "int8")

_DTYPE_SHORT = {"float32": "fp32", "bfloat16": "bf16", "int8": "int8"}

_F32_ONLY = frozenset({"float32"})
_LOW_PRECISION = frozenset(COMPUTE_DTYPES)


@dataclasses.dataclass(frozen=True)
class LayerQuery:
    """One conv layer's shape facts, as the registry sees them."""

    kh: int
    kw: int
    stride: tuple[int, int]
    groups: int = 1
    c_in: int | None = None
    c_out: int | None = None
    layout: str = "NHWC"

    @property
    def group_kind(self) -> str:
        if self.groups == 1:
            return "dense"
        if self.c_in is not None and self.groups == self.c_in:
            return "depthwise"
        return "grouped"

    @property
    def axis_kind(self) -> str:
        """'pointwise' (1x1), 'single_axis' (1xN / Nx1), or 'two_d'."""
        if self.kh == 1 and self.kw == 1:
            return "pointwise"
        if self.kh == 1 or self.kw == 1:
            return "single_axis"
        return "two_d"


def as_query(kh: int, kw: int, stride, *, groups: int = 1,
             c_in: int | None = None, c_out: int | None = None,
             layout: str = "NHWC") -> LayerQuery:
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    return LayerQuery(kh=kh, kw=kw, stride=s, groups=groups, c_in=c_in,
                      c_out=c_out, layout=layout)


@dataclasses.dataclass(frozen=True)
class Capability:
    """What one executor declares it can run.

    `executor` is the resolved name plan._build_spec materializes;
    `algorithm` is the requestable family it serves (one executor may be
    reachable from several families -- e.g. the pure-JAX 1D executor backs
    both 'winograd' and the Pallas families for 1xN layers, whose GEMM is a
    single matmul XLA already maps to the MXU)."""

    executor: str
    algorithm: str
    strides: frozenset | None            # of (sh, sw); None = any stride
    filter_sizes: frozenset | None       # per non-unit axis; None = any
    axis_kinds: frozenset                # subset of {pointwise, single_axis,
                                         #            two_d}
    group_kinds: frozenset               # subset of {dense, grouped,
                                         #            depthwise}
    unit_multiplier_only: bool = False   # depthwise: requires c_out == c_in
    layouts: frozenset = frozenset(LAYOUTS)
    fused_epilogue: bool = False         # bias+activation fused in-kernel
    cost_hint: float = 1.0               # relative per-output cost rank;
                                         # lower wins within a family and in
                                         # select_auto
    compute_dtypes: frozenset = _F32_ONLY  # transform-domain GEMM/Hadamard
                                           # dtypes (transforms stay fp32)
    note: str = ""

    def matches(self, q: LayerQuery) -> bool:
        if self.strides is not None and q.stride not in self.strides:
            return False
        if q.axis_kind not in self.axis_kinds:
            return False
        if self.filter_sizes is not None:
            for k in (q.kh, q.kw):
                if k != 1 and k not in self.filter_sizes:
                    return False
        if q.group_kind not in self.group_kinds:
            return False
        if self.unit_multiplier_only and q.group_kind == "depthwise":
            if q.c_out is None or q.c_out != q.c_in:
                return False
        if q.layout not in self.layouts:
            return False
        return True

    # ---- human-readable constraint rendering (error messages, README) ----

    @property
    def strides_str(self) -> str:
        if self.strides is None:
            return "any"
        return ", ".join(f"{s[0]}x{s[1]}" for s in sorted(self.strides))

    @property
    def filters_str(self) -> str:
        sizes = ("any" if self.filter_sizes is None
                 else "/".join(str(k) for k in sorted(self.filter_sizes)))
        kinds = []
        if "two_d" in self.axis_kinds:
            kinds.append(f"kxk (k in {sizes})" if sizes != "any" else "kxk")
        if "single_axis" in self.axis_kinds:
            kinds.append("1xN/Nx1")
        if "pointwise" in self.axis_kinds:
            kinds.append("1x1")
        return ", ".join(kinds)

    @property
    def groups_str(self) -> str:
        names = {"dense": "G=1", "grouped": "1<G<C",
                 "depthwise": ("G=C (mult 1)" if self.unit_multiplier_only
                               else "G=C")}
        return ", ".join(names[k] for k in _KINDS if k in self.group_kinds)

    @property
    def dtypes_str(self) -> str:
        return "/".join(_DTYPE_SHORT[d] for d in COMPUTE_DTYPES
                        if d in self.compute_dtypes)


_WFS = WINOGRAD_FILTER_SIZES
_SFS = STRIDED_FILTER_SIZES
_S1 = frozenset({(1, 1)})
_S2 = frozenset({(2, 2)})
_ALL_LAYOUTS = frozenset(LAYOUTS)


def _cap(executor, algorithm, *, strides, filter_sizes, axis_kinds,
         group_kinds, **kw) -> Capability:
    return Capability(
        executor=executor, algorithm=algorithm, strides=strides,
        filter_sizes=filter_sizes, axis_kinds=frozenset(axis_kinds),
        group_kinds=frozenset(group_kinds), **kw)


#: The registry. Order is display order (README table, error messages);
#: resolution prefers lower cost_hint within a family.
CAPABILITIES: tuple[Capability, ...] = (
    # -- pure-JAX (XLA) winograd family ------------------------------------
    _cap("winograd", "winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("two_d",), group_kinds=("dense",),
         compute_dtypes=_LOW_PRECISION,
         note="region-wise multi-channel 2D scheme (paper Fig. 2)"),
    _cap("winograd_1d", "winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("single_axis",), group_kinds=("dense",),
         compute_dtypes=_LOW_PRECISION,
         note="single-axis Cook-Toom (paper's Inception 1xN/Nx1 case)"),
    _cap("winograd_depthwise", "winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("two_d",), group_kinds=("depthwise",),
         compute_dtypes=_LOW_PRECISION,
         note="transform-domain Hadamard phase 2, any channel multiplier"),
    _cap("winograd_grouped", "winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("two_d",), group_kinds=("grouped",),
         compute_dtypes=_LOW_PRECISION,
         note="block-diagonal transform-domain reduction"),
    _cap("winograd_strided", "winograd", strides=_S2, filter_sizes=_SFS,
         axis_kinds=("two_d",),
         group_kinds=("dense", "grouped", "depthwise"), cost_hint=1.5,
         compute_dtypes=_LOW_PRECISION,
         note="stride-2 via transform-domain phase decomposition (4 phase "
              "sub-convolutions sharing one inverse transform)"),
    # -- large-tile F(6,3) winograd (own family: a distinct accuracy/speed
    #    point the measured auto_tuned policy races against F(2,3)/F(4,3)) --
    _cap("winograd_f63", "winograd_f63", strides=_S1,
         filter_sizes=frozenset({3}), axis_kinds=("two_d",),
         group_kinds=("dense",), cost_hint=0.9,
         note="F(6x6, 3x3) with power-of-two row-scaled transforms: 2.25x "
              "fewer point-GEMM flops than F(4,3), fp32 error held to "
              "transforms.F63_FP32_ERROR_BUDGET (fp32-only: the large "
              "tile's transform dynamic range amplifies the bf16/int8 "
              "grid ~8e-2 rel err, past any useful budget)"),
    # -- tiled FFT (rfft2) family ------------------------------------------
    _cap("fft", "fft", strides=_S1, filter_sizes=None,
         axis_kinds=("two_d",), group_kinds=("dense",), cost_hint=3.0,
         note="overlap-tiled rfft2 executor; transform cost per output is "
              "O(log t), independent of filter size (plan-time conjugated "
              "filter spectrum)"),
    # -- im2row GEMM baseline ----------------------------------------------
    _cap("im2col", "im2col", strides=None, filter_sizes=None,
         axis_kinds=("pointwise", "single_axis", "two_d"),
         group_kinds=("dense", "grouped", "depthwise"), cost_hint=9.0,
         compute_dtypes=_LOW_PRECISION,
         note="the paper's baseline; per-group lowering for G>1"),
    # -- streamed Pallas winograd family -----------------------------------
    _cap("pallas_winograd", "pallas_winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("two_d",), group_kinds=("dense",), fused_epilogue=True,
         compute_dtypes=_LOW_PRECISION,
         note="halo-streaming kernel; input/output are the only HBM tensors"),
    _cap("winograd_1d", "pallas_winograd", strides=_S1, filter_sizes=_WFS,
         axis_kinds=("single_axis",), group_kinds=("dense",), cost_hint=1.1,
         compute_dtypes=_LOW_PRECISION,
         note="1xN routes to the XLA 1D executor (its GEMM is one matmul)"),
    _cap("pallas_depthwise", "pallas_winograd", strides=_S1,
         filter_sizes=_WFS, axis_kinds=("two_d",), group_kinds=("depthwise",),
         fused_epilogue=True, compute_dtypes=_LOW_PRECISION,
         note="streamed depthwise kernel (Hadamard phase 2 in VMEM, any "
              "channel multiplier)"),
    _cap("pallas_winograd_strided", "pallas_winograd", strides=_S2,
         filter_sizes=_SFS, axis_kinds=("two_d",), group_kinds=("dense",),
         fused_epilogue=True, cost_hint=1.5, compute_dtypes=_LOW_PRECISION,
         note="stride-2 phase decomposition inside the streaming kernel"),
    _cap("pallas_depthwise_strided", "pallas_winograd", strides=_S2,
         filter_sizes=_SFS, axis_kinds=("two_d",), group_kinds=("depthwise",),
         unit_multiplier_only=True, fused_epilogue=True, cost_hint=1.5,
         compute_dtypes=_LOW_PRECISION,
         note="stride-2 streamed depthwise kernel"),
    # -- Pallas A/B baselines ----------------------------------------------
    _cap("pallas_winograd_materialized", "pallas_winograd_materialized",
         strides=_S1, filter_sizes=_WFS, axis_kinds=("two_d",),
         group_kinds=("dense",), cost_hint=2.0,
         note="pre-streaming tiles-domain kernel, kept for the streaming A/B"),
    _cap("winograd_1d", "pallas_winograd_materialized", strides=_S1,
         filter_sizes=_WFS, axis_kinds=("single_axis",),
         group_kinds=("dense",), cost_hint=2.1,
         note="1xN routes to the XLA 1D executor"),
    _cap("pallas_im2col", "pallas_im2col", strides=None, filter_sizes=None,
         axis_kinds=("pointwise", "single_axis", "two_d"),
         group_kinds=("dense",), fused_epilogue=True, cost_hint=9.0,
         compute_dtypes=_LOW_PRECISION,
         note="blocked Pallas im2row GEMM baseline"),
)

#: Requestable concrete algorithm families, in registration order.
FAMILIES: tuple[str, ...] = tuple(dict.fromkeys(
    c.algorithm for c in CAPABILITIES))


def family(algorithm: str) -> tuple[Capability, ...]:
    return tuple(c for c in CAPABILITIES if c.algorithm == algorithm)


def matching(q: LayerQuery,
             algorithm: str | None = None) -> tuple[Capability, ...]:
    """All capabilities covering the layer, optionally within one family."""
    caps: Iterable[Capability] = (CAPABILITIES if algorithm is None
                                  else family(algorithm))
    return tuple(c for c in caps if c.matches(q))


def supported(algorithm: str, q: LayerQuery) -> bool:
    """Whether the requested algorithm family has an executor for the layer
    ('auto'/'auto_tuned' always resolve to something)."""
    if algorithm in ("auto", "auto_tuned"):
        return True
    return bool(matching(q, algorithm))


def compute_dtypes_for(executor: str) -> tuple[str, ...]:
    """The transform-domain compute dtypes an executor supports, in
    COMPUTE_DTYPES display order (union over every capability record the
    executor is reachable from). Unknown executors get fp32 only -- the
    always-safe answer."""
    found = set()
    for c in CAPABILITIES:
        if c.executor == executor:
            found |= c.compute_dtypes
    if not found:
        found = {"float32"}
    return tuple(d for d in COMPUTE_DTYPES if d in found)


def best_fast(q: LayerQuery) -> Capability | None:
    """The cheapest matching capability of the XLA winograd family, or None
    -- the fast-scheme contender 'auto' and 'auto_tuned' consider."""
    caps = matching(q, "winograd")
    return min(caps, key=lambda c: c.cost_hint) if caps else None


def select_auto(q: LayerQuery) -> Capability:
    """The paper's mixed policy as a registry query: the cheapest fast-scheme
    capability where one matches, the im2row baseline everywhere else."""
    return best_fast(q) or resolve("im2col", q)


def resolve(algorithm: str, q: LayerQuery) -> Capability:
    """Resolve a requested algorithm family onto the matching executor
    capability, or raise a ValueError enumerating the registered executors
    that DO cover the layer."""
    caps = matching(q, algorithm)
    if caps:
        return min(caps, key=lambda c: c.cost_hint)
    raise resolution_error(algorithm, q)


def _layer_str(q: LayerQuery) -> str:
    s = (f"k=({q.kh},{q.kw}) stride=({q.stride[0]},{q.stride[1]}) "
         f"groups={q.groups}")
    if q.group_kind == "depthwise" and q.c_out is not None \
            and q.c_in not in (None, q.c_out):
        s += f" (channel multiplier {q.c_out // q.c_in})"
    if q.layout != "NHWC":
        s += f" layout={q.layout}"
    return s


def resolution_error(algorithm: str, q: LayerQuery) -> ValueError:
    """The one place algorithm-coverage errors are written: states what the
    requested family covers, then enumerates every registered capability
    that does match the layer, with the algorithm= that reaches it."""
    fam = family(algorithm)
    if not fam:
        return ValueError(
            f"unknown algorithm {algorithm!r}; requestable families: "
            f"{FAMILIES + ('auto', 'auto_tuned')}")
    covers = "; ".join(
        f"{c.executor}: filters {c.filters_str}, stride {c.strides_str}, "
        f"groups {c.groups_str}" for c in fam)
    alts = matching(q)
    if alts:
        fixes = ", ".join(
            f"{c.executor} (algorithm={c.algorithm!r})"
            for c in dict.fromkeys(alts))
        fix = f"executors that do cover this layer: {fixes}"
    else:
        fix = "no registered executor covers this layer"
    return ValueError(
        f"algorithm={algorithm!r} has no executor for layer {_layer_str(q)}. "
        f"{algorithm!r} covers [{covers}]. {fix}")


# ---------------------------------------------------------------------------
# Registry fingerprint (artifact cache key)
# ---------------------------------------------------------------------------

def fingerprint() -> str:
    """Stable digest of the declared capability records. Serialized network
    plans (repro.core.compile.NetworkPlan.save) stamp this into the artifact
    header: a saved plan's per-layer executor decisions are only valid
    against the registry that made them, so load() refuses an artifact whose
    fingerprint no longer matches (executors added/removed/re-constrained)
    and tells the caller to recompile. Frozenset fields are canonicalized
    (sorted) so the digest is stable across processes regardless of hash
    randomization."""
    def canon(v):
        if isinstance(v, frozenset):
            return "{" + ",".join(sorted(map(repr, v))) + "}"
        return repr(v)

    body = "\n".join(
        ";".join(f"{f.name}={canon(getattr(c, f.name))}"
                 for f in dataclasses.fields(c))
        for c in CAPABILITIES)
    return hashlib.sha256(body.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Markdown table generation (README capability table AND the per-layer
# NetworkPlan.describe() table render through the same generator, so the two
# docs surfaces cannot drift apart in format)
# ---------------------------------------------------------------------------

def markdown_table(header: Sequence[str], rows: Iterable[Sequence]) -> str:
    """Render a GitHub-flavored markdown table: the ONE table generator.
    `capability_table()` (the README algorithm table) and
    `repro.core.compile.NetworkPlan.describe()` (the per-layer algorithm
    table) both route through here -- drift-tested in tests/test_compile.py.
    """
    out = ["| " + " | ".join(str(h) for h in header) + " |",
           "| " + " | ".join("---" for _ in header) + " |"]
    for row in rows:
        out.append("| " + " | ".join(str(v) for v in row) + " |")
    return "\n".join(out)


def capability_table() -> str:
    """The registry rendered as the README's algorithm table -- one row per
    capability record, so the docs are generated from the same data the
    resolver queries.

    >>> print(capability_table().splitlines()[2].split("|")[1].strip())
    `winograd`
    """
    rows = [(f"`{c.executor}`", f"`{c.algorithm}`", c.filters_str,
             c.strides_str, c.groups_str, ", ".join(sorted(c.layouts)),
             c.dtypes_str, "in-kernel" if c.fused_epilogue else "XLA")
            for c in CAPABILITIES]
    return markdown_table(
        ["executor", "`algorithm=`", "filters", "strides", "groups",
         "layouts", "compute dtypes", "fused epilogue"], rows)
