"""Plan/execute split for convolution: decide once, run many.

The paper's deployment insight (section 4) is that the fast Winograd /
Cook-Toom scheme only pays off once the GEMM phase amortizes the transform
phases -- and that the *filter* transform should never be on the inference
path at all: weights are transformed once, offline, and reused every step.

This module is that insight as an architecture:

  * `plan_conv2d(x_shape, w, ...)` makes every per-layer decision exactly
    once -- algorithm choice, CookToom pair, output tile, padding amounts,
    tile counts, Pallas block sizes -- and pre-transforms the filter into the
    execution domain (Winograd domain for the fast scheme, the flattened
    GEMM matrix for im2row).
  * `ConvPlan.apply(x)` executes with zero per-call filter or geometry work.
  * A process-level spec cache keyed on (shapes, dtype, stride, padding,
    algorithm, output tile) means repeated planning of the same layer shape
    is a dict hit; the cached spec carries the algorithm decision, so a
    measured `auto_tuned` choice is made once per shape per process.
  * `algorithm="auto_tuned"` is *plan-time measured autotuning*: both
    schemes are timed on the real layer shape and the winner is cached.
    The static amortization constants remain only as the fallback policy
    when measurement is impossible (planning inside a jit trace).
  * Which executor may run which layer is declared by the executors
    themselves in the capability registry (repro.core.registry); every
    algorithm choice and coverage error message here is a registry query.

`core.dispatch.conv2d` / `conv1d` stay as thin per-call wrappers over this
module for backward compatibility; model code (models/cnn.py, models/audio.py)
builds plans at init/weight-load time and executes them.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import typing
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as _fft
# observability: stdlib-only tracing/metrics (repro.obs.trace/metrics import
# nothing from repro.core, so this dependency edge is acyclic and free --
# every hook's disabled path is one global None check / one dict lookup).
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.core import im2col as _im2col
from repro.core import registry
from repro.core import winograd as _wg
from repro.core.registry import LayerQuery
from repro.core.transforms import (DEFAULT_OUTPUT_TILE, CookToom, cook_toom,
                                   scaled_cook_toom)
# Shared epilogue vocabulary, dependency-free (the heavy Pallas kernels in
# repro.kernels stay optional, imported locally where needed).
# EPILOGUE_ACTIVATIONS: the activations plan.apply(..., activation=) accepts
# (kernels/runtime.py is the single source of truth): the Pallas executors
# fuse these into the kernel store, the pure-JAX executors apply them as one
# XLA op (_epilogue_jnp).
from repro.kernels.runtime import ACTIVATIONS as EPILOGUE_ACTIVATIONS
from repro.kernels.runtime import epilogue_jnp as _epilogue_jnp

Algorithm = Literal["auto", "auto_tuned", "winograd", "winograd_f63", "fft",
                    "im2col", "pallas_winograd",
                    "pallas_winograd_materialized", "pallas_im2col"]
#: The requestable algorithm names, derived from the Literal so the type,
#: the resolver checks, and every unknown-algorithm error message agree.
ALGORITHMS: tuple[str, ...] = typing.get_args(Algorithm)
Padding = _wg.Padding

#: Filter sizes the paper's fast scheme covers (2D NxN and 1D 1xN / Nx1).
#: Declared by the executor registry; re-exported for compatibility.
WINOGRAD_FILTER_SIZES = registry.WINOGRAD_FILTER_SIZES

#: auto_tuned *fallback* crossover, used only when plan-time measurement is
#: impossible (planning under an active jit trace, or REPRO_PLAN_NO_MEASURE
#: set): winograd wins when the per-point GEMMs are large enough to amortize
#: the transform passes -- which needs BOTH enough regions (output pixels)
#: and enough channel depth (the GEMM's contraction dim). Calibrated on the
#: measured per-layer sweep (results/bench_per_layer.json; EXPERIMENTS.md
#: section Perf). The primary auto_tuned policy is the measured one below
#: (_measure_autotune): time both schemes on the real shape, cache the winner.
AMORTIZE_MIN_OUT_PIXELS = 1156            # 34 x 34
AMORTIZE_MIN_C_IN = 64


def spatial_halo(k: int) -> int:
    """Rows of neighbor overlap a stride-1 SAME kxk conv needs on each side
    of a contiguous H strip to produce that strip's output rows exactly --
    the cross-device analogue of the halo-strip overlap stream_geometry
    derives per tile. Spatial partitioning (core/partition.py) exchanges
    this many rows between mesh neighbors and binds the local plan VALID."""
    return (k - 1) // 2


def winograd_suitable(kh: int, kw: int, stride) -> bool:
    """Whether some winograd-family executor covers this filter/stride
    combination (a registry query; kept as the historical entry point).
    Since the stride-2 phase-decomposition executors registered, suitable
    no longer means stride (1, 1)."""
    q = registry.as_query(kh, kw, stride)
    return registry.best_fast(q) is not None


def winograd_amortizes(h: int, w: int, kh: int, kw: int, c_in: int,
                       padding: str = "SAME", groups: int = 1,
                       stride=1) -> bool:
    """The paper's section-4 amortization insight as a static predicate --
    the auto_tuned fallback when plan-time measurement is unavailable.

    For grouped convs the GEMM contraction depth is the per-group channel
    count C/G, so that is what must clear the channel threshold. Depthwise
    (G == C) has no channel GEMM to amortize at all -- it is memory-bound
    (Zhang et al. 2020) and the transform passes pay for themselves on
    spatial extent alone, so only the output-pixel threshold applies."""
    sh, sw = (stride, stride) if isinstance(stride, int) else tuple(stride)
    out_h = -(-h // sh) if padding == "SAME" else (h - kh) // sh + 1
    out_w = -(-w // sw) if padding == "SAME" else (w - kw) // sw + 1
    if out_h * out_w < AMORTIZE_MIN_OUT_PIXELS:
        return False
    if groups > 1 and groups == c_in:     # depthwise
        return True
    return c_in // groups >= AMORTIZE_MIN_C_IN


def algorithm_supported(algorithm: str, kh: int, kw: int, stride,
                        *, groups: int = 1, c_in: int | None = None,
                        c_out: int | None = None,
                        layout: str = "NHWC") -> bool:
    """Whether plan_conv2d would accept this (algorithm, layer) combination
    without raising -- a registry query over the capabilities the executors
    declare. Model-level fallback policies (models/cnn.py:_layer_algorithm)
    consult this instead of duplicating the constraint list."""
    q = registry.as_query(kh, kw, stride, groups=groups, c_in=c_in,
                          c_out=c_out, layout=layout)
    return registry.supported(algorithm, q)


# ---------------------------------------------------------------------------
# Specs: the cacheable, weight-free part of a plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Everything about a planned conv layer except the weights: the resolved
    algorithm, transform variant, geometry, and kernel blocking. Hashable and
    shape-keyed, so it lives in the process-level plan cache."""

    x_shape: tuple[int, ...]          # (N, H, W, C) the plan was built for
                                      # (always NHWC internally; see layout)
    w_shape: tuple[int, ...]          # (kh, kw, C/groups, M)
    dtype: str
    stride: tuple[int, int]
    padding: str
    requested: str                    # the algorithm= the caller asked for
    algorithm: str                    # resolved executor (a registry
                                      # Capability.executor name): winograd |
                                      # winograd_1d | winograd_depthwise |
                                      # winograd_grouped | winograd_strided |
                                      # winograd_f63 | fft | im2col |
                                      # pallas_winograd | pallas_depthwise |
                                      # pallas_winograd_strided |
                                      # pallas_depthwise_strided |
                                      # pallas_winograd_materialized |
                                      # pallas_im2col
    groups: int = 1                   # feature_group_count (1 = dense,
                                      # C = depthwise)
    layout: str = "NHWC"              # caller-facing data format; "NCHW"
                                      # plans transpose weights once at plan
                                      # time and apply() transposes x/y at
                                      # the boundary
    compute_dtype: str = "float32"    # transform-domain GEMM/Hadamard dtype
                                      # (registry.COMPUTE_DTYPES). Input and
                                      # inverse transforms always run fp32;
                                      # bf16/int8 only change the cached
                                      # filter operand -- int8 carries
                                      # per-output-channel scales folded
                                      # into the epilogue (ConvPlan.scale)
    output_tile: tuple[int, int] | None = None
    ct_h: CookToom | None = None
    ct_w: CookToom | None = None      # also the single CT of the 1D variant
    geometry: Any = None              # Conv2DGeometry | Axis1DGeometry |
                                      # Im2RowGeometry
    axis: int | None = None           # 1xN / Nx1: the non-unit spatial axis
    blocks: tuple[int, ...] | None = None        # Pallas block sizes
    stream: Any = None                # StreamGeometry (halo blocking) of the
                                      # streaming pallas_winograd executor
    fft: Any = None                   # fft.FFTGeometry of the rfft2 executor
                                      # (re-derived from output_tile on
                                      # artifact reload)
    autotune: tuple | None = None     # (("t_winograd_s", ...), ...) measured
                                      # evidence behind an auto_tuned choice

    @property
    def autotune_report(self) -> dict | None:
        return dict(self.autotune) if self.autotune is not None else None


# ---------------------------------------------------------------------------
# Process-level spec cache
# ---------------------------------------------------------------------------

_SPEC_CACHE: dict[tuple, ConvSpec] = {}
_CACHE_HITS = 0
_CACHE_MISSES = 0
# Serialized-plan (NetworkPlan artifact) load counters: a hit is a
# successful NetworkPlan.load / compile(..., artifact=) warm start, a miss
# is a load that had to fall back to a cold compile (file absent, header
# mismatch). Maintained by repro.core.compile via record_artifact_load.
_ARTIFACT_HITS = 0
_ARTIFACT_MISSES = 0
# auto_tuned resolution accounting: 'measured' counts decisions backed by a
# plan-time N-way timing race, 'fallback' counts auto_tuned resolutions made
# WITHOUT measurement (heuristic under a jit trace / REPRO_PLAN_NO_MEASURE,
# or the sole-candidate im2col case). Plans rebuilt from a NetworkPlan
# artifact increment neither -- the zero-re-measurement contract of warm
# loads is asserted against these counters in tests.
_MEASURED = 0
_FALLBACK = 0
# Plan-time weight-quantization accounting: one count per int8
# _bind_weights pass (bf16 casts are free and not counted). Warm artifact
# loads take the quantized payload verbatim, so the zero-re-quantization
# contract of NetworkPlan.load is asserted against this counter in tests.
_QUANTIZED = 0
# Fleet tuning-database accounting: auto_tuned layers resolved from an
# installed tuning database (repro.obs.tuningdb) -- adopted measured
# evidence, zero local measurements. Such a resolution counts neither
# 'measured' nor 'fallback'.
_TUNINGDB_HITS = 0


def plan_cache_info() -> dict:
    """{'hits', 'misses', 'size'} of the process-level spec cache, plus
    {'artifact_hits', 'artifact_misses'} of serialized-plan loads
    (repro.core.compile.NetworkPlan.save/load warm starts),
    {'measured', 'fallback'} auto_tuned resolution counts (measured timing
    race vs the no-measurement fallback path), {'tuningdb_hits'} auto_tuned
    resolutions adopted from an installed fleet tuning database
    (repro.obs.tuningdb -- zero local measurements), and {'quantized'}
    plan-time int8 weight-quantization passes."""
    return {"hits": _CACHE_HITS, "misses": _CACHE_MISSES,
            "size": len(_SPEC_CACHE),
            "artifact_hits": _ARTIFACT_HITS,
            "artifact_misses": _ARTIFACT_MISSES,
            "measured": _MEASURED, "fallback": _FALLBACK,
            "tuningdb_hits": _TUNINGDB_HITS,
            "quantized": _QUANTIZED}


def _record_autotune_resolution(measured: bool) -> None:
    global _MEASURED, _FALLBACK
    if measured:
        _MEASURED += 1
        _obs_metrics.count("plan.autotune.measured")
    else:
        _FALLBACK += 1
        _obs_metrics.count("plan.autotune.fallback")


def record_artifact_load(hit: bool) -> None:
    """Count one serialized-plan load attempt (see plan_cache_info)."""
    global _ARTIFACT_HITS, _ARTIFACT_MISSES
    if hit:
        _ARTIFACT_HITS += 1
        _obs_metrics.count("plan.artifact.hit")
    else:
        _ARTIFACT_MISSES += 1
        _obs_metrics.count("plan.artifact.miss")


def clear_plan_cache() -> None:
    global _CACHE_HITS, _CACHE_MISSES, _ARTIFACT_HITS, _ARTIFACT_MISSES, \
        _MEASURED, _FALLBACK, _QUANTIZED, _TUNINGDB_HITS
    _SPEC_CACHE.clear()
    _CACHE_HITS = 0
    _CACHE_MISSES = 0
    _ARTIFACT_HITS = 0
    _ARTIFACT_MISSES = 0
    _MEASURED = 0
    _FALLBACK = 0
    _QUANTIZED = 0
    _TUNINGDB_HITS = 0


def _cache_enabled() -> bool:
    return not os.environ.get("REPRO_PLAN_NO_CACHE")


def _count_cache(hit: bool) -> None:
    """Spec-cache accounting, mirrored into the default metrics registry
    (plan.cache.hit / plan.cache.miss) so the observability snapshot and
    plan_cache_info() tell one story."""
    global _CACHE_HITS, _CACHE_MISSES
    if hit:
        _CACHE_HITS += 1
        _obs_metrics.count("plan.cache.hit")
    else:
        _CACHE_MISSES += 1
        _obs_metrics.count("plan.cache.miss")


def _measure_allowed() -> bool:
    """Measured autotuning needs concrete execution: it is disabled inside an
    active jit trace and via REPRO_PLAN_NO_MEASURE."""
    if os.environ.get("REPRO_PLAN_NO_MEASURE"):
        return False
    return jax.core.trace_state_clean()


# ---------------------------------------------------------------------------
# Fleet tuning database: adopt measured auto_tuned evidence without racing
# ---------------------------------------------------------------------------

#: installed database entries ({tuning_db_key: entry}) -- see
#: repro.obs.tuningdb for the export/merge/install pipeline. None means
#: "no database": plan_conv2d measures (or falls back) as always.
_TUNING_DB: dict[str, dict] | None = None
#: last REPRO_TUNING_DB path auto-loaded, so a bad/changed path is only
#: attempted once per value.
_TUNING_DB_ENV_PATH: str | None = None


def tuning_db_key(x_shape, w_shape, dtype: str, stride, padding: str,
                  groups: int, layout: str, compute_request: str,
                  output_tile=None) -> str:
    """The canonical database key: every plan_conv2d input that decides an
    auto_tuned race. `compute_request` is the caller's compute_dtype
    REQUEST ("auto" when reduced-precision contenders were fielded), not
    the resolved winner dtype; `output_tile` the requested (not tuned)
    tile."""
    if output_tile is None:
        ot = None
    elif isinstance(output_tile, (tuple, list)):
        ot = [int(v) for v in output_tile]
    else:
        ot = [int(output_tile), int(output_tile)]
    return json.dumps(
        [list(x_shape), list(w_shape), str(dtype),
         list(stride) if isinstance(stride, (tuple, list))
         else [stride, stride],
         str(padding), int(groups), str(layout), str(compute_request), ot],
        separators=(",", ":"))


def set_tuning_db(entries: dict | None) -> None:
    """Install (or with None remove) tuning-database entries. Entries stay
    installed across clear_plan_cache() -- the database is configuration,
    not cache state."""
    global _TUNING_DB
    _TUNING_DB = dict(entries) if entries is not None else None


def tuning_db() -> dict | None:
    _maybe_load_env_tuning_db()
    return _TUNING_DB


def _maybe_load_env_tuning_db() -> None:
    global _TUNING_DB, _TUNING_DB_ENV_PATH
    path = os.environ.get("REPRO_TUNING_DB")
    if _TUNING_DB is not None or not path or path == _TUNING_DB_ENV_PATH:
        return
    _TUNING_DB_ENV_PATH = path
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("format") == "repro.tuning_db":
            _TUNING_DB = dict(doc.get("entries") or {})
    except (OSError, ValueError):
        pass                     # unreadable database == no database


def _tuningdb_lookup(x_shape, w_shape, dtype: str, stride, padding: str,
                     groups: int, layout: str, compute_request: str,
                     output_tile) -> tuple | None:
    """A validated database resolution shaped exactly like
    _measure_autotune's return -- (winner, winner_tile, winner_dtype,
    evidence) -- or None (no database / no entry / entry names an
    executor or dtype this registry no longer covers)."""
    global _TUNINGDB_HITS
    _maybe_load_env_tuning_db()
    if _TUNING_DB is None:
        return None
    entry = _TUNING_DB.get(tuning_db_key(
        x_shape, w_shape, dtype, stride, padding, groups, layout,
        compute_request, output_tile))
    if not entry:
        return None
    winner = entry.get("winner")
    winner_dtype = str(entry.get("winner_dtype", "float32"))
    known = {cap.executor for cap in registry.CAPABILITIES}
    if winner not in known or \
            winner_dtype not in registry.compute_dtypes_for(winner):
        return None               # stale fleet evidence: race locally
    if compute_request not in ("auto", "float32") and \
            compute_request not in registry.compute_dtypes_for(winner):
        return None               # winner can't serve the pinned dtype
    tile = entry.get("winner_tile")
    evidence = tuple(
        (k, tuple(v) if isinstance(v, list) else v)
        for k, v in (entry.get("evidence") or []) if k != "source")
    evidence += (("source", "tuning_db"),)
    _TUNINGDB_HITS += 1
    _obs_metrics.count("plan.autotune.tuningdb_hit")
    _obs_trace.instant("plan.autotune.tuningdb_hit", winner=winner,
                       layer=f"{tuple(x_shape)}x{tuple(w_shape)}")
    return winner, tuple(tile) if tile else None, winner_dtype, evidence


# ---------------------------------------------------------------------------
# Spec construction (all per-layer decisions happen here, once)
# ---------------------------------------------------------------------------

def _resolve_output_tile(kh: int, kw: int, output_tile) -> tuple[int, int]:
    if output_tile is None:
        mt = DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        return (mt, mt)
    if isinstance(output_tile, int):
        return (output_tile, output_tile)
    return tuple(output_tile)


#: Shape thresholds below/above which the stride-2 executors default to the
#: F(2, r_ph) tile set instead of F(4, r_ph). The larger tile cuts the
#: per-output multiply count (4 * t^2/m^2 phase points per output) but its
#: four t=5 phase banks quadruple the transformed-input cache, so on small
#: output grids the point-GEMMs are too thin to amortize the transforms and
#: on deep layers the VMEM budget forces tiny region blocks. Calibrated on
#: the stride-2 reduction-block ladder (BENCH_PR4.json; EXPERIMENTS.md
#: section Perf): F(4, .) wins only on large-spatial shallow layers.
STRIDED_TILE4_MIN_OUT = 24
STRIDED_TILE4_MAX_C = 64


def _resolve_strided_tile(h: int, w: int, kh: int, kw: int, padding,
                          output_tile, c_in: int) -> tuple[int, int]:
    """Output tile of the stride-2 phase algorithm (per-axis F(m, r_ph),
    r_ph = (k+1)//2): explicit request wins; the default is shape-aware --
    F(4, .) on large-spatial shallow layers, F(2, .) everywhere else."""
    if output_tile is not None:
        if isinstance(output_tile, int):
            return (output_tile, output_tile)
        return tuple(output_tile)
    out_h = _wg.strided_out_size(h, kh, padding)
    out_w = _wg.strided_out_size(w, kw, padding)
    mt = 4 if (min(out_h, out_w) >= STRIDED_TILE4_MIN_OUT
               and c_in <= STRIDED_TILE4_MAX_C) else 2
    return (mt, mt)


def _build_spec(x_shape, w_shape, dtype, stride, padding, requested,
                resolved, output_tile, groups: int = 1,
                layout: str = "NHWC",
                compute_dtype: str = "float32") -> ConvSpec:
    """Materialize geometry/transform/blocking decisions for one resolved
    algorithm."""
    n, h, w, c = x_shape
    kh, kw, _, mout = w_shape
    base = dict(x_shape=tuple(x_shape), w_shape=tuple(w_shape), dtype=dtype,
                stride=stride, padding=padding, requested=requested,
                groups=groups, layout=layout, compute_dtype=compute_dtype)

    if (compute_dtype != "float32" and output_tile is None
            and resolved not in ("winograd_f63", "fft", "im2col",
                                 "pallas_im2col")):
        # Low-precision grids pair with the small tile: the transform-domain
        # dynamic range grows with tile size, and F(4,3)'s inverse transform
        # amplifies the bf16/int8 quantization grid past any useful budget
        # (measured ~1.4 rel max-abs err for int8 at F(4,3) vs ~0.02 at
        # F(2,3)). An explicit output_tile still wins.
        output_tile = 2

    if resolved in ("winograd_strided", "pallas_winograd_strided",
                    "pallas_depthwise_strided"):
        # shared stride-2 derivation: phase tile set F(m, (k+1)/2) and the
        # full-resolution phase geometry; only the halo blocking differs
        # per executor.
        mh, mw = _resolve_strided_tile(h, w, kh, kw, padding, output_tile, c)
        ct_h = cook_toom(mh, (kh + 1) // 2)
        ct_w = cook_toom(mw, (kw + 1) // 2)
        geom = _wg.conv2d_strided_geometry(h, w, kh, kw, mh, mw, padding)
        strided = dict(algorithm=resolved, output_tile=(mh, mw), ct_h=ct_h,
                       ct_w=ct_w, geometry=geom, **base)
        if resolved == "pallas_winograd_strided":
            stream = _wg.stream_geometry(geom.n_h, geom.n_w, c, mout,
                                         ct_h, ct_w, phases=4,
                                         input_stride=2)
            return ConvSpec(stream=stream,
                            blocks=(stream.bh * stream.bw, stream.block_c,
                                    stream.block_m), **strided)
        if resolved == "pallas_depthwise_strided":
            stream = _wg.stream_geometry_depthwise(geom.n_h, geom.n_w, c,
                                                   ct_h, ct_w, phases=4,
                                                   input_stride=2)
            return ConvSpec(stream=stream,
                            blocks=(stream.bh * stream.bw, stream.block_c),
                            **strided)
        return ConvSpec(**strided)

    if resolved in ("winograd_depthwise", "winograd_grouped"):
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        return ConvSpec(algorithm=resolved, output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, **base)

    if resolved == "pallas_depthwise":
        # Streamed depthwise: same halo blocking machinery as the dense
        # streaming kernel, channel axes collapsed (no M sweep, no C
        # reduction). A channel multiplier > 1 rides as a trailing taps
        # axis; the chooser widens its VMEM estimate accordingly.
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        stream = _wg.stream_geometry_depthwise(geom.n_h, geom.n_w, c,
                                               ct_h, ct_w, mult=mout // c)
        return ConvSpec(algorithm="pallas_depthwise", output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, stream=stream,
                        blocks=(stream.bh * stream.bw, stream.block_c),
                        **base)

    if resolved == "winograd_1d":
        # 1xN / Nx1: single-axis Cook-Toom (the Pallas families also declare
        # this executor -- its GEMM is one matmul XLA already maps to the
        # MXU).
        axis = 1 if kh > 1 else 2
        k = max(kh, kw)
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        m = (mh, mw)[axis - 1]
        ct = cook_toom(m, k)
        geom = _wg.conv1d_axis_geometry(x_shape[axis], axis, k, m, padding)
        return ConvSpec(algorithm="winograd_1d", output_tile=(m, m),
                        ct_w=ct, geometry=geom, axis=axis, **base)

    if resolved == "winograd":
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        return ConvSpec(algorithm="winograd", output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, **base)

    if resolved == "winograd_f63":
        # Large-tile F(6x6, 3x3): same executor as "winograd" with the
        # row-scaled transform set (transforms.scaled_cook_toom) that holds
        # the fp32 error budget at t = 8.
        ct_h, ct_w = scaled_cook_toom(6, kh), scaled_cook_toom(6, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, 6, 6, padding)
        return ConvSpec(algorithm="winograd_f63", output_tile=(6, 6),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, **base)

    if resolved == "fft":
        # rfft2 overlap-tiled executor: the transform lengths are the one
        # plan-time decision; output_tile persists them (fft = m + k - 1),
        # so artifact reloads rebuild the identical FFTGeometry.
        fftg = _fft.choose_fft_geometry(
            h, w, kh, kw,
            output_tile=(tuple(output_tile)
                         if isinstance(output_tile, (tuple, list))
                         else ((output_tile, output_tile)
                               if output_tile else None)))
        geom = _wg.conv2d_fft_geometry(h, w, kh, kw, fftg.fft_h, fftg.fft_w,
                                       padding)
        return ConvSpec(algorithm="fft", output_tile=(fftg.m_h, fftg.m_w),
                        geometry=geom, fft=fftg, **base)

    if resolved == "pallas_winograd":
        # Streaming executor: halo-blocking geometry (strip origins,
        # edge-block padding, VMEM budget -> block sizes) derived here, once.
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        stream = _wg.stream_geometry(geom.n_h, geom.n_w, c, mout, ct_h, ct_w)
        return ConvSpec(algorithm="pallas_winograd", output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, stream=stream,
                        blocks=(stream.bh * stream.bw, stream.block_c,
                                stream.block_m), **base)

    if resolved == "pallas_winograd_materialized":
        from repro.kernels import ops  # local import: kernels are optional
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        blocks = ops.winograd_blocks(n * geom.n_h * geom.n_w, c, mout)
        return ConvSpec(algorithm="pallas_winograd_materialized",
                        output_tile=(mh, mw), ct_h=ct_h, ct_w=ct_w,
                        geometry=geom, blocks=blocks, **base)

    if resolved == "im2col":
        geom = _im2col.im2row_geometry(h, w, kh, kw, stride, padding)
        return ConvSpec(algorithm="im2col", geometry=geom, **base)

    if resolved == "pallas_im2col":
        from repro.kernels import ops
        geom = _im2col.im2row_geometry(h, w, kh, kw, stride, padding)
        blocks = ops.im2col_blocks(n * geom.oh * geom.ow, kh * kw * c, mout)
        return ConvSpec(algorithm="pallas_im2col", geometry=geom,
                        blocks=blocks, **base)

    raise ValueError(f"unknown algorithm {resolved!r}")


def _depthwise_domain_taps(w: jax.Array, ct_h: CookToom, ct_w: CookToom,
                           c_in: int, c_pad: int) -> jax.Array:
    """(kh, kw, 1, C) depthwise filter -> (P, Cp) Winograd-domain taps,
    channel-padded to the kernel block grid. The one recipe shared by the
    pallas_depthwise plan binding and the fused separable-block binding."""
    u = _wg.transform_filter_2d(w, ct_h, ct_w)            # (th, tw, 1, C)
    u = u.reshape(ct_h.t * ct_w.t, c_in)                  # (P, C)
    return jnp.pad(u, ((0, 0), (0, c_pad - c_in)))


def _domain_filter(spec: ConvSpec, w: jax.Array) -> jax.Array:
    """Transform the filter into the spec's execution domain (fp32). This is
    the once-per-plan weight work; ConvPlan.apply never touches it again."""
    kh, kw, c, mout = spec.w_shape     # c = C/groups (HWIO grouped filter)
    if spec.algorithm in ("winograd", "winograd_f63"):
        return _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
    if spec.algorithm == "fft":
        return _fft.fft_transform_filter(w, spec.fft.fft_h, spec.fft.fft_w)
    if spec.algorithm == "winograd_1d":
        return _wg.transform_filter_1d(w.reshape(max(kh, kw), c, mout),
                                       spec.ct_w)
    if spec.algorithm == "winograd_depthwise":
        c_in = spec.x_shape[3]
        u = _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)  # (th, tw, 1, M)
        return u.reshape(spec.ct_h.t, spec.ct_w.t, c_in, mout // c_in)
    if spec.algorithm == "winograd_grouped":
        return _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
    if spec.algorithm == "winograd_strided":
        u = _wg.strided_phase_filters(w, spec.ct_h, spec.ct_w)
        if spec.groups > 1 and spec.groups == spec.x_shape[3]:
            # depthwise: make the channel axis explicit, (2,2,th,tw,C,mult)
            c_in = spec.x_shape[3]
            return u.reshape(*u.shape[:4], c_in, mout // c_in)
        return u                                     # (2, 2, th, tw, Cg, M)
    if spec.algorithm == "pallas_winograd_strided":
        from repro.kernels import ops
        u = _wg.strided_phase_filters(w, spec.ct_h, spec.ct_w)
        u = u.reshape(4 * spec.ct_h.t * spec.ct_w.t, c, mout)  # phase-major
        return ops.pad_winograd_filter(u, spec.blocks[1], spec.blocks[2])
    if spec.algorithm == "pallas_depthwise_strided":
        c_in = spec.x_shape[3]
        u = _wg.strided_phase_filters(w, spec.ct_h, spec.ct_w)
        u = u.reshape(4 * spec.ct_h.t * spec.ct_w.t, c_in)     # (4P, C)
        return jnp.pad(u, ((0, 0), (0, spec.stream.c_pad - c_in)))
    if spec.algorithm == "pallas_depthwise":
        # (kh, kw, 1, C*mult) -> (P, Cp, mult): the last HWIO axis is
        # o = c*mult + j (lax ordering), so the reshape peels the
        # multiplier off as a trailing taps axis.
        c_in = spec.x_shape[3]
        u = _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
        u = u.reshape(spec.ct_h.t * spec.ct_w.t, c_in, mout // c_in)
        return jnp.pad(u, ((0, 0), (0, spec.stream.c_pad - c_in), (0, 0)))
    if spec.algorithm in ("pallas_winograd", "pallas_winograd_materialized"):
        from repro.kernels import ops
        u = _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
        u = u.reshape(spec.ct_h.t * spec.ct_w.t, c, mout)
        return ops.pad_winograd_filter(u, spec.blocks[1], spec.blocks[2])
    if spec.algorithm == "im2col":
        if spec.groups > 1:
            return _im2col.grouped_filter_matrix(w, spec.groups)
        return w.reshape(kh * kw * c, mout)
    if spec.algorithm == "pallas_im2col":
        from repro.kernels import ops
        return ops.pad_im2col_filter(w.reshape(kh * kw * c, mout),
                                     spec.blocks[1], spec.blocks[2])
    raise ValueError(spec.algorithm)


def _quantize_axes(spec: ConvSpec) -> tuple[tuple[int, ...], str]:
    """(channel_axes, scale_form) of the int8 per-output-channel quantizer
    for one executor's execution-domain filter layout. `channel_axes` are
    the axes that together enumerate output channels (depthwise layouts
    split them into (C, mult)); scale_form says how ConvPlan.scale is
    shaped for the executor's epilogue -- 'flat' (pure-JAX: one f32 per
    NHWC output channel, broadcast in _dequantize) or 'row' (Pallas: a
    (1, M_padded) operand mirroring the bias blockspec)."""
    alg = spec.algorithm
    depthwise = spec.groups > 1 and spec.groups == spec.x_shape[3]
    if alg in ("winograd", "winograd_1d", "winograd_grouped"):
        return (-1,), "flat"
    if alg == "winograd_depthwise":
        return (-2, -1), "flat"
    if alg == "winograd_strided":
        return ((-2, -1) if depthwise else (-1,)), "flat"
    if alg == "im2col":
        return ((0, 2) if spec.groups > 1 else (-1,)), "flat"
    if alg in ("pallas_winograd", "pallas_winograd_materialized",
               "pallas_winograd_strided", "pallas_im2col",
               "pallas_depthwise_strided"):
        return (-1,), "row"
    if alg == "pallas_depthwise":
        return (-2, -1), "row"
    raise ValueError(
        f"executor {alg!r} has no int8 transform-domain path")


def _bind_weights(spec: ConvSpec,
                  w: jax.Array) -> tuple[jax.Array, jax.Array | None]:
    """Filter -> (execution-domain filter, dequantization scale). fp32
    plans get (fp32 u, None); bf16 plans downcast the transformed filter
    (dequantization is implicit -- bf16 is a truncated fp32); int8 plans
    quantize per output channel AFTER the transform and padding, so
    `u_int8 * scale` reproduces the fp32 transformed filter up to rounding
    and the hot path dequantizes with ONE per-channel multiply folded into
    the bias+activation epilogue. All of this is once-per-plan weight work;
    warm artifact loads bypass it entirely."""
    global _QUANTIZED
    u = _domain_filter(spec, w)
    cd = spec.compute_dtype
    if cd == "float32":
        return u, None
    if cd == "bfloat16":
        return u.astype(jnp.bfloat16), None
    if cd == "int8":
        from repro.optim import compression as _comp
        axes, form = _quantize_axes(spec)
        q, scale = _comp.quantize_channelwise(u, channel_axes=axes)
        _QUANTIZED += 1
        scale = (scale.reshape(1, -1) if form == "row"
                 else scale.reshape(-1))
        return q, scale
    raise ValueError(f"unknown compute_dtype {cd!r}; expected one of "
                     f"{registry.COMPUTE_DTYPES}")


# ---------------------------------------------------------------------------
# ConvPlan: spec + weights in the execution domain
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """A fully-decided, weight-bound convolution. apply(x) does only input
    work: pad, tile, transform the input, GEMM against the cached filter,
    inverse-transform. No filter transform, no geometry derivation.

    apply(x, bias=..., activation=...) runs the layer epilogue too: on the
    Pallas executors (streaming Winograd, im2col GEMM) the bias add and
    activation are fused into the kernel's store step, so the conv output
    never round-trips HBM before the elementwise work; pure-JAX executors
    apply the same contract as one XLA op."""

    spec: ConvSpec
    u: jax.Array                       # filter in the execution domain
                                       # (fp32 / bf16 / int8 per
                                       # spec.compute_dtype)
    build_time_s: float = 0.0
    precision: Any = None
    scale: jax.Array | None = None     # int8 per-output-channel dequant
                                       # scales (None for fp32/bf16): flat
                                       # (M,) on pure-JAX executors, (1, Mp)
                                       # on Pallas executors (a kernel
                                       # operand mirroring the bias)

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias: jax.Array | None = None,
              activation: str = "none") -> jax.Array:
        spec = self.spec
        if spec.layout == "NCHW":
            # NCHW ingest: one boundary transpose per call (the weights were
            # transposed once, at plan time); executors always run NHWC.
            want = (spec.x_shape[3],) + spec.x_shape[1:3]
            if x.shape[1:] != want:
                raise ValueError(
                    f"plan built for NCHW input (N, {want[0]}, {want[1]}, "
                    f"{want[2]}) got {x.shape} (batch may differ; C/H/W "
                    f"must match)")
            y = self._apply_nhwc(jnp.transpose(x, (0, 2, 3, 1)), bias,
                                 activation)
            return jnp.transpose(y, (0, 3, 1, 2))
        return self._apply_nhwc(x, bias, activation)

    def _dequantize(self, y: jax.Array) -> jax.Array:
        """Fold the int8 per-output-channel scales back in (pure-JAX
        executors only -- the Pallas kernels take `scale` as an operand and
        multiply in the store epilogue). One elementwise multiply, fused by
        XLA into the bias/activation epilogue that follows."""
        if self.scale is None:
            return y
        return y * self.scale.reshape(-1).astype(y.dtype)

    def _apply_nhwc(self, x: jax.Array, bias: jax.Array | None,
                    activation: str) -> jax.Array:
        spec = self.spec
        if x.shape[1:] != spec.x_shape[1:]:
            raise ValueError(
                f"plan built for input {spec.x_shape} got {x.shape} "
                f"(batch may differ; H/W/C must match)")
        if activation not in EPILOGUE_ACTIVATIONS:
            raise ValueError(f"unknown activation {activation!r}; "
                             f"expected one of {EPILOGUE_ACTIVATIONS}")
        alg = spec.algorithm
        if alg in ("winograd", "winograd_f63"):
            y = _wg.winograd_conv2d_pretransformed(
                x, self.u, spec.ct_h, spec.ct_w, padding=spec.padding,
                geometry=spec.geometry, precision=self.precision)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "fft":
            y = _fft.fft_conv2d_pretransformed(
                x, self.u, spec.fft, padding=spec.padding,
                geometry=spec.geometry, precision=self.precision)
            return _epilogue_jnp(y, bias, activation)
        if alg == "winograd_1d":
            y = _wg.winograd_conv1d_axis_pretransformed(
                x, self.u, spec.ct_w, spec.geometry, precision=self.precision)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "winograd_depthwise":
            y = _wg.winograd_depthwise_conv2d_pretransformed(
                x, self.u, spec.ct_h, spec.ct_w, padding=spec.padding,
                geometry=spec.geometry)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "winograd_grouped":
            y = _wg.winograd_grouped_conv2d_pretransformed(
                x, self.u, spec.ct_h, spec.ct_w, spec.groups,
                padding=spec.padding, geometry=spec.geometry,
                precision=self.precision)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "winograd_strided":
            y = _wg.winograd_strided_conv2d_pretransformed(
                x, self.u, spec.ct_h, spec.ct_w, groups=spec.groups,
                geometry=spec.geometry, precision=self.precision)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "pallas_winograd_strided":
            from repro.kernels import ops
            return ops.winograd_strided_conv2d_planned(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_shape[3], bias=bias, activation=activation,
                scale=self.scale)
        if alg == "pallas_depthwise_strided":
            from repro.kernels import ops
            return ops.depthwise_strided_conv2d_planned(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_shape[3], bias=bias, activation=activation,
                scale=self.scale)
        if alg == "im2col":
            geom = spec.geometry
            kh, kw, _, mout = spec.w_shape
            b = self.u
            if b.dtype == jnp.bfloat16:
                cast = lambda a: a.astype(jnp.bfloat16)   # noqa: E731
            elif b.dtype != x.dtype:
                b, cast = b.astype(x.dtype), (lambda a: a)  # int8 -> f32
            else:
                cast = lambda a: a                        # noqa: E731
            if spec.groups > 1:
                a, _ = _im2col.grouped_im2row(x, kh, kw, spec.stride,
                                              spec.padding, spec.groups, geom)
                y = jnp.einsum("rgk,gkm->rgm", cast(a), b,
                               precision=self.precision,
                               preferred_element_type=jnp.float32)
            else:
                a, _ = _im2col.im2row(x, kh, kw, spec.stride, spec.padding,
                                      geom)
                y = jnp.matmul(cast(a), b, precision=self.precision,
                               preferred_element_type=jnp.float32)
            y = y.reshape(x.shape[0], geom.oh, geom.ow, mout).astype(x.dtype)
            return _epilogue_jnp(self._dequantize(y), bias, activation)
        if alg == "pallas_depthwise":
            from repro.kernels import ops
            return ops.depthwise_conv2d_planned(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_shape[3], bias=bias, activation=activation,
                scale=self.scale)
        if alg == "pallas_winograd":
            from repro.kernels import ops
            return ops.winograd_conv2d_planned(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_shape[3], bias=bias, activation=activation,
                scale=self.scale)
        if alg == "pallas_winograd_materialized":
            from repro.kernels import ops
            _, _, c, mout = spec.w_shape
            y = ops.winograd_conv2d_planned_materialized(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, blocks=spec.blocks, c_in=c,
                c_out=mout)
            return _epilogue_jnp(y, bias, activation)
        if alg == "pallas_im2col":
            from repro.kernels import ops
            kh, kw, _, mout = spec.w_shape
            return ops.im2col_conv2d_planned(
                x, self.u, kh=kh, kw=kw, stride=spec.stride,
                padding=spec.padding, geometry=spec.geometry,
                blocks=spec.blocks, c_out=mout, bias=bias,
                activation=activation, scale=self.scale)
        raise ValueError(alg)

    @property
    def algorithm(self) -> str:
        return self.spec.algorithm

    @property
    def out_shape(self) -> tuple[int, ...]:
        spec, g = self.spec, self.spec.geometry
        mout = spec.w_shape[-1]
        n = spec.x_shape[0]
        if spec.algorithm in ("winograd", "winograd_f63", "fft",
                              "winograd_depthwise",
                              "winograd_grouped", "winograd_strided",
                              "pallas_winograd", "pallas_depthwise",
                              "pallas_winograd_strided",
                              "pallas_depthwise_strided",
                              "pallas_winograd_materialized"):
            shape = (n, g.out_h, g.out_w, mout)
        elif spec.algorithm == "winograd_1d":
            h, w = spec.x_shape[1:3]
            shape = ((n, g.out_size, w, mout) if g.axis == 1
                     else (n, h, g.out_size, mout))
        else:
            shape = (n, g.oh, g.ow, mout)
        if spec.layout == "NCHW":
            return (shape[0], shape[3], shape[1], shape[2])
        return shape

    # ---- LayerPlan protocol: describe + artifact (de)serialization -------

    def describe(self) -> dict:
        spec = self.spec
        kh, kw = spec.w_shape[:2]
        if spec.requested == "auto_tuned":
            # an auto_tuned plan says HOW it was decided: "measured" carries
            # the timing-race evidence (spec.autotune_report), "heuristic"
            # means the static fallback decided (planning inside a jit
            # trace, REPRO_PLAN_NO_MEASURE, or a sole-candidate layer).
            decision = "measured" if spec.autotune is not None else \
                "heuristic"
        else:
            decision = "static"
        return {"kind": "conv2d", "executor": spec.algorithm,
                "requested": spec.requested, "filter": f"{kh}x{kw}",
                "stride": f"{spec.stride[0]}x{spec.stride[1]}",
                "groups": spec.groups,
                "tile": ("x".join(map(str, spec.output_tile))
                         if spec.output_tile else "-"),
                "decision": decision,
                "compute_dtype": spec.compute_dtype}

    def to_artifact(self) -> tuple[dict, dict]:
        """(meta, arrays): `meta` is the JSON-safe spec record from which
        _build_spec deterministically re-derives all geometry; `arrays` is
        the execution-domain filter. Loading re-runs neither the algorithm
        decision nor the filter transform."""
        spec = self.spec
        meta = {"kind": "conv2d", "x_shape": list(spec.x_shape),
                "w_shape": list(spec.w_shape), "dtype": spec.dtype,
                "stride": list(spec.stride), "padding": spec.padding,
                "requested": spec.requested, "algorithm": spec.algorithm,
                "groups": spec.groups, "layout": spec.layout,
                "compute_dtype": spec.compute_dtype,
                "output_tile": (list(spec.output_tile)
                                if spec.output_tile else None),
                "autotune": ([list(kv) for kv in spec.autotune]
                             if spec.autotune else None)}
        arrays = {"u": np.asarray(self.u)}
        if self.scale is not None:
            arrays["scale"] = np.asarray(self.scale)
        return meta, arrays

    @classmethod
    def from_artifact(cls, meta: dict, arrays: dict) -> "ConvPlan":
        """Rebuild the plan from a saved artifact: the spec geometry is
        re-derived from the *saved* resolved algorithm (deterministic, no
        measurement), and the execution-domain filter is taken verbatim --
        _bind_weights never runs, so no filter-transform op executes."""
        ot = meta["output_tile"]
        spec = _build_spec(tuple(meta["x_shape"]), tuple(meta["w_shape"]),
                           meta["dtype"], tuple(meta["stride"]),
                           meta["padding"], meta["requested"],
                           meta["algorithm"], tuple(ot) if ot else None,
                           meta["groups"], meta["layout"],
                           meta.get("compute_dtype", "float32"))
        if meta.get("autotune"):
            spec = dataclasses.replace(
                spec, autotune=tuple(
                    (k, tuple(v) if isinstance(v, list) else v)
                    for k, v in meta["autotune"]))
        scale = (jnp.asarray(arrays["scale"]) if "scale" in arrays
                 else None)
        return cls(spec=spec, u=jnp.asarray(arrays["u"]), scale=scale)


# ---------------------------------------------------------------------------
# Plan-time measured autotuning (algorithm="auto_tuned")
# ---------------------------------------------------------------------------

def _time_apply(plan: ConvPlan, x, warmup: int = 1, iters: int = 3) -> float:
    fn = jax.jit(plan.apply)
    for _ in range(warmup):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


#: Accuracy budgets of the auto_tuned dtype race: a reduced-precision
#: contender may only win when its relative max-abs error vs the fp32
#: reference output stays under budget. bf16 has ~3 decimal digits of
#: mantissa; int8's budget also absorbs the per-channel quantization grid.
AUTOTUNE_ACCURACY_BUDGET = {"bfloat16": 3e-2, "int8": 6e-2}

_DTYPE_LABEL = {"bfloat16": "bf16", "int8": "int8"}


def _autotune_contenders(x_shape, w_shape, stride, groups,
                         output_tile, fast: str,
                         pin_dtype: str = "float32",
                         dtype_race: bool = False) -> list[tuple]:
    """(label, executor, output_tile, compute_dtype) contenders of the
    N-way auto_tuned race: the registry-matched winograd-family executor at
    its default tile (F(4,3) for dense 3x3), its small-tile F(2,3) variant,
    the large-tile F(6,3) executor, the rfft2 executor, the im2row
    baseline, and the fast executor's reduced-precision (bf16/int8)
    transform-domain variants where its Capability declares them -- each
    only where the record covers the layer. Labels key the persisted
    evidence (t_<label>_s; dtype contenders also persist err_<label>)."""
    kh, kw = w_shape[:2]
    q = LayerQuery(kh=kh, kw=kw, stride=stride, groups=groups,
                   c_in=x_shape[3], c_out=w_shape[3])
    entries = [("winograd", fast, output_tile, "float32")]
    if fast == "winograd" and output_tile is None and (kh, kw) == (3, 3):
        entries.append(("winograd_f2", "winograd", 2, "float32"))
    if registry.supported("winograd_f63", q):
        entries.append(("f63", "winograd_f63", None, "float32"))
    if registry.supported("fft", q):
        entries.append(("fft", "fft", None, "float32"))
    entries.append(("im2col", "im2col", None, "float32"))
    if dtype_race or pin_dtype != "float32":
        # Reduced-precision contenders are strictly opt-in: the default
        # fp32 race must keep fp32 numerics (a crowned int8 winner would
        # silently change auto_tuned outputs by up to its accuracy
        # budget). compute_dtype="auto" opts the unpinned race in; a
        # pinned reduced dtype fields its own variant so the race times
        # what the pinned build will actually run.
        fast_dts = registry.compute_dtypes_for(fast)
        for dt in ("bfloat16", "int8"):
            if dt in fast_dts:
                entries.append((f"winograd_{_DTYPE_LABEL[dt]}", fast,
                                output_tile, dt))
    if pin_dtype != "float32":
        # A pinned reduced dtype drops contenders whose executor cannot run
        # it -- the race must not crown an fp32-only executor (fft, f63)
        # that the pinned build would then refuse.
        entries = [e for e in entries
                   if pin_dtype in registry.compute_dtypes_for(e[1])]
    return entries


def _measure_autotune(x_shape, w_shape, dtype, stride, padding,
                      output_tile, groups: int = 1,
                      fast: str = "winograd",
                      pin_dtype: str = "float32",
                      dtype_race: bool = False
                      ) -> tuple[str, Any, str, tuple]:
    """Time every registry-eligible contender on the real layer shape;
    return (winner executor, winner output_tile, winner compute_dtype,
    evidence). Runs once per shape per process (the spec cache holds the
    result) and the evidence tuple is persisted into NetworkPlan artifacts,
    so warm loads never re-measure. `fast` is the winograd-family executor
    the registry matched for this layer (grouped/depthwise/strided variants
    included); the legacy evidence keys t_winograd_s / t_im2col_s name that
    contender and the (grouped) im2row baseline.

    Reduced-precision contenders (winograd_bf16 / winograd_int8) enter the
    race only when the caller opted in (compute_dtype="auto" sets
    `dtype_race`, or a pinned reduced dtype fields its own variant) and
    are gated on accuracy BEFORE they may win: each is compared against the fp32 fast
    contender's output and dropped from the race (its err_<label> evidence
    still persisted) when its relative max-abs error exceeds
    AUTOTUNE_ACCURACY_BUDGET -- a quantized executor never wins on speed at
    the cost of a busted output."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(x_shape), dtype)
    w = jnp.asarray(rng.standard_normal(w_shape)
                    / (w_shape[0] * w_shape[1]), dtype)
    times: dict[str, tuple[float, str, Any, str]] = {}
    errs: list[tuple[str, float]] = []
    y_ref = None   # fp32 fast-contender output, the dtype-gate oracle
    for label, alg, ot, cd in _autotune_contenders(x_shape, w_shape, stride,
                                                   groups, output_tile,
                                                   fast, pin_dtype,
                                                   dtype_race):
        try:
            spec = _build_spec(x_shape, w_shape, str(jnp.dtype(dtype)),
                               stride, padding, alg, alg, ot, groups,
                               compute_dtype=cd)
            u, scale = _bind_weights(spec, w)
            plan = ConvPlan(spec=spec, u=u, scale=scale)
            if cd != "float32":
                if y_ref is None:
                    continue   # no fp32 oracle -> no gated contender
                y = np.asarray(jax.jit(plan.apply)(x), np.float32)
                err = float(np.max(np.abs(y - y_ref))
                            / (np.max(np.abs(y_ref)) or 1.0))
                errs.append((f"err_{label}", err))
                if err > AUTOTUNE_ACCURACY_BUDGET[cd]:
                    continue   # accuracy gate: may not win the race
            t = _time_apply(plan, x)
            if label == "winograd":
                y_ref = np.asarray(jax.jit(plan.apply)(x), np.float32)
        except Exception:
            if label in ("winograd", "im2col"):
                raise  # the two contenders every eligible layer must have
            continue
        times[label] = (t, spec.algorithm, spec.output_tile, cd)
    win = min(times, key=lambda k: times[k][0])
    _, winner, winner_tile, winner_dtype = times[win]
    evidence = [(f"t_{label}_s", times[label][0]) for label in times]
    evidence.extend(errs)
    # winner: resolved executor; winner_label: the contender that won the
    # race (the two differ when e.g. the F(2,3) tile variant of the same
    # winograd executor wins, or a reduced-precision variant of it does).
    evidence.append(("winner_label", win))
    evidence.append(("winner", winner))
    evidence.append(("winner_dtype", winner_dtype))
    if winner_tile is not None:
        evidence.append(("winner_tile", tuple(winner_tile)))
    # race identity, so repro.obs.tuningdb can reconstruct the exact
    # planning request (the dtype pin vs the "auto" race, the requested
    # tile) when lifting this evidence out of an artifact.
    evidence.append(("pin_dtype", pin_dtype))
    evidence.append(("dtype_race", bool(dtype_race)))
    if output_tile is not None:
        evidence.append(("req_tile", tuple(output_tile)
                         if isinstance(output_tile, (tuple, list))
                         else (output_tile, output_tile)))
    return winner, winner_tile, winner_dtype, tuple(evidence)


# ---------------------------------------------------------------------------
# plan_conv2d: the public entry point
# ---------------------------------------------------------------------------

def plan_conv2d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    groups: int = 1,
    output_tile: int | tuple[int, int] | None = None,
    precision=None,
    dtype=None,
    data_format: str = "NHWC",
    compute_dtype: str = "float32",
) -> ConvPlan:
    """Build a ConvPlan for a (N, H, W, C) x (kh, kw, C/groups, M) conv.

    All per-layer decisions (algorithm, transform variant, padding/tiling
    geometry, Pallas blocking) are made here, once; the filter is transformed
    into the execution domain, once. Decisions are cached process-wide keyed
    on (shapes, dtype, stride, padding, algorithm, groups, output_tile,
    data_format), so repeated planning of the same layer shape -- including
    a measured auto_tuned choice -- is a dict lookup plus one filter
    transform.

    Algorithm resolution is a query against the capability-declaring
    executor registry (repro.core.registry): the concrete families resolve
    to the matching declared executor or raise an error enumerating the
    executors that do cover the layer; "auto" is the paper's mixed policy
    (cheapest matching fast-scheme capability, else im2row). Stride-2
    layers with odd filters resolve to the transform-domain
    phase-decomposition executors (winograd_strided / the strided Pallas
    kernels).

    `groups` is jax.lax's feature_group_count: 1 is the dense conv, C is a
    depthwise conv ((kh, kw, 1, C*mult) filter), anything between is a
    grouped conv.

    `data_format="NCHW"` ingests NCHW inputs with an OIHW (M, C/groups, kh,
    kw) filter -- checkpoint compatibility: the filter is transposed to HWIO
    once, here, and apply() transposes x/y at the call boundary.

    `compute_dtype` selects the transform-domain GEMM/Hadamard dtype:
    "float32" (default), "bfloat16" (filter cast once at bind time), or
    "int8" (per-output-channel symmetric weight quantization at bind time;
    dequantization folds into the bias+activation epilogue). The input and
    inverse transforms always run fp32. An explicit reduced dtype pins the
    choice. `compute_dtype="auto"` (requires `algorithm="auto_tuned"`)
    additionally fields bf16/int8 contenders in the measured race, gated
    by AUTOTUNE_ACCURACY_BUDGET, and adopts the winner's dtype; the
    default "float32" race never lowers precision, so plain auto_tuned
    keeps fp32 numerics.
    """
    global _CACHE_HITS, _CACHE_MISSES
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; expected one of "
                         f"{ALGORITHMS}")
    if data_format not in registry.LAYOUTS:
        raise ValueError(f"unknown data_format {data_format!r}; expected one "
                         f"of {registry.LAYOUTS}")
    if len(x_shape) != 4 or len(w.shape) != 4:
        raise ValueError(f"expected 4D input x 4D filter, got {x_shape} x "
                         f"{tuple(w.shape)}")
    if data_format == "NCHW":
        # One plan-time normalization: NCHW/OIHW -> NHWC/HWIO. The weight
        # transpose happens once per plan; the spec cache key carries the
        # layout so NCHW and NHWC plans of the same shape stay distinct.
        x_shape = (x_shape[0], x_shape[2], x_shape[3], x_shape[1])
        w = jnp.transpose(w, (2, 3, 1, 0))
    w_shape = tuple(w.shape)
    if groups < 1 or x_shape[3] % groups or w_shape[3] % groups:
        raise ValueError(
            f"groups={groups} must divide both C_in={x_shape[3]} and "
            f"C_out={w_shape[3]}")
    if x_shape[3] != w_shape[2] * groups:
        raise ValueError(
            f"channel mismatch: input {x_shape} (NHWC) filter {w_shape} "
            f"(HWIO) groups={groups} (grouped filters carry C_in/groups "
            f"input channels)")
    stride = (stride, stride) if isinstance(stride, int) else tuple(stride)
    dtype = dtype or w.dtype
    dtype_str = str(jnp.dtype(dtype))
    dtype_race = compute_dtype == "auto"
    if dtype_race:
        if algorithm != "auto_tuned":
            raise ValueError(
                "compute_dtype='auto' races bf16/int8 against fp32 and "
                "needs measured evidence -- it requires "
                "algorithm='auto_tuned' (got algorithm="
                f"{algorithm!r}); pin a concrete dtype otherwise")
        compute_dtype = "float32"   # race baseline; winner may lower it
    else:
        compute_dtype = str(jnp.dtype(compute_dtype))
    if compute_dtype not in registry.COMPUTE_DTYPES:
        raise ValueError(
            f"unknown compute_dtype {compute_dtype!r}; expected one of "
            f"{registry.COMPUTE_DTYPES}")
    kh, kw = w_shape[:2]
    n, h, wdt, c = x_shape
    query = LayerQuery(kh=kh, kw=kw, stride=stride, groups=groups, c_in=c,
                       c_out=w_shape[3], layout=data_format)

    key = (x_shape, w_shape, dtype_str, stride, padding, algorithm,
           output_tile if not isinstance(output_tile, list) else
           tuple(output_tile), precision, groups, data_format,
           "auto" if dtype_race else compute_dtype)
    spec = _SPEC_CACHE.get(key) if _cache_enabled() else None
    if spec is not None:
        _count_cache(True)
    else:
        _count_cache(False)
        fast = registry.best_fast(query)
        autotune = None
        build_tile = output_tile
        build_dtype = compute_dtype
        if algorithm == "auto":
            resolved = registry.select_auto(query).executor
        elif algorithm == "auto_tuned":
            if fast is None:
                resolved = "im2col"
                _record_autotune_resolution(measured=False)
            elif (tuned := _tuningdb_lookup(
                    x_shape, w_shape, dtype_str, stride, padding, groups,
                    data_format, "auto" if dtype_race else compute_dtype,
                    output_tile)) is not None or _measure_allowed():
                if tuned is not None:
                    # fleet tuning database: adopt the recorded winner,
                    # tile, dtype, and evidence -- zero local
                    # measurements (plan_cache_info()["tuningdb_hits"]).
                    resolved, tuned_tile, tuned_dtype, autotune = tuned
                else:
                    t_race = time.perf_counter()
                    resolved, tuned_tile, tuned_dtype, autotune = \
                        _measure_autotune(
                            x_shape, w_shape, dtype_str, stride, padding,
                            output_tile, groups, fast=fast.executor,
                            pin_dtype=compute_dtype,
                            dtype_race=dtype_race)
                    _obs_trace.add_span(
                        "plan.autotune.race", t_race, time.perf_counter(),
                        winner=resolved, contenders=len(
                            [k for k, _ in autotune
                             if k.startswith("t_")]),
                        layer=f"{x_shape}x{w_shape}")
                    _record_autotune_resolution(measured=True)
                if tuned_tile is not None:
                    build_tile = tuned_tile
                # Only compute_dtype="auto" fields reduced contenders, so
                # an un-opted race always returns tuned_dtype="float32"
                # and default numerics are untouched; an explicit reduced
                # dtype pins the choice (the race still picked the
                # executor). A pinned reduced dtype must not inherit an
                # fp32 winner's tile -- the low-precision grid needs the
                # small-tile default.
                if compute_dtype == "float32":
                    build_dtype = tuned_dtype
                elif tuned_dtype != compute_dtype:
                    build_tile = output_tile
            else:
                resolved = fast.executor if winograd_amortizes(
                    h, wdt, kh, kw, c, padding, groups, stride) else "im2col"
                _record_autotune_resolution(measured=False)
        else:
            # concrete algorithm families: the registry either yields the
            # declared executor or raises the capability-enumerating error.
            resolved = registry.resolve(algorithm, query).executor
        if build_dtype != "float32":
            supported = registry.compute_dtypes_for(resolved)
            if build_dtype not in supported:
                supporting = sorted({
                    cap.executor for cap in registry.CAPABILITIES
                    if build_dtype in cap.compute_dtypes})
                raise ValueError(
                    f"executor {resolved!r} does not support "
                    f"compute_dtype={build_dtype!r} (it supports "
                    f"{'/'.join(supported)}); executors with a "
                    f"{build_dtype} transform-domain path: {supporting}")
        spec = _build_spec(x_shape, w_shape, dtype_str, stride, padding,
                           algorithm, resolved, build_tile, groups,
                           data_format, compute_dtype=build_dtype)
        if autotune is not None:
            spec = dataclasses.replace(spec, autotune=autotune)
        # An auto_tuned decision made via the heuristic fallback (planning
        # under a jit trace) must not be cached: a later eager plan of the
        # same shape should still get to measure. Only measured decisions
        # (and the deterministic unsuitable->im2col case) are durable.
        durable = (algorithm != "auto_tuned" or autotune is not None
                   or fast is None)
        if _cache_enabled() and durable:
            _SPEC_CACHE[key] = spec

    u, scale = _bind_weights(spec, w)
    return ConvPlan(spec=spec, u=u, scale=scale, precision=precision,
                    build_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Separable blocks: depthwise kxk -> pointwise 1x1 planned as one fused unit
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SeparableSpec:
    """Cacheable decisions of a planned separable (depthwise kxk +
    pointwise 1x1) block. mode 'fused_pallas' runs both convs and both
    epilogues in ONE streamed kernel (kernels/depthwise.py:
    separable_streamed -- the intermediate never touches HBM); mode
    'composed' chains two ConvPlans (each with its own fused-epilogue
    path), covering strided / multiplier>1 / non-Pallas configurations."""

    x_shape: tuple[int, ...]          # (N, H, W, C)
    w_dw_shape: tuple[int, ...]       # (kh, kw, 1, C*mult)
    w_pw_shape: tuple[int, ...]       # (1, 1, C*mult, M)
    dtype: str
    stride: tuple[int, int]
    padding: str
    requested: str
    mode: str                         # "fused_pallas" | "composed"
    output_tile: tuple[int, int] | None = None
    ct_h: CookToom | None = None
    ct_w: CookToom | None = None
    geometry: Any = None              # Conv2DGeometry (fused mode)
    stream: Any = None                # StreamGeometry (fused mode)


@dataclasses.dataclass(frozen=True)
class SeparableBlockPlan:
    """A planned MobileNet-style separable block with a single epilogue
    contract: apply(x, bias_dw=, bias_pw=, inner_activation=, activation=)
    runs depthwise conv -> bias+activation -> pointwise conv ->
    bias+activation. In fused mode all of it happens inside one Pallas
    kernel; in composed mode each conv rides its own plan's epilogue."""

    spec: SeparableSpec
    u_dw: jax.Array | None = None      # (P, Cp) fused-mode depthwise taps
    u_pw: jax.Array | None = None      # (Cp, Mp) fused-mode pointwise matrix
    dw: ConvPlan | None = None         # composed-mode sub-plans
    pw: ConvPlan | None = None
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias_dw: jax.Array | None = None,
              bias_pw: jax.Array | None = None,
              inner_activation: str = "relu",
              activation: str = "relu") -> jax.Array:
        spec = self.spec
        if x.shape[1:] != spec.x_shape[1:]:
            raise ValueError(
                f"plan built for input {spec.x_shape} got {x.shape} "
                f"(batch may differ; H/W/C must match)")
        for act in (inner_activation, activation):
            if act not in EPILOGUE_ACTIVATIONS:
                raise ValueError(f"unknown activation {act!r}; expected one "
                                 f"of {EPILOGUE_ACTIVATIONS}")
        if spec.mode == "fused_pallas":
            from repro.kernels import ops
            return ops.separable_conv2d_planned(
                x, self.u_dw, self.u_pw, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_pw_shape[3], bias_dw=bias_dw, bias_pw=bias_pw,
                inner_activation=inner_activation, activation=activation)
        h = self.dw.apply(x, bias=bias_dw, activation=inner_activation)
        return self.pw.apply(h, bias=bias_pw, activation=activation)

    @property
    def mode(self) -> str:
        return self.spec.mode

    @property
    def out_shape(self) -> tuple[int, ...]:
        if self.spec.mode == "fused_pallas":
            g = self.spec.geometry
            return (self.spec.x_shape[0], g.out_h, g.out_w,
                    self.spec.w_pw_shape[3])
        return self.pw.out_shape

    # ---- LayerPlan protocol: describe + artifact (de)serialization -------

    def describe(self) -> dict:
        spec = self.spec
        if spec.mode == "fused_pallas":
            executor = "separable_streamed"
            cd = "float32"
        else:
            executor = f"{self.dw.algorithm}+{self.pw.algorithm}"
            cds = [self.dw.spec.compute_dtype, self.pw.spec.compute_dtype]
            cd = cds[0] if cds[0] == cds[1] else "+".join(cds)
        return {"kind": "separable", "executor": executor,
                "compute_dtype": cd,
                "requested": spec.requested, "mode": spec.mode,
                "filter": f"{spec.w_dw_shape[0]}x{spec.w_dw_shape[1]}+1x1",
                "stride": f"{spec.stride[0]}x{spec.stride[1]}",
                "groups": spec.x_shape[3],
                "tile": ("x".join(map(str, spec.output_tile))
                         if spec.output_tile else "-")}

    def to_artifact(self) -> tuple[dict, dict]:
        spec = self.spec
        meta = {"kind": "separable", "mode": spec.mode,
                "x_shape": list(spec.x_shape),
                "w_dw_shape": list(spec.w_dw_shape),
                "w_pw_shape": list(spec.w_pw_shape), "dtype": spec.dtype,
                "stride": list(spec.stride), "padding": spec.padding,
                "requested": spec.requested,
                "output_tile": (list(spec.output_tile)
                                if spec.output_tile else None)}
        if spec.mode == "fused_pallas":
            return meta, {"u_dw": np.asarray(self.u_dw),
                          "u_pw": np.asarray(self.u_pw)}
        meta["dw"], dw_arrays = self.dw.to_artifact()
        meta["pw"], pw_arrays = self.pw.to_artifact()
        arrays = {f"dw.{k}": v for k, v in dw_arrays.items()}
        arrays.update({f"pw.{k}": v for k, v in pw_arrays.items()})
        return meta, arrays

    @classmethod
    def from_artifact(cls, meta: dict, arrays: dict) -> "SeparableBlockPlan":
        ot = meta["output_tile"]
        if meta["mode"] == "fused_pallas":
            spec = _build_separable_fused_spec(
                tuple(meta["x_shape"]), tuple(meta["w_dw_shape"]),
                tuple(meta["w_pw_shape"]), meta["dtype"],
                tuple(meta["stride"]), meta["padding"], meta["requested"],
                tuple(ot) if ot else None)
            return cls(spec=spec, u_dw=jnp.asarray(arrays["u_dw"]),
                       u_pw=jnp.asarray(arrays["u_pw"]))
        spec = SeparableSpec(
            x_shape=tuple(meta["x_shape"]),
            w_dw_shape=tuple(meta["w_dw_shape"]),
            w_pw_shape=tuple(meta["w_pw_shape"]), dtype=meta["dtype"],
            stride=tuple(meta["stride"]), padding=meta["padding"],
            requested=meta["requested"], mode="composed",
            output_tile=tuple(ot) if ot else None)
        return cls(spec=spec,
                   dw=ConvPlan.from_artifact(meta["dw"],
                                             _sub_arrays(arrays, "dw.")),
                   pw=ConvPlan.from_artifact(meta["pw"],
                                             _sub_arrays(arrays, "pw.")))


def _sub_arrays(arrays: dict, prefix: str) -> dict:
    """Select the `prefix`-namespaced entries of a nested artifact's array
    dict, prefix stripped."""
    return {k[len(prefix):]: v for k, v in arrays.items()
            if k.startswith(prefix)}


def _build_separable_fused_spec(x_shape, dw_shape, pw_shape, dtype_str,
                                stride, padding, requested,
                                output_tile) -> SeparableSpec:
    """Derive the fused-mode SeparableSpec (transform set, conv geometry,
    halo blocking) -- shared by plan_separable_block and artifact reload."""
    n, h, wdt, c = x_shape
    kh, kw = dw_shape[:2]
    mh, mw = _resolve_output_tile(kh, kw, output_tile)
    ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
    geom = _wg.conv2d_geometry(h, wdt, kh, kw, mh, mw, padding)
    stream = _wg.stream_geometry(geom.n_h, geom.n_w, c, pw_shape[3],
                                 ct_h, ct_w)
    return SeparableSpec(
        x_shape=x_shape, w_dw_shape=dw_shape, w_pw_shape=pw_shape,
        dtype=dtype_str, stride=stride, padding=padding,
        requested=requested, mode="fused_pallas", output_tile=(mh, mw),
        ct_h=ct_h, ct_w=ct_w, geometry=geom, stream=stream)


def plan_separable_block(
    x_shape: tuple[int, ...],
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | tuple[int, int] | None = None,
    dtype=None,
    compute_dtype: str = "float32",
) -> SeparableBlockPlan:
    """Plan a depthwise kxk conv and its following 1x1 pointwise conv as one
    unit (the MobileNet separable block).

    With a Pallas algorithm on a fusable configuration (stride 1, suitable
    filter size, channel multiplier 1) the block is planned onto the fused
    streamed kernel: the depthwise output stays in VMEM and feeds the
    pointwise GEMM directly, with both bias+activation epilogues applied
    in-kernel. Every other configuration composes two ConvPlans (the
    depthwise one falling back per the usual suitability rules), so this
    entry point never rejects a block shape.

    A reduced `compute_dtype` (bfloat16 / int8) always composes: the fused
    separable kernel is fp32-only, and the composed sub-plans each carry
    their own quantized transform-domain filter + epilogue scales.
    """
    global _CACHE_HITS, _CACHE_MISSES
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    dw_shape, pw_shape = tuple(w_dw.shape), tuple(w_pw.shape)
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; expected one of "
                         f"{ALGORITHMS}")
    if len(x_shape) != 4 or len(dw_shape) != 4 or len(pw_shape) != 4:
        raise ValueError(f"expected NHWC x HWIO x HWIO, got {x_shape} x "
                         f"{dw_shape} x {pw_shape}")
    n, h, wdt, c = x_shape
    kh, kw = dw_shape[:2]
    if dw_shape[2] != 1 or dw_shape[3] % c:
        raise ValueError(f"depthwise filter must be (kh, kw, 1, C*mult) for "
                         f"C={c}, got {dw_shape}")
    if pw_shape[:2] != (1, 1) or pw_shape[2] != dw_shape[3]:
        raise ValueError(f"pointwise filter must be (1, 1, {dw_shape[3]}, M), "
                         f"got {pw_shape}")
    stride = (stride, stride) if isinstance(stride, int) else tuple(stride)
    dtype = dtype or w_dw.dtype
    dtype_str = str(jnp.dtype(dtype))
    mult = dw_shape[3] // c
    pallas = algorithm in ("pallas_winograd", "pallas_winograd_materialized",
                           "pallas_im2col")
    dw_query = registry.as_query(kh, kw, stride, groups=c, c_in=c,
                                 c_out=dw_shape[3])
    # Only the streamed-kernel request fuses; the Pallas *baseline*
    # algorithms must never be silently substituted with the fast path
    # (their whole point is to be the other arm of an A/B). The fused
    # separable kernel itself is stride-1 only -- stride-2 blocks compose a
    # strided depthwise plan with a pointwise plan below.
    fusable = (algorithm == "pallas_winograd" and mult == 1
               and stride == (1, 1)
               and str(jnp.dtype(compute_dtype)) == "float32"
               and registry.supported("pallas_winograd", dw_query))

    if fusable:
        key = ("sepblock", x_shape, dw_shape, pw_shape, dtype_str, stride,
               padding, algorithm, output_tile)
        spec = _SPEC_CACHE.get(key) if _cache_enabled() else None
        if spec is not None:
            _count_cache(True)
        else:
            _count_cache(False)
            spec = _build_separable_fused_spec(
                x_shape, dw_shape, pw_shape, dtype_str, stride, padding,
                algorithm, output_tile)
            if _cache_enabled():
                _SPEC_CACHE[key] = spec
        u_dw = _depthwise_domain_taps(w_dw, spec.ct_h, spec.ct_w, c,
                                      spec.stream.c_pad)
        u_pw = jnp.pad(w_pw.reshape(c, pw_shape[3]),
                       ((0, spec.stream.c_pad - c),
                        (0, spec.stream.m_pad - pw_shape[3])))
        return SeparableBlockPlan(spec=spec, u_dw=u_dw, u_pw=u_pw,
                                  build_time_s=time.perf_counter() - t0)

    # composed fallback: two plans, each on its best available executor.
    if pallas:
        # reached when the block cannot fuse (stride > 1, unsuitable k,
        # mult > 1) or a Pallas baseline was requested. The streamed-kernel
        # family keeps its own depthwise executors where one is declared
        # (e.g. the stride-2 streamed depthwise kernel); the Pallas
        # *baselines* have no depthwise executor and run grouped im2row.
        if algorithm == "pallas_winograd" and registry.supported(algorithm,
                                                                 dw_query):
            dw_alg = "pallas_winograd"
        else:
            dw_alg = "im2col"
        pw_alg = "pallas_im2col"
    else:
        dw_alg = algorithm
        if algorithm == "winograd" and not registry.supported("winograd",
                                                              dw_query):
            dw_alg = "im2col"
        pw_alg = "im2col" if algorithm == "im2col" else "auto"
    dw = plan_conv2d(x_shape, w_dw, stride=stride, padding=padding,
                     algorithm=dw_alg, groups=c, output_tile=output_tile,
                     dtype=dtype, compute_dtype=compute_dtype)
    pw = plan_conv2d(dw.out_shape, w_pw, stride=1, padding="SAME",
                     algorithm=pw_alg, dtype=dtype,
                     compute_dtype=compute_dtype)
    spec = SeparableSpec(x_shape=x_shape, w_dw_shape=dw_shape,
                         w_pw_shape=pw_shape, dtype=dtype_str, stride=stride,
                         padding=padding, requested=algorithm,
                         mode="composed")
    return SeparableBlockPlan(spec=spec, dw=dw, pw=pw,
                              build_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Inverted residual blocks (MobileNet-v2): expand -> depthwise -> project
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InvertedResidualPlan:
    """A planned MobileNet-v2 inverted residual unit: 1x1 expand (+bias,
    activation) -> kxk depthwise (+bias, activation) -> 1x1 linear project
    (+bias, NO activation) -> residual add when stride 1 and C_in == C_out.

    Built on the separable-block machinery: the depthwise+project pair is
    ONE SeparableBlockPlan, so on the Pallas path (stride 1, suitable k,
    multiplier 1) it runs as a single streamed kernel with the intermediate
    in VMEM; the expand conv is a pure channel GEMM XLA maps to the MXU
    directly. Stride-2 blocks compose, with the depthwise half on the
    strided transform-domain executors."""

    x_shape: tuple[int, ...]
    stride: tuple[int, int]
    residual: bool
    expand: ConvPlan | None            # None when expansion factor is 1
    sep: SeparableBlockPlan
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias_exp: jax.Array | None = None,
              bias_dw: jax.Array | None = None,
              bias_pw: jax.Array | None = None,
              activation: str = "relu6") -> jax.Array:
        h = x
        if self.expand is not None:
            h = self.expand.apply(h, bias=bias_exp, activation=activation)
        y = self.sep.apply(h, bias_dw=bias_dw, bias_pw=bias_pw,
                           inner_activation=activation,
                           activation="none")        # linear bottleneck
        return x + y if self.residual else y

    @property
    def mode(self) -> str:
        return self.sep.mode

    @property
    def out_shape(self) -> tuple[int, ...]:
        return self.sep.out_shape

    # ---- LayerPlan protocol: describe + artifact (de)serialization -------

    def describe(self) -> dict:
        d = self.sep.describe()
        executor = d["executor"]
        cd = d.get("compute_dtype", "float32")
        if self.expand is not None:
            executor = f"{self.expand.algorithm}+{executor}"
            exp_cd = self.expand.spec.compute_dtype
            if exp_cd != cd:
                cd = f"{exp_cd}+{cd}"
        return {"kind": "inverted_residual", "executor": executor,
                "compute_dtype": cd,
                "requested": d["requested"], "mode": self.mode,
                "filter": ("1x1+" if self.expand is not None else "")
                + d["filter"],
                "stride": f"{self.stride[0]}x{self.stride[1]}",
                "groups": self.sep.spec.x_shape[3],
                "tile": d["tile"],
                "residual": self.residual}

    def to_artifact(self) -> tuple[dict, dict]:
        meta = {"kind": "inverted_residual", "x_shape": list(self.x_shape),
                "stride": list(self.stride), "residual": self.residual,
                "expand": None}
        arrays = {}
        if self.expand is not None:
            meta["expand"], exp_arrays = self.expand.to_artifact()
            arrays.update({f"exp.{k}": v for k, v in exp_arrays.items()})
        meta["sep"], sep_arrays = self.sep.to_artifact()
        arrays.update({f"sep.{k}": v for k, v in sep_arrays.items()})
        return meta, arrays

    @classmethod
    def from_artifact(cls, meta: dict,
                      arrays: dict) -> "InvertedResidualPlan":
        expand = None
        if meta["expand"] is not None:
            expand = ConvPlan.from_artifact(meta["expand"],
                                            _sub_arrays(arrays, "exp."))
        sep = SeparableBlockPlan.from_artifact(meta["sep"],
                                               _sub_arrays(arrays, "sep."))
        return cls(x_shape=tuple(meta["x_shape"]),
                   stride=tuple(meta["stride"]), residual=meta["residual"],
                   expand=expand, sep=sep)


def plan_inverted_residual(
    x_shape: tuple[int, ...],
    w_exp: jax.Array | None,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | tuple[int, int] | None = None,
    dtype=None,
    compute_dtype: str = "float32",
) -> InvertedResidualPlan:
    """Plan a MobileNet-v2 inverted residual block as one unit.

    `w_exp` is the (1, 1, C, C*t) expansion filter (None for expand factor
    1), `w_dw` the (k, k, 1, C*t) depthwise filter, `w_pw` the
    (1, 1, C*t, M) linear projection. The depthwise+project pair rides
    plan_separable_block (fused streamed kernel where it applies); the
    residual connection is planned in when stride is 1 and M == C."""
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    stride = (stride, stride) if isinstance(stride, int) else tuple(stride)
    expand = None
    inner_shape = x_shape
    if w_exp is not None:
        # 1x1 expand: a pure channel GEMM -- "auto" resolves it to the
        # im2row executor, which for 1x1 is exactly one XLA matmul.
        expand = plan_conv2d(x_shape, w_exp, stride=1, padding="SAME",
                             algorithm="auto", dtype=dtype,
                             compute_dtype=compute_dtype)
        inner_shape = expand.out_shape
    sep = plan_separable_block(inner_shape, w_dw, w_pw, stride=stride,
                               padding=padding, algorithm=algorithm,
                               output_tile=output_tile, dtype=dtype,
                               compute_dtype=compute_dtype)
    residual = stride == (1, 1) and x_shape[3] == tuple(w_pw.shape)[3]
    return InvertedResidualPlan(
        x_shape=x_shape, stride=stride, residual=residual, expand=expand,
        sep=sep, build_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# conv1d plans (sequence convolutions, incl. polyphase stride > 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Conv1DPlan:
    """Planned (B, L, C) x (k, C, M) -> (B, L', M) sequence convolution.

    mode "as2d": stride-1, executed through a 2D plan on (B, L, 1, C).
    mode "polyphase": stride > 1 decomposed into stride-1 Cook-Toom
      sub-convolutions (sub-filter w[p::s] over sub-sequence x[p::s]), each
      planned independently; geometry (padding, output length) precomputed.
    mode "im2col": strided baseline through a 2D im2col plan.
    """

    x_shape: tuple[int, ...]
    w_shape: tuple[int, ...]
    stride: int
    padding: str
    requested: str
    mode: str
    inner: ConvPlan | None = None
    subplans: tuple[ConvPlan, ...] = ()
    pad: tuple[int, int] = (0, 0)
    out_len: int = 0
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias: jax.Array | None = None,
              activation: str = "none") -> jax.Array:
        if self.mode in ("as2d", "im2col"):
            return self.inner.apply(x[:, :, None, :], bias=bias,
                                    activation=activation)[:, :, 0, :]
        # polyphase: y[i] = sum_p (w[p::s] (*) x[p::s])[i]. The epilogue can
        # only run after the cross-phase sum, so it stays an XLA op here.
        s = self.stride
        x = jnp.pad(x, ((0, 0), self.pad, (0, 0)))
        acc = None
        for p, sub in enumerate(self.subplans):
            sub_x = x[:, p::s, None, :]
            y = sub.apply(sub_x)[:, :self.out_len, 0, :]
            acc = y if acc is None else acc + y
        return _epilogue_jnp(acc, bias, activation)

    # ---- LayerPlan protocol: describe + artifact (de)serialization -------

    def describe(self) -> dict:
        if self.mode == "polyphase":
            executor = (f"polyphase[{'+'.join(s.algorithm for s in self.subplans)}]")
        else:
            executor = self.inner.algorithm
        return {"kind": "conv1d", "executor": executor,
                "requested": self.requested, "mode": self.mode,
                "filter": f"k={self.w_shape[0]}", "stride": str(self.stride),
                "groups": 1, "tile": "-"}

    def to_artifact(self) -> tuple[dict, dict]:
        meta = {"kind": "conv1d", "mode": self.mode,
                "x_shape": list(self.x_shape), "w_shape": list(self.w_shape),
                "stride": self.stride, "padding": self.padding,
                "requested": self.requested, "pad": list(self.pad),
                "out_len": self.out_len}
        arrays = {}
        if self.mode in ("as2d", "im2col"):
            meta["inner"], inner_arrays = self.inner.to_artifact()
            arrays.update({f"inner.{k}": v for k, v in inner_arrays.items()})
        else:
            subs = []
            for i, sub in enumerate(self.subplans):
                sm, sa = sub.to_artifact()
                subs.append(sm)
                arrays.update({f"sub{i}.{k}": v for k, v in sa.items()})
            meta["subplans"] = subs
        return meta, arrays

    @classmethod
    def from_artifact(cls, meta: dict, arrays: dict) -> "Conv1DPlan":
        base = dict(x_shape=tuple(meta["x_shape"]),
                    w_shape=tuple(meta["w_shape"]), stride=meta["stride"],
                    padding=meta["padding"], requested=meta["requested"],
                    mode=meta["mode"], pad=tuple(meta["pad"]),
                    out_len=meta["out_len"])
        if meta["mode"] in ("as2d", "im2col"):
            inner = ConvPlan.from_artifact(meta["inner"],
                                           _sub_arrays(arrays, "inner."))
            return cls(inner=inner, **base)
        subplans = tuple(
            ConvPlan.from_artifact(sm, _sub_arrays(arrays, f"sub{i}."))
            for i, sm in enumerate(meta["subplans"]))
        return cls(subplans=subplans, **base)


def plan_conv1d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    stride: int = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | None = None,
) -> Conv1DPlan:
    """Plan a (B, L, C) x (k, C, M) sequence convolution (see Conv1DPlan)."""
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    b, length, c = x_shape
    k, _, m = w.shape
    base = dict(x_shape=x_shape, w_shape=tuple(w.shape), stride=stride,
                padding=padding, requested=algorithm)
    if stride == 1:
        inner = plan_conv2d((b, length, 1, c), w[:, None, :, :], stride=1,
                            padding=padding, algorithm=algorithm,
                            output_tile=output_tile)
        return Conv1DPlan(mode="as2d", inner=inner,
                          build_time_s=time.perf_counter() - t0, **base)

    if algorithm in ("winograd", "auto") and k > stride:
        if padding == "SAME":
            out = -(-length // stride)
            total = max((out - 1) * stride + k - length, 0)
            pad = (total // 2, total - total // 2)
        else:
            out = (length - k) // stride + 1
            pad = (0, 0)
        padded = length + pad[0] + pad[1]
        subplans = []
        for p in range(stride):
            sub_w = w[p::stride]                    # (ceil((k-p)/s), C, M)
            sub_len = -(-(padded - p) // stride)
            subplans.append(plan_conv2d(
                (b, sub_len, 1, c), sub_w[:, None, :, :], stride=1,
                padding="VALID", algorithm="auto", output_tile=output_tile))
        return Conv1DPlan(mode="polyphase", subplans=tuple(subplans),
                          pad=pad, out_len=out,
                          build_time_s=time.perf_counter() - t0, **base)

    inner = plan_conv2d((b, length, 1, c), w[:, None, :, :],
                        stride=(stride, 1), padding=padding,
                        algorithm="im2col")
    return Conv1DPlan(mode="im2col", inner=inner,
                      build_time_s=time.perf_counter() - t0, **base)


# ---------------------------------------------------------------------------
# Depthwise causal Cook-Toom conv1d plans (Mamba's short conv)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DepthwiseConv1DSpec:
    """Cacheable decisions of a planned (B, L, C) x (r, C) causal depthwise
    Cook-Toom convolution: the F(m, r) transform set, tile count, padding and
    kernel blocking -- everything the unplanned path re-derived per call."""

    x_shape: tuple[int, ...]          # (B, L, C) the plan was built for
    w_shape: tuple[int, ...]          # (r, C)
    dtype: str
    output_tile: int
    backend: str                      # "jnp" | "pallas"
    ct: CookToom = None
    n_tiles: int = 0
    pad_hi: int = 0                   # right pad so tiles cover n_tiles * m
    blocks: tuple[int, int] | None = None   # (block_s, block_c), pallas only


@dataclasses.dataclass(frozen=True)
class DepthwiseConv1DPlan:
    """Spec + taps in the Cook-Toom domain. apply(x) performs no cook_toom
    construction, tile-count or padding derivation -- only the input work."""

    spec: DepthwiseConv1DSpec
    u: jax.Array                      # (t, C) (jnp) / (t, Cp) (pallas) taps
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)

    def apply(self, x: jax.Array) -> jax.Array:
        spec = self.spec
        if x.shape[1:] != spec.x_shape[1:]:
            raise ValueError(
                f"plan built for input {spec.x_shape} got {x.shape} "
                f"(batch may differ; L/C must match)")
        if spec.backend == "pallas":
            from repro.kernels import ops
            return ops.ct_depthwise_causal_conv1d_planned(
                x, self.u, ct=spec.ct, n_tiles=spec.n_tiles,
                pad_hi=spec.pad_hi, blocks=spec.blocks,
                c_in=spec.w_shape[1])
        return _wg.ct_depthwise_causal_conv1d_pretransformed(
            x, self.u, spec.ct, n_tiles=spec.n_tiles, pad_hi=spec.pad_hi)

    # ---- LayerPlan protocol: describe + artifact (de)serialization -------

    def describe(self) -> dict:
        spec = self.spec
        return {"kind": "conv1d_depthwise",
                "executor": f"ct_causal_{spec.backend}",
                "requested": spec.backend, "filter": f"k={spec.w_shape[0]}",
                "stride": "1", "groups": spec.w_shape[1],
                "tile": str(spec.output_tile)}

    def to_artifact(self) -> tuple[dict, dict]:
        spec = self.spec
        meta = {"kind": "conv1d_depthwise", "x_shape": list(spec.x_shape),
                "w_shape": list(spec.w_shape), "dtype": spec.dtype,
                "output_tile": spec.output_tile, "backend": spec.backend}
        return meta, {"u": np.asarray(self.u)}

    @classmethod
    def from_artifact(cls, meta: dict,
                      arrays: dict) -> "DepthwiseConv1DPlan":
        r = meta["w_shape"][0]
        length = meta["x_shape"][1]
        ct = cook_toom(meta["output_tile"], r)
        nt = -(-length // ct.m)
        blocks = None
        if meta["backend"] == "pallas":
            from repro.kernels import ops
            blocks = ops.conv1d_ct_blocks(nt, meta["w_shape"][1])
        spec = DepthwiseConv1DSpec(
            x_shape=tuple(meta["x_shape"]), w_shape=tuple(meta["w_shape"]),
            dtype=meta["dtype"], output_tile=meta["output_tile"],
            backend=meta["backend"], ct=ct, n_tiles=nt,
            pad_hi=nt * ct.m - length, blocks=blocks)
        return cls(spec=spec, u=jnp.asarray(arrays["u"]))


def plan_depthwise_conv1d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    output_tile: int = 4,
    backend: str = "jnp",
    dtype=None,
) -> DepthwiseConv1DPlan:
    """Plan a causal depthwise Cook-Toom conv (B, L, C) x (r, C) -> (B, L, C).

    Decisions (cook_toom transform set, tile count, padding, Pallas blocking)
    are made once and cached process-wide keyed on (shape, dtype, output
    tile, backend); the taps are transformed into the Cook-Toom domain here.
    models/mamba.py routes its short conv through this, so the hot path does
    only input work per call.
    """
    global _CACHE_HITS, _CACHE_MISSES
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    if len(x_shape) != 3 or len(w.shape) != 2 or x_shape[2] != w.shape[1]:
        raise ValueError(f"expected (B, L, C) x (r, C), got "
                         f"{x_shape} x {tuple(w.shape)}")
    r, c = w.shape
    length = x_shape[1]
    dtype_str = str(jnp.dtype(dtype or w.dtype))
    key = ("dwconv1d", x_shape, tuple(w.shape), dtype_str, output_tile,
           backend)
    spec = _SPEC_CACHE.get(key) if _cache_enabled() else None
    if spec is not None:
        _count_cache(True)
    else:
        _count_cache(False)
        ct = cook_toom(output_tile, r)
        nt = -(-length // ct.m)
        blocks = None
        if backend == "pallas":
            from repro.kernels import ops
            blocks = ops.conv1d_ct_blocks(nt, c)
        elif backend != "jnp":
            raise ValueError(f"unknown backend {backend!r}")
        spec = DepthwiseConv1DSpec(
            x_shape=x_shape, w_shape=tuple(w.shape), dtype=dtype_str,
            output_tile=output_tile, backend=backend, ct=ct, n_tiles=nt,
            pad_hi=nt * ct.m - length, blocks=blocks)
        if _cache_enabled():
            _SPEC_CACHE[key] = spec

    u = jnp.einsum("ij,jc->ic", jnp.asarray(spec.ct.G, w.dtype), w)  # (t, C)
    if spec.backend == "pallas":
        bc = spec.blocks[1]
        pad_c = -(-c // bc) * bc - c
        if pad_c:
            u = jnp.pad(u, ((0, 0), (0, pad_c)))
    return DepthwiseConv1DPlan(spec=spec, u=u,
                               build_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# LayerPlan protocol dispatcher (artifact reload)
# ---------------------------------------------------------------------------

#: kind tag (to_artifact meta["kind"]) -> plan class. Every class conforms
#: to the LayerPlan protocol: apply(x, ...), describe(), to_artifact(),
#: from_artifact(meta, arrays).
PLAN_KINDS = {
    "conv2d": ConvPlan,
    "separable": SeparableBlockPlan,
    "inverted_residual": InvertedResidualPlan,
    "conv1d": Conv1DPlan,
    "conv1d_depthwise": DepthwiseConv1DPlan,
}


def plan_from_artifact(meta: dict, arrays: dict):
    """Rebuild any LayerPlan from its (meta, arrays) artifact pair. The
    inverse of .to_artifact(): geometry is re-derived deterministically from
    the saved decisions; the execution-domain weights are taken verbatim
    (no filter transform runs)."""
    kind = meta.get("kind")
    if kind not in PLAN_KINDS:
        raise ValueError(f"unknown plan artifact kind {kind!r}; expected one "
                         f"of {sorted(PLAN_KINDS)}")
    return PLAN_KINDS[kind].from_artifact(meta, arrays)
