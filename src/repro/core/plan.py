"""Plan/execute split for convolution: decide once, run many.

The paper's deployment insight (section 4) is that the fast Winograd /
Cook-Toom scheme only pays off once the GEMM phase amortizes the transform
phases -- and that the *filter* transform should never be on the inference
path at all: weights are transformed once, offline, and reused every step.

This module is that insight as an architecture:

  * `plan_conv2d(x_shape, w, ...)` makes every per-layer decision exactly
    once -- algorithm choice, CookToom pair, output tile, padding amounts,
    tile counts, Pallas block sizes -- and pre-transforms the filter into the
    execution domain (Winograd domain for the fast scheme, the flattened
    GEMM matrix for im2row).
  * `ConvPlan.apply(x)` executes with zero per-call filter or geometry work.
  * A process-level spec cache keyed on (shapes, dtype, stride, padding,
    algorithm, output tile) means repeated planning of the same layer shape
    is a dict hit; the cached spec carries the algorithm decision, so a
    measured `auto_tuned` choice is made once per shape per process.
  * `algorithm="auto_tuned"` is *plan-time measured autotuning*: both
    schemes are timed on the real layer shape and the winner is cached.
    The static amortization constants remain only as the fallback policy
    when measurement is impossible (planning inside a jit trace).

`core.dispatch.conv2d` / `conv1d` stay as thin per-call wrappers over this
module for backward compatibility; model code (models/cnn.py, models/audio.py)
builds plans at init/weight-load time and executes them.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import im2col as _im2col
from repro.core import winograd as _wg
from repro.core.transforms import DEFAULT_OUTPUT_TILE, CookToom, cook_toom
# Shared epilogue vocabulary, dependency-free (the heavy Pallas kernels in
# repro.kernels stay optional, imported locally where needed).
# EPILOGUE_ACTIVATIONS: the activations plan.apply(..., activation=) accepts
# (kernels/runtime.py is the single source of truth): the Pallas executors
# fuse these into the kernel store, the pure-JAX executors apply them as one
# XLA op (_epilogue_jnp).
from repro.kernels.runtime import ACTIVATIONS as EPILOGUE_ACTIVATIONS
from repro.kernels.runtime import epilogue_jnp as _epilogue_jnp

Algorithm = Literal["auto", "auto_tuned", "winograd", "im2col",
                    "pallas_winograd", "pallas_winograd_materialized",
                    "pallas_im2col"]
Padding = _wg.Padding

#: Filter sizes the paper's fast scheme covers (2D NxN and 1D 1xN / Nx1).
WINOGRAD_FILTER_SIZES = frozenset({2, 3, 4, 5, 7})

#: auto_tuned *fallback* crossover, used only when plan-time measurement is
#: impossible (planning under an active jit trace, or REPRO_PLAN_NO_MEASURE
#: set): winograd wins when the per-point GEMMs are large enough to amortize
#: the transform passes -- which needs BOTH enough regions (output pixels)
#: and enough channel depth (the GEMM's contraction dim). Calibrated on the
#: measured per-layer sweep (results/bench_per_layer.json; EXPERIMENTS.md
#: section Perf). The primary auto_tuned policy is the measured one below
#: (_measure_autotune): time both schemes on the real shape, cache the winner.
AMORTIZE_MIN_OUT_PIXELS = 1156            # 34 x 34
AMORTIZE_MIN_C_IN = 64


def winograd_suitable(kh: int, kw: int, stride) -> bool:
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    if s != (1, 1):
        return False
    if kh == 1 and kw == 1:
        return False                      # 1x1 is already a pure GEMM
    for k in (kh, kw):
        if k != 1 and k not in WINOGRAD_FILTER_SIZES:
            return False
    return True


def winograd_amortizes(h: int, w: int, kh: int, kw: int, c_in: int,
                       padding: str = "SAME") -> bool:
    """The paper's section-4 amortization insight as a static predicate --
    the auto_tuned fallback when plan-time measurement is unavailable."""
    out_h = h if padding == "SAME" else h - kh + 1
    out_w = w if padding == "SAME" else w - kw + 1
    return (out_h * out_w >= AMORTIZE_MIN_OUT_PIXELS
            and c_in >= AMORTIZE_MIN_C_IN)


# ---------------------------------------------------------------------------
# Specs: the cacheable, weight-free part of a plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Everything about a planned conv layer except the weights: the resolved
    algorithm, transform variant, geometry, and kernel blocking. Hashable and
    shape-keyed, so it lives in the process-level plan cache."""

    x_shape: tuple[int, ...]          # (N, H, W, C) the plan was built for
    w_shape: tuple[int, ...]          # (kh, kw, C, M)
    dtype: str
    stride: tuple[int, int]
    padding: str
    requested: str                    # the algorithm= the caller asked for
    algorithm: str                    # resolved executor: winograd |
                                      # winograd_1d | im2col |
                                      # pallas_winograd |
                                      # pallas_winograd_materialized |
                                      # pallas_im2col
    output_tile: tuple[int, int] | None = None
    ct_h: CookToom | None = None
    ct_w: CookToom | None = None      # also the single CT of the 1D variant
    geometry: Any = None              # Conv2DGeometry | Axis1DGeometry |
                                      # Im2RowGeometry
    axis: int | None = None           # 1xN / Nx1: the non-unit spatial axis
    blocks: tuple[int, ...] | None = None        # Pallas block sizes
    stream: Any = None                # StreamGeometry (halo blocking) of the
                                      # streaming pallas_winograd executor
    autotune: tuple | None = None     # (("t_winograd_s", ...), ...) measured
                                      # evidence behind an auto_tuned choice

    @property
    def autotune_report(self) -> dict | None:
        return dict(self.autotune) if self.autotune is not None else None


# ---------------------------------------------------------------------------
# Process-level spec cache
# ---------------------------------------------------------------------------

_SPEC_CACHE: dict[tuple, ConvSpec] = {}
_CACHE_HITS = 0
_CACHE_MISSES = 0


def plan_cache_info() -> dict:
    """{'hits', 'misses', 'size'} of the process-level spec cache."""
    return {"hits": _CACHE_HITS, "misses": _CACHE_MISSES,
            "size": len(_SPEC_CACHE)}


def clear_plan_cache() -> None:
    global _CACHE_HITS, _CACHE_MISSES
    _SPEC_CACHE.clear()
    _CACHE_HITS = 0
    _CACHE_MISSES = 0


def _cache_enabled() -> bool:
    return not os.environ.get("REPRO_PLAN_NO_CACHE")


def _measure_allowed() -> bool:
    """Measured autotuning needs concrete execution: it is disabled inside an
    active jit trace and via REPRO_PLAN_NO_MEASURE."""
    if os.environ.get("REPRO_PLAN_NO_MEASURE"):
        return False
    return jax.core.trace_state_clean()


# ---------------------------------------------------------------------------
# Spec construction (all per-layer decisions happen here, once)
# ---------------------------------------------------------------------------

def _resolve_output_tile(kh: int, kw: int, output_tile) -> tuple[int, int]:
    if output_tile is None:
        mt = DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        return (mt, mt)
    if isinstance(output_tile, int):
        return (output_tile, output_tile)
    return tuple(output_tile)


def _build_spec(x_shape, w_shape, dtype, stride, padding, requested,
                resolved, output_tile) -> ConvSpec:
    """Materialize geometry/transform/blocking decisions for one resolved
    algorithm."""
    n, h, w, c = x_shape
    kh, kw, _, mout = w_shape
    base = dict(x_shape=tuple(x_shape), w_shape=tuple(w_shape), dtype=dtype,
                stride=stride, padding=padding, requested=requested)

    if resolved in ("winograd", "pallas_winograd",
                    "pallas_winograd_materialized") and (kh == 1 or kw == 1):
        # 1xN / Nx1: single-axis Cook-Toom (the Pallas backend also routes
        # here -- its GEMM is one matmul XLA already maps to the MXU).
        axis = 1 if kh > 1 else 2
        k = max(kh, kw)
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        m = (mh, mw)[axis - 1]
        ct = cook_toom(m, k)
        geom = _wg.conv1d_axis_geometry(x_shape[axis], axis, k, m, padding)
        return ConvSpec(algorithm="winograd_1d", output_tile=(m, m),
                        ct_w=ct, geometry=geom, axis=axis, **base)

    if resolved == "winograd":
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        return ConvSpec(algorithm="winograd", output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, **base)

    if resolved == "pallas_winograd":
        # Streaming executor: halo-blocking geometry (strip origins,
        # edge-block padding, VMEM budget -> block sizes) derived here, once.
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        stream = _wg.stream_geometry(geom.n_h, geom.n_w, c, mout, ct_h, ct_w)
        return ConvSpec(algorithm="pallas_winograd", output_tile=(mh, mw),
                        ct_h=ct_h, ct_w=ct_w, geometry=geom, stream=stream,
                        blocks=(stream.bh * stream.bw, stream.block_c,
                                stream.block_m), **base)

    if resolved == "pallas_winograd_materialized":
        from repro.kernels import ops  # local import: kernels are optional
        mh, mw = _resolve_output_tile(kh, kw, output_tile)
        ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
        geom = _wg.conv2d_geometry(h, w, kh, kw, mh, mw, padding)
        blocks = ops.winograd_blocks(n * geom.n_h * geom.n_w, c, mout)
        return ConvSpec(algorithm="pallas_winograd_materialized",
                        output_tile=(mh, mw), ct_h=ct_h, ct_w=ct_w,
                        geometry=geom, blocks=blocks, **base)

    if resolved == "im2col":
        geom = _im2col.im2row_geometry(h, w, kh, kw, stride, padding)
        return ConvSpec(algorithm="im2col", geometry=geom, **base)

    if resolved == "pallas_im2col":
        from repro.kernels import ops
        geom = _im2col.im2row_geometry(h, w, kh, kw, stride, padding)
        blocks = ops.im2col_blocks(n * geom.oh * geom.ow, kh * kw * c, mout)
        return ConvSpec(algorithm="pallas_im2col", geometry=geom,
                        blocks=blocks, **base)

    raise ValueError(f"unknown algorithm {resolved!r}")


def _bind_weights(spec: ConvSpec, w: jax.Array) -> jax.Array:
    """Transform the filter into the spec's execution domain. This is the
    once-per-plan weight work; ConvPlan.apply never touches it again."""
    kh, kw, c, mout = spec.w_shape
    if spec.algorithm == "winograd":
        return _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
    if spec.algorithm == "winograd_1d":
        return _wg.transform_filter_1d(w.reshape(max(kh, kw), c, mout),
                                       spec.ct_w)
    if spec.algorithm in ("pallas_winograd", "pallas_winograd_materialized"):
        from repro.kernels import ops
        u = _wg.transform_filter_2d(w, spec.ct_h, spec.ct_w)
        u = u.reshape(spec.ct_h.t * spec.ct_w.t, c, mout)
        return ops.pad_winograd_filter(u, spec.blocks[1], spec.blocks[2])
    if spec.algorithm == "im2col":
        return w.reshape(kh * kw * c, mout)
    if spec.algorithm == "pallas_im2col":
        from repro.kernels import ops
        return ops.pad_im2col_filter(w.reshape(kh * kw * c, mout),
                                     spec.blocks[1], spec.blocks[2])
    raise ValueError(spec.algorithm)


# ---------------------------------------------------------------------------
# ConvPlan: spec + weights in the execution domain
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """A fully-decided, weight-bound convolution. apply(x) does only input
    work: pad, tile, transform the input, GEMM against the cached filter,
    inverse-transform. No filter transform, no geometry derivation.

    apply(x, bias=..., activation=...) runs the layer epilogue too: on the
    Pallas executors (streaming Winograd, im2col GEMM) the bias add and
    activation are fused into the kernel's store step, so the conv output
    never round-trips HBM before the elementwise work; pure-JAX executors
    apply the same contract as one XLA op."""

    spec: ConvSpec
    u: jax.Array                       # filter in the execution domain
    build_time_s: float = 0.0
    precision: Any = None

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias: jax.Array | None = None,
              activation: str = "none") -> jax.Array:
        spec = self.spec
        if x.shape[1:] != spec.x_shape[1:]:
            raise ValueError(
                f"plan built for input {spec.x_shape} got {x.shape} "
                f"(batch may differ; H/W/C must match)")
        if activation not in EPILOGUE_ACTIVATIONS:
            raise ValueError(f"unknown activation {activation!r}; "
                             f"expected one of {EPILOGUE_ACTIVATIONS}")
        alg = spec.algorithm
        if alg == "winograd":
            y = _wg.winograd_conv2d_pretransformed(
                x, self.u, spec.ct_h, spec.ct_w, padding=spec.padding,
                geometry=spec.geometry, precision=self.precision)
            return _epilogue_jnp(y, bias, activation)
        if alg == "winograd_1d":
            y = _wg.winograd_conv1d_axis_pretransformed(
                x, self.u, spec.ct_w, spec.geometry, precision=self.precision)
            return _epilogue_jnp(y, bias, activation)
        if alg == "im2col":
            geom = spec.geometry
            kh, kw, _, mout = spec.w_shape
            a, _ = _im2col.im2row(x, kh, kw, spec.stride, spec.padding, geom)
            y = jnp.matmul(a, self.u, precision=self.precision,
                           preferred_element_type=jnp.float32)
            y = y.reshape(x.shape[0], geom.oh, geom.ow, mout).astype(x.dtype)
            return _epilogue_jnp(y, bias, activation)
        if alg == "pallas_winograd":
            from repro.kernels import ops
            return ops.winograd_conv2d_planned(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, stream=spec.stream,
                c_out=spec.w_shape[3], bias=bias, activation=activation)
        if alg == "pallas_winograd_materialized":
            from repro.kernels import ops
            _, _, c, mout = spec.w_shape
            y = ops.winograd_conv2d_planned_materialized(
                x, self.u, ct_h=spec.ct_h, ct_w=spec.ct_w,
                geometry=spec.geometry, blocks=spec.blocks, c_in=c,
                c_out=mout)
            return _epilogue_jnp(y, bias, activation)
        if alg == "pallas_im2col":
            from repro.kernels import ops
            kh, kw, _, mout = spec.w_shape
            return ops.im2col_conv2d_planned(
                x, self.u, kh=kh, kw=kw, stride=spec.stride,
                padding=spec.padding, geometry=spec.geometry,
                blocks=spec.blocks, c_out=mout, bias=bias,
                activation=activation)
        raise ValueError(alg)

    @property
    def algorithm(self) -> str:
        return self.spec.algorithm

    @property
    def out_shape(self) -> tuple[int, ...]:
        spec, g = self.spec, self.spec.geometry
        mout = spec.w_shape[-1]
        n = spec.x_shape[0]
        if spec.algorithm in ("winograd", "pallas_winograd",
                              "pallas_winograd_materialized"):
            return (n, g.out_h, g.out_w, mout)
        if spec.algorithm == "winograd_1d":
            h, w = spec.x_shape[1:3]
            return ((n, g.out_size, w, mout) if g.axis == 1
                    else (n, h, g.out_size, mout))
        return (n, g.oh, g.ow, mout)


# ---------------------------------------------------------------------------
# Plan-time measured autotuning (algorithm="auto_tuned")
# ---------------------------------------------------------------------------

def _time_apply(plan: ConvPlan, x, warmup: int = 1, iters: int = 3) -> float:
    fn = jax.jit(plan.apply)
    for _ in range(warmup):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_autotune(x_shape, w_shape, dtype, stride, padding,
                      output_tile) -> tuple[str, tuple]:
    """Time winograd vs im2col on the real shape; return (winner, evidence).
    Runs once per shape per process (the spec cache holds the result)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(x_shape), dtype)
    w = jnp.asarray(rng.standard_normal(w_shape)
                    / (w_shape[0] * w_shape[1]), dtype)
    times = {}
    for alg in ("winograd", "im2col"):
        spec = _build_spec(x_shape, w_shape, str(jnp.dtype(dtype)), stride,
                           padding, alg, alg, output_tile)
        times[alg] = _time_apply(ConvPlan(spec=spec, u=_bind_weights(spec, w)),
                                 x)
    winner = min(times, key=times.get)
    evidence = (("t_winograd_s", times["winograd"]),
                ("t_im2col_s", times["im2col"]), ("winner", winner))
    return winner, evidence


# ---------------------------------------------------------------------------
# plan_conv2d: the public entry point
# ---------------------------------------------------------------------------

def plan_conv2d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | tuple[int, int] | None = None,
    precision=None,
    dtype=None,
) -> ConvPlan:
    """Build a ConvPlan for a (N, H, W, C) x (kh, kw, C, M) convolution.

    All per-layer decisions (algorithm, transform variant, padding/tiling
    geometry, Pallas blocking) are made here, once; the filter is transformed
    into the execution domain, once. Decisions are cached process-wide keyed
    on (shapes, dtype, stride, padding, algorithm, output_tile), so repeated
    planning of the same layer shape -- including a measured auto_tuned
    choice -- is a dict lookup plus one filter transform.
    """
    global _CACHE_HITS, _CACHE_MISSES
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    w_shape = tuple(w.shape)
    if len(x_shape) != 4 or len(w_shape) != 4:
        raise ValueError(f"expected NHWC x HWIO, got {x_shape} x {w_shape}")
    if x_shape[3] != w_shape[2]:
        raise ValueError(f"channel mismatch: input {x_shape} filter {w_shape}")
    stride = (stride, stride) if isinstance(stride, int) else tuple(stride)
    dtype = dtype or w.dtype
    dtype_str = str(jnp.dtype(dtype))
    kh, kw = w_shape[:2]
    n, h, wdt, c = x_shape

    key = (x_shape, w_shape, dtype_str, stride, padding, algorithm,
           output_tile if not isinstance(output_tile, list) else
           tuple(output_tile), precision)
    spec = _SPEC_CACHE.get(key) if _cache_enabled() else None
    if spec is not None:
        _CACHE_HITS += 1
    else:
        _CACHE_MISSES += 1
        suitable = winograd_suitable(kh, kw, stride)
        autotune = None
        if algorithm == "auto":
            resolved = "winograd" if suitable else "im2col"
        elif algorithm == "auto_tuned":
            if not suitable:
                resolved = "im2col"
            elif _measure_allowed():
                resolved, autotune = _measure_autotune(
                    x_shape, w_shape, dtype_str, stride, padding, output_tile)
            else:
                resolved = "winograd" if winograd_amortizes(
                    h, wdt, kh, kw, c, padding) else "im2col"
        else:
            resolved = algorithm
            if resolved in ("winograd", "pallas_winograd",
                            "pallas_winograd_materialized") and not suitable:
                raise ValueError(
                    f"winograd requested for unsuitable layer "
                    f"k=({kh},{kw}) stride={stride}")
        spec = _build_spec(x_shape, w_shape, dtype_str, stride, padding,
                           algorithm, resolved, output_tile)
        if autotune is not None:
            spec = dataclasses.replace(spec, autotune=autotune)
        # An auto_tuned decision made via the heuristic fallback (planning
        # under a jit trace) must not be cached: a later eager plan of the
        # same shape should still get to measure. Only measured decisions
        # (and the deterministic unsuitable->im2col case) are durable.
        durable = (algorithm != "auto_tuned" or autotune is not None
                   or not suitable)
        if _cache_enabled() and durable:
            _SPEC_CACHE[key] = spec

    u = _bind_weights(spec, w)
    return ConvPlan(spec=spec, u=u, precision=precision,
                    build_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# conv1d plans (sequence convolutions, incl. polyphase stride > 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Conv1DPlan:
    """Planned (B, L, C) x (k, C, M) -> (B, L', M) sequence convolution.

    mode "as2d": stride-1, executed through a 2D plan on (B, L, 1, C).
    mode "polyphase": stride > 1 decomposed into stride-1 Cook-Toom
      sub-convolutions (sub-filter w[p::s] over sub-sequence x[p::s]), each
      planned independently; geometry (padding, output length) precomputed.
    mode "im2col": strided baseline through a 2D im2col plan.
    """

    x_shape: tuple[int, ...]
    w_shape: tuple[int, ...]
    stride: int
    padding: str
    requested: str
    mode: str
    inner: ConvPlan | None = None
    subplans: tuple[ConvPlan, ...] = ()
    pad: tuple[int, int] = (0, 0)
    out_len: int = 0
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array, **kwargs) -> jax.Array:
        return self.apply(x, **kwargs)

    def apply(self, x: jax.Array, bias: jax.Array | None = None,
              activation: str = "none") -> jax.Array:
        if self.mode in ("as2d", "im2col"):
            return self.inner.apply(x[:, :, None, :], bias=bias,
                                    activation=activation)[:, :, 0, :]
        # polyphase: y[i] = sum_p (w[p::s] (*) x[p::s])[i]. The epilogue can
        # only run after the cross-phase sum, so it stays an XLA op here.
        s = self.stride
        x = jnp.pad(x, ((0, 0), self.pad, (0, 0)))
        acc = None
        for p, sub in enumerate(self.subplans):
            sub_x = x[:, p::s, None, :]
            y = sub.apply(sub_x)[:, :self.out_len, 0, :]
            acc = y if acc is None else acc + y
        return _epilogue_jnp(acc, bias, activation)


def plan_conv1d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    stride: int = 1,
    padding: Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | None = None,
) -> Conv1DPlan:
    """Plan a (B, L, C) x (k, C, M) sequence convolution (see Conv1DPlan)."""
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    b, length, c = x_shape
    k, _, m = w.shape
    base = dict(x_shape=x_shape, w_shape=tuple(w.shape), stride=stride,
                padding=padding, requested=algorithm)
    if stride == 1:
        inner = plan_conv2d((b, length, 1, c), w[:, None, :, :], stride=1,
                            padding=padding, algorithm=algorithm,
                            output_tile=output_tile)
        return Conv1DPlan(mode="as2d", inner=inner,
                          build_time_s=time.perf_counter() - t0, **base)

    if algorithm in ("winograd", "auto") and k > stride:
        if padding == "SAME":
            out = -(-length // stride)
            total = max((out - 1) * stride + k - length, 0)
            pad = (total // 2, total - total // 2)
        else:
            out = (length - k) // stride + 1
            pad = (0, 0)
        padded = length + pad[0] + pad[1]
        subplans = []
        for p in range(stride):
            sub_w = w[p::stride]                    # (ceil((k-p)/s), C, M)
            sub_len = -(-(padded - p) // stride)
            subplans.append(plan_conv2d(
                (b, sub_len, 1, c), sub_w[:, None, :, :], stride=1,
                padding="VALID", algorithm="auto", output_tile=output_tile))
        return Conv1DPlan(mode="polyphase", subplans=tuple(subplans),
                          pad=pad, out_len=out,
                          build_time_s=time.perf_counter() - t0, **base)

    inner = plan_conv2d((b, length, 1, c), w[:, None, :, :],
                        stride=(stride, 1), padding=padding,
                        algorithm="im2col")
    return Conv1DPlan(mode="im2col", inner=inner,
                      build_time_s=time.perf_counter() - t0, **base)


# ---------------------------------------------------------------------------
# Depthwise causal Cook-Toom conv1d plans (Mamba's short conv)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DepthwiseConv1DSpec:
    """Cacheable decisions of a planned (B, L, C) x (r, C) causal depthwise
    Cook-Toom convolution: the F(m, r) transform set, tile count, padding and
    kernel blocking -- everything the unplanned path re-derived per call."""

    x_shape: tuple[int, ...]          # (B, L, C) the plan was built for
    w_shape: tuple[int, ...]          # (r, C)
    dtype: str
    output_tile: int
    backend: str                      # "jnp" | "pallas"
    ct: CookToom = None
    n_tiles: int = 0
    pad_hi: int = 0                   # right pad so tiles cover n_tiles * m
    blocks: tuple[int, int] | None = None   # (block_s, block_c), pallas only


@dataclasses.dataclass(frozen=True)
class DepthwiseConv1DPlan:
    """Spec + taps in the Cook-Toom domain. apply(x) performs no cook_toom
    construction, tile-count or padding derivation -- only the input work."""

    spec: DepthwiseConv1DSpec
    u: jax.Array                      # (t, C) (jnp) / (t, Cp) (pallas) taps
    build_time_s: float = 0.0

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)

    def apply(self, x: jax.Array) -> jax.Array:
        spec = self.spec
        if x.shape[1:] != spec.x_shape[1:]:
            raise ValueError(
                f"plan built for input {spec.x_shape} got {x.shape} "
                f"(batch may differ; L/C must match)")
        if spec.backend == "pallas":
            from repro.kernels import ops
            return ops.ct_depthwise_causal_conv1d_planned(
                x, self.u, ct=spec.ct, n_tiles=spec.n_tiles,
                pad_hi=spec.pad_hi, blocks=spec.blocks,
                c_in=spec.w_shape[1])
        return _wg.ct_depthwise_causal_conv1d_pretransformed(
            x, self.u, spec.ct, n_tiles=spec.n_tiles, pad_hi=spec.pad_hi)


def plan_depthwise_conv1d(
    x_shape: tuple[int, ...],
    w: jax.Array,
    *,
    output_tile: int = 4,
    backend: str = "jnp",
    dtype=None,
) -> DepthwiseConv1DPlan:
    """Plan a causal depthwise Cook-Toom conv (B, L, C) x (r, C) -> (B, L, C).

    Decisions (cook_toom transform set, tile count, padding, Pallas blocking)
    are made once and cached process-wide keyed on (shape, dtype, output
    tile, backend); the taps are transformed into the Cook-Toom domain here.
    models/mamba.py routes its short conv through this, so the hot path does
    only input work per call.
    """
    global _CACHE_HITS, _CACHE_MISSES
    t0 = time.perf_counter()
    x_shape = tuple(x_shape)
    if len(x_shape) != 3 or len(w.shape) != 2 or x_shape[2] != w.shape[1]:
        raise ValueError(f"expected (B, L, C) x (r, C), got "
                         f"{x_shape} x {tuple(w.shape)}")
    r, c = w.shape
    length = x_shape[1]
    dtype_str = str(jnp.dtype(dtype or w.dtype))
    key = ("dwconv1d", x_shape, tuple(w.shape), dtype_str, output_tile,
           backend)
    spec = _SPEC_CACHE.get(key) if _cache_enabled() else None
    if spec is not None:
        _CACHE_HITS += 1
    else:
        _CACHE_MISSES += 1
        ct = cook_toom(output_tile, r)
        nt = -(-length // ct.m)
        blocks = None
        if backend == "pallas":
            from repro.kernels import ops
            blocks = ops.conv1d_ct_blocks(nt, c)
        elif backend != "jnp":
            raise ValueError(f"unknown backend {backend!r}")
        spec = DepthwiseConv1DSpec(
            x_shape=x_shape, w_shape=tuple(w.shape), dtype=dtype_str,
            output_tile=output_tile, backend=backend, ct=ct, n_tiles=nt,
            pad_hi=nt * ct.m - length, blocks=blocks)
        if _cache_enabled():
            _SPEC_CACHE[key] = spec

    u = jnp.einsum("ij,jc->ic", jnp.asarray(spec.ct.G, w.dtype), w)  # (t, C)
    if spec.backend == "pallas":
        bc = spec.blocks[1]
        pad_c = -(-c // bc) * bc - c
        if pad_c:
            u = jnp.pad(u, ((0, 0), (0, pad_c)))
    return DepthwiseConv1DPlan(spec=spec, u=u,
                               build_time_s=time.perf_counter() - t0)
