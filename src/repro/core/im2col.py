"""im2row/im2col + GEMM convolution -- the paper's baseline comparator.

The paper benchmarks its region-wise multi-channel Winograd scheme against
"aggressively optimized" im2row lowering: patches are linearized into rows of
an [OHW x khkwC] matrix and multiplied with the [khkwC x M] filter matrix.
We implement the same lowering in JAX (NHWC / row-major => im2row); the
Pallas counterpart is the blocked GEMM path in kernels/ops.py
(im2col_conv2d_planned over kernels/matmul.py).

The patch matrix is a read-amplified copy of the input: each input element
appears in up to kh*kw/(sh*sw) patch rows (9/4 = 2.25x for a 3x3 stride-2
layer), which is exactly the HBM traffic the streaming Winograd executors
avoid -- see read_amplification() and the bytes models in
benchmarks/common.py.
"""

from __future__ import annotations

from typing import Literal, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Padding = Literal["SAME", "VALID"]


def _same_pads(size: int, k: int, stride: int) -> tuple[int, int]:
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2


class Im2RowGeometry(NamedTuple):
    """Static padding/output geometry of one im2row lowering -- derived once
    at plan time (core/plan.py) so the hot path skips the derivation."""

    ph: tuple[int, int]
    pw: tuple[int, int]
    oh: int
    ow: int


def im2row_geometry(h: int, w: int, kh: int, kw: int,
                    stride: tuple[int, int], padding: Padding) -> Im2RowGeometry:
    sh, sw = stride
    ph = _same_pads(h, kh, sh) if padding == "SAME" else (0, 0)
    pw = _same_pads(w, kw, sw) if padding == "SAME" else (0, 0)
    hp, wp = h + ph[0] + ph[1], w + pw[0] + pw[1]
    return Im2RowGeometry(ph, pw, (hp - kh) // sh + 1, (wp - kw) // sw + 1)


def _patches(x: jax.Array, kh: int, kw: int, stride: tuple[int, int],
             padding: Padding, geometry: Im2RowGeometry | None
             ) -> tuple[jax.Array, tuple[int, int]]:
    """(N, H, W, C) -> ((N, OH, OW, kh*kw, C), (OH, OW)) patch extraction
    shared by the dense and grouped im2row lowerings."""
    n, h, w, c = x.shape
    sh, sw = stride
    if geometry is None:
        geometry = im2row_geometry(h, w, kh, kw, stride, padding)
    ph, pw, oh, ow = geometry
    if any(ph) or any(pw):
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
        h, w = x.shape[1], x.shape[2]
    # static gather of patch rows; under jit this lowers to slices/concats.
    rows = []
    for di in range(kh):
        for dj in range(kw):
            rows.append(
                jax.lax.slice(x, (0, di, dj, 0),
                              (n, di + (oh - 1) * sh + 1, dj + (ow - 1) * sw + 1, c),
                              (1, sh, sw, 1)))
    return jnp.stack(rows, axis=3), (oh, ow)          # (N, OH, OW, khkw, C)


def read_amplification(kh: int, kw: int, stride: tuple[int, int]) -> float:
    """How many times the im2row lowering copies each input element into the
    patch matrix (the kernel-window overlap factor at this stride)."""
    sh, sw = stride
    return (kh * kw) / (sh * sw)


def im2row(x: jax.Array, kh: int, kw: int, stride: tuple[int, int],
           padding: Padding, geometry: Im2RowGeometry | None = None
           ) -> tuple[jax.Array, tuple[int, int]]:
    """(N, H, W, C) -> ((N * OH * OW, kh * kw * C), (OH, OW))."""
    n, _, _, c = x.shape
    patches, (oh, ow) = _patches(x, kh, kw, stride, padding, geometry)
    return patches.reshape(n * oh * ow, kh * kw * c), (oh, ow)


def grouped_im2row(x: jax.Array, kh: int, kw: int, stride: tuple[int, int],
                   padding: Padding, groups: int,
                   geometry: Im2RowGeometry | None = None
                   ) -> tuple[jax.Array, tuple[int, int]]:
    """Grouped im2row lowering: per-group patch rows.

    (N, H, W, C) -> ((N * OH * OW, G, kh * kw * C/G), (OH, OW)); each row
    group g multiplies only its own (kh*kw*Cg, Mg) filter block -- the
    block-diagonal structure of a grouped conv never materializes the zero
    blocks a dense [khkwC x M] lowering would carry.
    """
    n, _, _, c = x.shape
    cg = c // groups
    patches, (oh, ow) = _patches(x, kh, kw, stride, padding, geometry)
    patches = patches.reshape(n * oh * ow, kh * kw, groups, cg)
    return (patches.transpose(0, 2, 1, 3).reshape(
        n * oh * ow, groups, kh * kw * cg), (oh, ow))


def grouped_filter_matrix(w: jax.Array, groups: int) -> jax.Array:
    """(kh, kw, Cg, M) HWIO grouped filter -> (G, kh*kw*Cg, Mg) per-group
    GEMM matrices (group-major on the output axis, matching
    feature_group_count). Plan-time: done once per plan."""
    kh, kw, cg, m = w.shape
    mg = m // groups
    return (w.reshape(kh * kw, cg, groups, mg)
            .transpose(2, 0, 1, 3).reshape(groups, kh * kw * cg, mg))


def im2col_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: Padding = "SAME",
    groups: int = 1,
    geometry: Im2RowGeometry | None = None,
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """Baseline convolution: im2row lowering + GEMM (per-group for
    groups > 1, covering grouped and depthwise layers).

    Args:
      x: (N, H, W, C) NHWC.
      w: (kh, kw, C/groups, M) HWIO; M % groups == 0.
    """
    n = x.shape[0]
    kh, kw, _, m = w.shape
    stride = (stride, stride) if isinstance(stride, int) else stride
    if groups == 1:
        a, (oh, ow) = im2row(x, kh, kw, stride, padding, geometry)
        b = w.reshape(kh * kw * x.shape[3], m)
        y = jnp.matmul(a, b, precision=precision,
                       preferred_element_type=preferred_element_type)
    else:
        a, (oh, ow) = grouped_im2row(x, kh, kw, stride, padding, groups,
                                     geometry)
        b = grouped_filter_matrix(w, groups)
        y = jnp.einsum("rgk,gkm->rgm", a, b, precision=precision,
                       preferred_element_type=preferred_element_type)
    return y.reshape(n, oh, ow, m).astype(x.dtype)


def direct_conv2d(x: jax.Array, w: jax.Array, *, stride=1,
                  padding: Padding = "SAME", groups: int = 1) -> jax.Array:
    """lax.conv_general_dilated oracle (testing only)."""
    stride = (stride, stride) if isinstance(stride, int) else stride
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
