"""Per-layer convolution algorithm selection.

The paper runs its region-wise multi-channel Winograd scheme on "suitable"
layers (stride-1 NxN / 1xN / Nx1 with N in {3, 5, 7}) and the im2row baseline
everywhere else; whole-network numbers mix the two. `conv2d` reproduces that
dispatch, and is the single convolution entry point used by the model zoo.

`algorithm=`:
  * "auto"       -- the paper's policy (winograd where suitable, else im2col).
  * "auto_tuned" -- beyond-paper: the paper's section-4 amortization insight
                    turned into a dispatch rule. The paper observes achieved
                    speedup only approaches the theoretical bound once the
                    GEMM phase amortizes the transform phase; on layers too
                    small to amortize, the fast scheme *loses* to one big
                    im2row GEMM. auto_tuned picks winograd only when the
                    measured crossover predicts a win (EXPERIMENTS.md
                    section Perf documents the calibration).
  * "winograd"   -- force the fast scheme (raises if unsuitable).
  * "im2col"     -- force the baseline (for the paper's A/B benchmarks).
  * "pallas_*"   -- the hand-tiled TPU kernels (see repro.kernels.ops).
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import im2col as _im2col
from repro.core import winograd as _winograd
from repro.core.transforms import DEFAULT_OUTPUT_TILE

Algorithm = Literal["auto", "auto_tuned", "winograd", "im2col",
                    "pallas_winograd", "pallas_im2col"]

#: Filter sizes the paper's fast scheme covers (2D NxN and 1D 1xN / Nx1).
WINOGRAD_FILTER_SIZES = frozenset({2, 3, 4, 5, 7})

#: auto_tuned crossover: winograd wins on this backend when the per-point
#: GEMMs are large enough to amortize the transform passes -- which needs
#: BOTH enough regions (output pixels) and enough channel depth (the GEMM's
#: contraction dim). Calibrated on the measured per-layer sweep
#: (results/bench_per_layer.json; EXPERIMENTS.md section Perf): wins are
#: {224^2 x 64: 2.05, 112^2 x 64..128: 1.6, 56^2 x 128..256: 1.2,
#: 35^2 x 64..96: 1.15}; losses are every c_in < 64 layer (0.2-0.6x) and
#: every sub-34^2 layer (0.3-0.6x).
AMORTIZE_MIN_OUT_PIXELS = 1156            # 34 x 34
AMORTIZE_MIN_C_IN = 64


def winograd_suitable(kh: int, kw: int, stride) -> bool:
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    if s != (1, 1):
        return False
    if kh == 1 and kw == 1:
        return False                      # 1x1 is already a pure GEMM
    for k in (kh, kw):
        if k != 1 and k not in WINOGRAD_FILTER_SIZES:
            return False
    return True


def winograd_amortizes(h: int, w: int, kh: int, kw: int, c_in: int,
                       padding: str = "SAME") -> bool:
    """The paper's section-4 amortization insight as a dispatch predicate:
    is the layer big enough that the GEMM phase amortizes the transforms?"""
    out_h = h if padding == "SAME" else h - kh + 1
    out_w = w if padding == "SAME" else w - kw + 1
    return (out_h * out_w >= AMORTIZE_MIN_OUT_PIXELS
            and c_in >= AMORTIZE_MIN_C_IN)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: _winograd.Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | None = None,
    precision=None,
) -> jax.Array:
    """Unified convolution entry point (NHWC x HWIO -> NHWC)."""
    kh, kw, _, _ = w.shape
    suitable = winograd_suitable(kh, kw, stride)
    if algorithm == "auto":
        algorithm = "winograd" if suitable else "im2col"
    elif algorithm == "auto_tuned":
        algorithm = "winograd" if (
            suitable and winograd_amortizes(x.shape[1], x.shape[2], kh, kw,
                                            x.shape[3], padding)) else "im2col"
    if algorithm in ("winograd", "pallas_winograd") and not suitable:
        raise ValueError(
            f"winograd requested for unsuitable layer k=({kh},{kw}) stride={stride}")

    if algorithm == "winograd":
        mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
        return _winograd.winograd_conv2d(
            x, w, output_tile=mt, padding=padding, precision=precision)
    if algorithm == "im2col":
        return _im2col.im2col_conv2d(
            x, w, stride=stride, padding=padding, precision=precision)
    if algorithm in ("pallas_winograd", "pallas_im2col"):
        from repro.kernels import ops  # local import: kernels are optional
        if algorithm == "pallas_winograd":
            mt = output_tile or DEFAULT_OUTPUT_TILE.get(max(kh, kw), 2)
            return ops.winograd_conv2d(x, w, output_tile=mt, padding=padding)
        return ops.im2col_conv2d(x, w, stride=stride, padding=padding)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def conv1d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: _winograd.Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | None = None,
) -> jax.Array:
    """Sequence convolution (B, L, C) x (k, C, M) -> (B, L', M).

    Stride > 1 is handled by polyphase decomposition into stride-1 Cook-Toom
    convolutions (sub-filter w[p::s] over sub-sequence x[p::s]) when the
    sub-filters stay suitable; otherwise falls back to im2col. This covers the
    Whisper conv stem (k=3, strides 1 and 2).
    """
    k, c, m = w.shape
    if stride == 1:
        x4 = x[:, :, None, :]                       # (B, L, 1, C)
        w4 = w[:, None, :, :]                       # (k, 1, C, M)
        y = conv2d(x4, w4, stride=1, padding=padding,
                   algorithm=algorithm, output_tile=output_tile)
        return y[:, :, 0, :]

    if algorithm in ("winograd", "auto") and k > stride:
        # polyphase: y[i] = sum_p (w[p::s] (*) x[p::s])[i]
        b, length, _ = x.shape
        if padding == "SAME":
            out = -(-length // stride)
            total = max((out - 1) * stride + k - length, 0)
            x = jnp.pad(x, ((0, 0), (total // 2, total - total // 2), (0, 0)))
        else:
            out = (length - k) // stride + 1
        acc = None
        for p in range(stride):
            sub_w = w[p::stride]                    # (ceil((k-p)/s), C, M)
            sub_x = x[:, p::stride]
            y = conv1d(sub_x, sub_w, stride=1, padding="VALID",
                       algorithm="auto", output_tile=output_tile)[:, :out]
            acc = y if acc is None else acc + y
        return acc

    x4 = x[:, :, None, :]
    w4 = w[:, None, :, :]
    y = _im2col.im2col_conv2d(x4, w4, stride=(stride, 1), padding=padding)
    return y[:, :, 0, :]
