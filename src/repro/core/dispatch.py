"""Per-layer convolution algorithm selection -- thin wrappers over plans.

The paper runs its region-wise multi-channel Winograd scheme on "suitable"
layers (stride-1 NxN / 1xN / Nx1 with N in {3, 5, 7}) and the im2row baseline
everywhere else; whole-network numbers mix the two. `conv2d` reproduces that
dispatch and stays the single convolution entry point for ad-hoc callers,
but since the plan/execute split it is a compatibility wrapper: each call
builds (or cache-hits) a ConvPlan via repro.core.plan and applies it.
Callers that run the same layer many times should plan once at init /
weight-load time and call `plan.apply(x)` directly -- that path performs no
per-call filter transform or geometry derivation. Whole networks should go
one level higher: repro.core.compile.compile (re-exported here as
`compile_network`) lowers a model description to the layer IR, runs the
fusion/placement passes, and returns a serializable NetworkPlan
(models/cnn.py and models/audio.py route through it).

Which executor may run which layer is declared by the executors themselves
in the capability registry (repro.core.registry): every algorithm choice is
a registry query, and a request the registered executors cannot cover
raises an error enumerating the capabilities that DO match the layer.

`algorithm=` (the full requestable set is plan.ALGORITHMS; every resolver
error message lists it):
  * "auto"       -- the paper's policy (winograd where suitable, else im2col).
  * "auto_tuned" -- beyond-paper: the paper's section-4 amortization insight
                    as a *plan-time measured* policy. The paper observes
                    achieved speedup only approaches the theoretical bound
                    once the GEMM phase amortizes the transform phase; on
                    layers too small to amortize, the fast scheme *loses* to
                    one big im2row GEMM. auto_tuned times both schemes on
                    the real layer shape at plan time and caches the winner
                    process-wide; when measurement is impossible (planning
                    inside a jit trace) it falls back to the static
                    calibrated crossover (plan.winograd_amortizes).
  * "winograd"   -- force the fast scheme (raises if no capability matches);
                    with groups > 1 this resolves to the depthwise
                    (transform-domain Hadamard) or block-diagonal grouped
                    executor, and stride-2 layers resolve to the
                    transform-domain phase-decomposition executor.
  * "im2col"     -- force the baseline (for the paper's A/B benchmarks);
                    any stride/size/groups (grouped im2row for groups > 1).
  * "pallas_winograd" -- the streamed TPU kernel (repro.kernels.ops); with
                    groups == C_in this is the streamed depthwise kernel;
                    stride-2 layers run the strided streaming kernels.
  * "pallas_winograd_materialized" -- the pre-streaming tiles-domain Pallas
                    executor, kept as the A/B baseline for the streaming
                    path (dense only: groups == 1).
  * "pallas_im2col" -- the Pallas im2row GEMM baseline (dense only).
"""

from __future__ import annotations

import jax

from repro.core import winograd as _winograd
from repro.core.compile import NetworkPlan, compile as compile_network
from repro.core.plan import (ALGORITHMS, AMORTIZE_MIN_C_IN,
                             AMORTIZE_MIN_OUT_PIXELS, WINOGRAD_FILTER_SIZES,
                             Algorithm, algorithm_supported, plan_conv1d,
                             plan_conv2d, plan_depthwise_conv1d,
                             plan_separable_block, winograd_amortizes,
                             winograd_suitable)

__all__ = [
    "ALGORITHMS", "Algorithm", "NetworkPlan", "algorithm_supported",
    "compile_network", "conv1d", "conv2d", "plan_depthwise_conv1d",
    "plan_separable_block", "winograd_amortizes", "winograd_suitable",
    "WINOGRAD_FILTER_SIZES", "AMORTIZE_MIN_OUT_PIXELS", "AMORTIZE_MIN_C_IN",
]


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int | tuple[int, int] = 1,
    padding: _winograd.Padding = "SAME",
    algorithm: Algorithm = "auto",
    groups: int = 1,
    output_tile: int | None = None,
    precision=None,
    bias: jax.Array | None = None,
    activation: str = "none",
    data_format: str = "NHWC",
) -> jax.Array:
    """Unified convolution entry point (NHWC x HWIO -> NHWC).

    Compatibility wrapper: plans (cached by shape) then executes. The filter
    transform still happens on every call here -- hold a ConvPlan instead
    (repro.core.plan.plan_conv2d) to pre-transform weights once.
    `bias`/`activation` run the layer epilogue through the plan's fused path
    (in-kernel on the Pallas executors). `groups` is feature_group_count
    (C_in for a depthwise conv); the filter then carries C_in/groups input
    channels: (kh, kw, C_in/groups, M). `data_format="NCHW"` ingests NCHW
    inputs with an OIHW filter and returns NCHW output (the weight transpose
    happens at plan time, cache-keyed).
    """
    plan = plan_conv2d(x.shape, w, stride=stride, padding=padding,
                       algorithm=algorithm, groups=groups,
                       output_tile=output_tile, precision=precision,
                       data_format=data_format)
    return plan.apply(x, bias=bias, activation=activation)


def conv1d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: _winograd.Padding = "SAME",
    algorithm: Algorithm = "auto",
    output_tile: int | None = None,
) -> jax.Array:
    """Sequence convolution (B, L, C) x (k, C, M) -> (B, L', M).

    Stride > 1 is handled by polyphase decomposition into stride-1 Cook-Toom
    convolutions (sub-filter w[p::s] over sub-sequence x[p::s]) when the
    sub-filters stay suitable; otherwise falls back to im2col. This covers the
    Whisper conv stem (k=3, strides 1 and 2). Compatibility wrapper over
    repro.core.plan.plan_conv1d.
    """
    plan = plan_conv1d(x.shape, w, stride=stride, padding=padding,
                       algorithm=algorithm, output_tile=output_tile)
    return plan.apply(x)
