"""Region-wise multi-channel Winograd / Cook-Toom convolution (pure JAX).

This is the paper's core contribution expressed as a composable JAX module.
The three phases map 1:1 onto the paper's scheme (Fig. 2):

  1. *Input transform*: tile the NHWC input into overlapping t x t regions,
     apply B^T x B per region, and scatter the t^2 Winograd-domain points into
     a (P, R, C) tensor -- P = t^2 Winograd points, R = regions, C = channels.
     (The paper's "array of A matrices".)
  2. *GEMM*: P batched matmuls (P, R, C) x (P, C, M) -> (P, R, M). The
     channel-wise sum of Hadamard products becomes a matrix multiply over C --
     on TPU this feeds the MXU; the Pallas kernel in kernels/winograd.py is the
     hand-tiled version of exactly this einsum.
  3. *Output transform*: gather each region's P points, apply A^T (.) A, and
     write the m x m spatial outputs back into NHWC.

Layout note (paper section 2.1): NHWC keeps C innermost, so the transform
arithmetic -- which is a fixed pattern of adds/subs across the *tile* axes --
vectorizes over channels. On TPU the channel axis maps onto the 128-wide lane
dimension; all einsums below keep C/M innermost for that reason.

Stride-1 convolutions map onto the Winograd domain directly; stride-2
layers decompose into four stride-1 phase sub-convolutions whose sum also
happens in the transform domain (winograd_strided_conv2d_pretransformed
below -- the 2D analogue of the polyphase conv1d path). Anything else
falls back to im2row per the executor registry (core/registry.py), exactly
as the paper restricts the fast scheme to "suitable" layers.
"""

from __future__ import annotations

import functools
from typing import Literal, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import CookToom, cook_toom
# dependency-free shared blocking-granularity rule (repro.kernels stays an
# optional package; runtime.py imports nothing heavy)
from repro.kernels.runtime import pick_block as _stream_block

Padding = Literal["SAME", "VALID"]


# ---------------------------------------------------------------------------
# Filter transforms (done once per layer; weights are kept in the Winograd
# domain between steps, mirroring the paper's pre-transformed 'B' matrices).
# ---------------------------------------------------------------------------

def transform_filter_2d(w: jax.Array, ct_h: CookToom, ct_w: CookToom) -> jax.Array:
    """(kh, kw, C, M) -> (th, tw, C, M): G_h w G_w^T over the spatial axes."""
    g_h = jnp.asarray(ct_h.G, w.dtype)
    g_w = jnp.asarray(ct_w.G, w.dtype)
    return jnp.einsum("ij,jkcm,lk->ilcm", g_h, w, g_w)


def transform_filter_1d(w: jax.Array, ct: CookToom) -> jax.Array:
    """(k, C, M) -> (t, C, M)."""
    return jnp.einsum("ij,jcm->icm", jnp.asarray(ct.G, w.dtype), w)


# ---------------------------------------------------------------------------
# Tiling helpers
# ---------------------------------------------------------------------------

def _pad_amounts(size: int, k: int, m: int, padding: Padding) -> tuple[int, int, int]:
    """Return (lo, hi, n_tiles) padding for one spatial axis.

    The axis is padded so that (padded - k + 1) is a positive multiple of the
    output tile m; surplus outputs are cropped after the inverse transform.
    """
    if padding == "SAME":
        out = size
        lo = (k - 1) // 2
    else:
        out = size - k + 1
        lo = 0
    if out <= 0:
        raise ValueError(f"axis of size {size} too small for filter {k} ({padding})")
    n_tiles = -(-out // m)                      # ceil
    padded = n_tiles * m + k - 1
    hi = padded - size - lo
    return lo, hi, n_tiles


class Conv2DGeometry(NamedTuple):
    """Static tiling geometry of one (H, W) conv shape.

    Derived once at plan time (core/plan.py) and threaded through every
    execution so the hot path never re-derives padding or tile counts.
    """

    lo_h: int
    hi_h: int
    n_h: int          # tile count along H
    lo_w: int
    hi_w: int
    n_w: int          # tile count along W
    out_h: int
    out_w: int


def conv2d_geometry(h: int, w: int, kh: int, kw: int, mh: int, mw: int,
                    padding: Padding) -> Conv2DGeometry:
    """All padding/tiling decisions for an (H, W) layer, computed once."""
    lo_h, hi_h, nh = _pad_amounts(h, kh, mh, padding)
    lo_w, hi_w, nw = _pad_amounts(w, kw, mw, padding)
    out_h = h if padding == "SAME" else h - kh + 1
    out_w = w if padding == "SAME" else w - kw + 1
    return Conv2DGeometry(lo_h, hi_h, nh, lo_w, hi_w, nw, out_h, out_w)


def conv2d_fft_geometry(h: int, w: int, kh: int, kw: int, fft_h: int,
                        fft_w: int, padding: Padding) -> Conv2DGeometry:
    """Tiling geometry for the FFT executor (core/fft.py).

    The overlap tiling of the FFT path is the *same* scheme as Winograd's:
    a transform length t yields m = t - k + 1 valid outputs per tile, and
    consecutive tile origins advance by m. So the FFT geometry is exactly
    conv2d_geometry with the output tile set to fft - k + 1 per axis; the
    padded extent n_tiles * m + k - 1 matches the last tile's fft window and
    the surplus outputs are cropped after the inverse transform, identically
    to the Winograd path."""
    return conv2d_geometry(h, w, kh, kw, fft_h - kh + 1, fft_w - kw + 1,
                           padding)


def strided_out_size(size: int, k: int, padding: Padding) -> int:
    """Output extent of one stride-2 axis (lax conventions) -- the ONE place
    this formula lives; the strided geometry and the plan-time tile chooser
    (core/plan.py:_resolve_strided_tile) both consult it."""
    return -(-size // 2) if padding == "SAME" else (size - k) // 2 + 1


def _pad_amounts_strided(size: int, k: int, m: int,
                         padding: Padding) -> tuple[int, int, int, int]:
    """(lo, hi, n_tiles, out) padding for one stride-2 phase-decomposed axis.

    The axis is padded to 2*n_tiles*m + k - 1 elements so every phase
    sub-grid x[p::2] (p in {0, 1}) holds exactly n_tiles*m + r_ph - 1
    elements, r_ph = (k+1)//2 -- the length the stride-1 phase tiling needs
    to cover n_tiles*m outputs. lo follows lax's SAME convention for
    stride 2; surplus outputs are cropped after the inverse transform."""
    out = strided_out_size(size, k, padding)
    if padding == "SAME":
        total = max((out - 1) * 2 + k - size, 0)
        lo = total // 2
    else:
        lo = 0
    if out <= 0:
        raise ValueError(
            f"axis of size {size} too small for filter {k} stride 2 "
            f"({padding})")
    n_tiles = -(-out // m)
    padded = 2 * n_tiles * m + k - 1
    return lo, padded - size - lo, n_tiles, out


def conv2d_strided_geometry(h: int, w: int, kh: int, kw: int, mh: int,
                            mw: int, padding: Padding) -> Conv2DGeometry:
    """Padding/tiling decisions for a stride-2 phase-decomposed layer: same
    shape of record as the stride-1 geometry (tile counts n_h/n_w describe
    the phase sub-grids; lo/hi pad the full-resolution input)."""
    lo_h, hi_h, nh, out_h = _pad_amounts_strided(h, kh, mh, padding)
    lo_w, hi_w, nw, out_w = _pad_amounts_strided(w, kw, mw, padding)
    return Conv2DGeometry(lo_h, hi_h, nh, lo_w, hi_w, nw, out_h, out_w)


class StreamGeometry(NamedTuple):
    """Halo-blocking geometry for the region-streaming Pallas kernel
    (kernels/winograd.py:winograd_streamed), derived once at plan time.

    The kernel's grid walks (n_hb, n_wb) blocks of (bh, bw) output tiles;
    each grid cell reads one overlapping halo strip of the padded input
    (origin stride bh*mh / bw*mw, extent k-1 larger) and writes one
    non-overlapping (bh*mh, bw*mw) NHWC output block. Edge blocks are
    covered by padding the input up to n_hb*bh / n_wb*bw whole tile blocks
    (`pad_h` / `pad_w` extra rows/cols beyond the convolution padding);
    the surplus outputs are cropped after the kernel.
    """

    bh: int           # output-tile rows per grid cell
    bw: int           # output-tile cols per grid cell
    n_hb: int         # grid extent along H  (= ceil(n_h / bh))
    n_wb: int         # grid extent along W  (= ceil(n_w / bw))
    pad_h: int        # extra rows of input padding for edge blocks
    pad_w: int        # extra cols of input padding for edge blocks
    block_c: int      # Pallas channel block
    block_m: int      # Pallas output-channel block
    c_pad: int        # C rounded up to block_c
    m_pad: int        # M rounded up to block_m


#: Per-strip fixed cost in tile-equivalents for the stream_geometry score:
#: each (i, j) grid strip pays DMA setup / loop overhead on top of its
#: per-tile compute, so blockings that shatter the image into many small
#: strips lose to slightly-wasteful large strips.
_STRIP_OVERHEAD_TILES = 16


def stream_geometry(n_h: int, n_w: int, c: int, mout: int,
                    ct_h: CookToom, ct_w: CookToom, *,
                    phases: int = 1, input_stride: int = 1,
                    vmem_budget_bytes: int = 15 * 2 ** 20) -> StreamGeometry:
    """Choose the halo blocking for one layer, once, at plan time.

    Candidate (bh, bw) tile-block shapes are scored by estimated cost:
    padded tile count (edge-block compute waste) plus a fixed per-strip
    overhead term (many tiny strips lose), tie-broken toward larger region
    blocks (bigger point-GEMMs). Candidates that do not fit the VMEM budget
    (halo strip + filter block double-buffered, fp32 accumulator,
    transformed-input cache, transform transient, output block) are
    discarded.

    `phases`/`input_stride` describe the stride-2 phase-decomposition
    kernels: the halo strip spans `input_stride`x more input per axis and
    the Winograd-domain tensors (filter blocks, transformed-input cache)
    carry `phases` phase copies, so both scale the VMEM estimate.
    """
    th, tw, mh, mw = ct_h.t, ct_w.t, ct_h.m, ct_w.m
    p = th * tw
    c_ref = -(-c // _stream_block(c, 128)) * _stream_block(c, 128)
    m_ref = -(-mout // _stream_block(mout, 128)) * _stream_block(mout, 128)

    def tile_candidates(n_tiles: int) -> list[int]:
        cand = {b for b in (1, 2, 4, 8, 16) if b <= max(n_tiles, 1)}
        cand |= {b for b in range(1, 17) if n_tiles % b == 0}
        return sorted(cand)

    def chan_candidates(dim: int) -> list[int]:
        cand = {_stream_block(dim, 128)}
        if dim > 128:
            cand.add(256)               # fewer, fatter grid steps when it fits
        return sorted(cand)

    best = None
    for bc in chan_candidates(c):
        c_pad = -(-c // bc) * bc
        for bm in chan_candidates(mout):
            m_pad = -(-mout // bm) * bm
            n_cb, n_mb = c_pad // bc, m_pad // bm
            for bh in tile_candidates(n_h):
                for bw in tile_candidates(n_w):
                    n_hb, n_wb = -(-n_h // bh), -(-n_w // bw)
                    br = bh * bw
                    if br > 256:
                        continue
                    hs = input_stride * (bh * mh + th - mh)
                    ws = input_stride * (bw * mw + tw - mw)
                    pp = p * phases     # Winograd points across all phases
                    vmem = 4 * (2 * hs * ws * bc    # halo strip (x2 buffer)
                                + 2 * pp * bc * bm  # filter block (x2 buffer)
                                + p * br * bm       # fp32 accumulator
                                + pp * br * c_pad   # transformed-input cache
                                + pp * br * bc      # transform transient
                                + bh * mh * bw * mw * bm)   # output block
                    if vmem > vmem_budget_bytes:
                        continue
                    # work: padded tiles, scaled by any extra C/M padding
                    # this blocking forces; overhead: fixed cost per grid
                    # step (tiny steps lose to slightly-wasteful fat ones).
                    work = (n_hb * bh * n_wb * bw * c_pad * m_pad
                            / (c_ref * m_ref))
                    steps = n_hb * n_wb * n_cb * n_mb
                    score = (work + _STRIP_OVERHEAD_TILES * steps, -br, -bc)
                    if best is None or score < best[0]:
                        best = (score, (bh, bw, n_hb, n_wb, bc, bm,
                                        c_pad, m_pad))
    if best is None:
        raise ValueError(
            f"no halo blocking of the ({n_h}, {n_w})-tile grid (C={c}, "
            f"M={mout}, t=({ct_h.t}, {ct_w.t}), phases={phases}) fits the "
            f"{vmem_budget_bytes >> 20} MiB VMEM budget; use a smaller "
            f"output_tile")
    bh, bw, n_hb, n_wb, bc, bm, c_pad, m_pad = best[1]
    return StreamGeometry(bh=bh, bw=bw, n_hb=n_hb, n_wb=n_wb,
                          pad_h=(n_hb * bh - n_h) * mh,
                          pad_w=(n_wb * bw - n_w) * mw,
                          block_c=bc, block_m=bm, c_pad=c_pad, m_pad=m_pad)


def stream_geometry_depthwise(n_h: int, n_w: int, c: int,
                              ct_h: CookToom, ct_w: CookToom, *,
                              phases: int = 1, input_stride: int = 1,
                              mult: int = 1,
                              vmem_budget_bytes: int = 15 * 2 ** 20
                              ) -> StreamGeometry:
    """Halo blocking for the streamed depthwise kernel: reuse the dense
    chooser (same strip-origin / edge-padding / per-strip-overhead model;
    its dense VMEM estimate upper-bounds the depthwise kernel's working set,
    which has no filter blocks or cross-C accumulator) with the output
    channel axis collapsed onto the channel axis -- depthwise walks ONE
    channel axis, so block_m is pinned to block_c. A channel multiplier > 1
    widens the taps and output block by `mult`; folding it into the phase
    count keeps the VMEM estimate an upper bound without a second model."""
    g = stream_geometry(n_h, n_w, c, c, ct_h, ct_w,
                        phases=phases * mult,
                        input_stride=input_stride,
                        vmem_budget_bytes=vmem_budget_bytes)
    return g._replace(block_m=g.block_c, m_pad=g.c_pad)


class Axis1DGeometry(NamedTuple):
    """Static tiling geometry for the 1xN / Nx1 (single-axis) algorithm."""

    axis: int         # spatial axis the filter runs along (1 = H, 2 = W)
    lo: int
    hi: int
    n_t: int          # tile count along the axis
    out_size: int


def conv1d_axis_geometry(size: int, axis: int, k: int, m: int,
                         padding: Padding) -> Axis1DGeometry:
    lo, hi, nt = _pad_amounts(size, k, m, padding)
    out = size if padding == "SAME" else size - k + 1
    return Axis1DGeometry(axis, lo, hi, nt, out)


def _extract_tiles_1d(x: jax.Array, axis: int, t: int, m: int, n: int) -> jax.Array:
    """Slice axis of length n*m + t - m into n overlapping windows of length t.

    Output: the axis is replaced by two axes (n, t). Uses a gather with a
    static index map (cheap under jit; the Pallas kernel replaces this with a
    BlockSpec index_map so no materialized gather happens on TPU).
    """
    idx = (np.arange(n)[:, None] * m + np.arange(t)[None, :]).reshape(-1)
    out = jnp.take(x, jnp.asarray(idx), axis=axis)
    new_shape = x.shape[:axis] + (n, t) + x.shape[axis + 1:]
    return out.reshape(new_shape)


# ---------------------------------------------------------------------------
# 2D region-wise multi-channel convolution
# ---------------------------------------------------------------------------

def winograd_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    output_tile: int | tuple[int, int] = 4,
    padding: Padding = "SAME",
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """F(m x m, kh x kw) region-wise multi-channel convolution.

    Args:
      x: (N, H, W, C) input, NHWC.
      w: (kh, kw, C, M) filter, HWIO. kh/kw may be 1 (degenerates to the 1D
         row/column algorithm, the paper's 1xN / Nx1 case).
      output_tile: m (outputs per tile per axis). Axes with k == 1 use m = 1
         implicitly via F(m, 1) = identity-free passthrough handled by the 1D
         path below.
      padding: SAME or VALID; stride is always 1 (dispatcher enforces).

    Returns:
      (N, H', W', M) output in the same spatial convention as
      jax.lax.conv_general_dilated with the given padding.
    """
    kh, kw, c, mout = w.shape
    if kh == 1 or kw == 1:
        return _winograd_conv2d_1d_kernel(
            x, w, output_tile=output_tile, padding=padding,
            precision=precision, preferred_element_type=preferred_element_type)

    mh, mw = (output_tile, output_tile) if isinstance(output_tile, int) else output_tile
    ct_h, ct_w = cook_toom(mh, kh), cook_toom(mw, kw)
    u = transform_filter_2d(w, ct_h, ct_w)              # (th, tw, C, M)
    return winograd_conv2d_pretransformed(
        x, u, ct_h, ct_w, padding=padding, precision=precision,
        preferred_element_type=preferred_element_type)


def winograd_conv2d_pretransformed(
    x: jax.Array,
    u: jax.Array,
    ct_h: CookToom,
    ct_w: CookToom,
    *,
    padding: Padding = "SAME",
    geometry: Conv2DGeometry | None = None,
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """Same as winograd_conv2d but with the filter already in the Winograd
    domain -- the deployment path (weights transformed once, reused per step).
    Pass `geometry` (built once by conv2d_geometry / core.plan) to skip the
    per-call padding/tiling derivation entirely.
    """
    n, h, wdt, c = x.shape
    th, tw, _, mout = u.shape
    mh, mw, kh, kw = ct_h.m, ct_w.m, ct_h.r, ct_w.r

    if geometry is None:
        geometry = conv2d_geometry(h, wdt, kh, kw, mh, mw, padding)
    lo_h, hi_h, nh = geometry.lo_h, geometry.hi_h, geometry.n_h
    lo_w, hi_w, nw = geometry.lo_w, geometry.hi_w, geometry.n_w
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))

    # --- phase 1: tile + input transform + scatter -------------------------
    tiles = _extract_tiles_1d(xp, 1, th, mh, nh)        # (N, nh, th, Wp, C)
    tiles = _extract_tiles_1d(tiles, 3, tw, mw, nw)     # (N, nh, th, nw, tw, C)
    bt_h = jnp.asarray(ct_h.BT, x.dtype)
    bt_w = jnp.asarray(ct_w.BT, x.dtype)
    # B^T d B, vectorized over (N, nh, nw, C) -- channels innermost (NHWC).
    v = jnp.einsum("it,nhtwuc,ju->nhwijc", bt_h, tiles, bt_w)
    # scatter: (P, R, C) with P = th*tw Winograd points, R = N*nh*nw regions.
    v = v.reshape(n * nh * nw, th * tw, c).transpose(1, 0, 2)

    # --- phase 2: P batched GEMMs [R x C] x [C x M] ------------------------
    uu = u.reshape(th * tw, c, mout)
    y = jnp.einsum("prc,pcm->prm", v, uu, precision=precision,
                   preferred_element_type=preferred_element_type)

    # --- phase 3: gather + output transform --------------------------------
    y = y.transpose(1, 0, 2).reshape(n, nh, nw, th, tw, mout)
    at_h = jnp.asarray(ct_h.AT, y.dtype)
    at_w = jnp.asarray(ct_w.AT, y.dtype)
    out = jnp.einsum("it,nhwtum,ju->nhiwjm", at_h, y, at_w)
    out = out.reshape(n, nh * mh, nw * mw, mout)
    return out[:, :geometry.out_h, :geometry.out_w, :].astype(x.dtype)


def winograd_depthwise_conv2d_pretransformed(
    x: jax.Array,
    u: jax.Array,
    ct_h: CookToom,
    ct_w: CookToom,
    *,
    padding: Padding = "SAME",
    geometry: Conv2DGeometry | None = None,
) -> jax.Array:
    """Depthwise 2D Winograd executor: the transform-domain channel GEMM of
    the dense scheme degenerates to an *elementwise* multiply batched over
    channels -- each channel convolves with its own filter, so phase 2 is a
    Hadamard product over the (P, R, C) Winograd points instead of a GEMM
    over C. Phases 1 and 3 (tiling, B^T (.) B, A^T (.) A) are identical to
    the dense path and reuse its geometry.

    Args:
      x: (N, H, W, C) input, NHWC.
      u: (th, tw, C, mult) pre-transformed depthwise filter -- the HWIO
         (kh, kw, 1, C*mult) filter transformed by G_h (.) G_w^T and
         regrouped so the channel axis is explicit (mult = channel
         multiplier; the common MobileNet case is mult = 1).

    Returns:
      (N, H', W', C*mult), matching jax.lax.conv_general_dilated with
      feature_group_count = C (output channel o = c * mult + j).
    """
    n, h, wdt, c = x.shape
    th, tw, _, mult = u.shape
    mh, mw, kh, kw = ct_h.m, ct_w.m, ct_h.r, ct_w.r
    if geometry is None:
        geometry = conv2d_geometry(h, wdt, kh, kw, mh, mw, padding)
    nh, nw = geometry.n_h, geometry.n_w
    xp = jnp.pad(x, ((0, 0), (geometry.lo_h, geometry.hi_h),
                     (geometry.lo_w, geometry.hi_w), (0, 0)))

    tiles = _extract_tiles_1d(xp, 1, th, mh, nh)
    tiles = _extract_tiles_1d(tiles, 3, tw, mw, nw)     # (N, nh, th, nw, tw, C)
    bt_h = jnp.asarray(ct_h.BT, jnp.float32)
    bt_w = jnp.asarray(ct_w.BT, jnp.float32)
    v = jnp.einsum("it,nhtwuc,ju->nhwijc", bt_h,
                   tiles.astype(jnp.float32), bt_w)     # (N, nh, nw, th, tw, C)
    # phase 2, depthwise: Hadamard over channels (batched over mult). The
    # repeated c axis makes this an elementwise product, not a contraction.
    y = jnp.einsum("nhwijc,ijcm->nhwijcm", v, u.astype(jnp.float32))
    at_h = jnp.asarray(ct_h.AT, jnp.float32)
    at_w = jnp.asarray(ct_w.AT, jnp.float32)
    out = jnp.einsum("it,nhwtucm,ju->nhiwjcm", at_h, y, at_w)
    out = out.reshape(n, nh * mh, nw * mw, c * mult)
    return out[:, :geometry.out_h, :geometry.out_w, :].astype(x.dtype)


def winograd_grouped_conv2d_pretransformed(
    x: jax.Array,
    u: jax.Array,
    ct_h: CookToom,
    ct_w: CookToom,
    groups: int,
    *,
    padding: Padding = "SAME",
    geometry: Conv2DGeometry | None = None,
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """Grouped dense Winograd executor: the full channel reduction becomes a
    block-diagonal reduction -- one (R x Cg) x (Cg x Mg) GEMM per group per
    Winograd point, expressed as a single batched einsum so the per-group
    GEMMs stay fused. Phases 1 and 3 are the dense path's.

    Args:
      x: (N, H, W, C) input; C = groups * Cg.
      u: (th, tw, Cg, M) pre-transformed grouped filter; M = groups * Mg,
         group-major on the output axis (matching feature_group_count).
    """
    n, h, wdt, c = x.shape
    th, tw, cg, mout = u.shape
    mg = mout // groups
    mh, mw, kh, kw = ct_h.m, ct_w.m, ct_h.r, ct_w.r
    if geometry is None:
        geometry = conv2d_geometry(h, wdt, kh, kw, mh, mw, padding)
    nh, nw = geometry.n_h, geometry.n_w
    xp = jnp.pad(x, ((0, 0), (geometry.lo_h, geometry.hi_h),
                     (geometry.lo_w, geometry.hi_w), (0, 0)))

    tiles = _extract_tiles_1d(xp, 1, th, mh, nh)
    tiles = _extract_tiles_1d(tiles, 3, tw, mw, nw)     # (N, nh, th, nw, tw, C)
    bt_h = jnp.asarray(ct_h.BT, x.dtype)
    bt_w = jnp.asarray(ct_w.BT, x.dtype)
    v = jnp.einsum("it,nhtwuc,ju->nhwijc", bt_h, tiles, bt_w)
    # scatter with the channel axis split (P, R, G, Cg)
    v = v.reshape(n * nh * nw, th * tw, groups, cg).transpose(1, 0, 2, 3)

    # phase 2: block-diagonal reduction -- P x G batched (R, Cg) x (Cg, Mg)
    uu = u.reshape(th * tw, cg, groups, mg)
    y = jnp.einsum("prgc,pcgm->prgm", v, uu, precision=precision,
                   preferred_element_type=preferred_element_type)
    y = y.reshape(th * tw, n * nh * nw, mout)           # group-major M

    y = y.transpose(1, 0, 2).reshape(n, nh, nw, th, tw, mout)
    at_h = jnp.asarray(ct_h.AT, y.dtype)
    at_w = jnp.asarray(ct_w.AT, y.dtype)
    out = jnp.einsum("it,nhwtum,ju->nhiwjm", at_h, y, at_w)
    out = out.reshape(n, nh * mh, nw * mw, mout)
    return out[:, :geometry.out_h, :geometry.out_w, :].astype(x.dtype)


def strided_phase_filters(w: jax.Array, ct_h: CookToom,
                          ct_w: CookToom) -> jax.Array:
    """(kh, kw, Cg, M) filter -> (2, 2, th, tw, Cg, M) Winograd-domain phase
    sub-filters for the stride-2 decomposition.

    The filter is zero-padded to even size (kh+1, kw+1) so all four phase
    sub-filters w[p::2, q::2] share one size r_ph = (k+1)//2 -- and hence one
    F(m, r_ph) transform set, which is what lets the phase sum happen in the
    transform domain (before the single inverse transform). Done once per
    plan."""
    kh, kw = w.shape[:2]
    wp = jnp.pad(w, ((0, 1), (0, 1), (0, 0), (0, 0)))
    return jnp.stack([
        jnp.stack([transform_filter_2d(wp[p::2, q::2], ct_h, ct_w)
                   for q in (0, 1)], 0)
        for p in (0, 1)], 0)


def winograd_strided_conv2d_pretransformed(
    x: jax.Array,
    u: jax.Array,
    ct_h: CookToom,
    ct_w: CookToom,
    *,
    groups: int = 1,
    geometry: Conv2DGeometry,
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """Stride-2 convolution via transform-domain phase decomposition -- the
    2D analogue of the polyphase conv1d path, with the cross-phase sum moved
    *into* the Winograd domain.

    A stride-2 conv splits into four stride-1 sub-convolutions over the four
    input phases x[p::2, q::2] with phase sub-filters w[p::2, q::2] (the
    filter zero-padded to even size so all phases share one F(m, r_ph)
    transform set, r_ph = (k+1)//2). Because every phase uses the same A^T,
    the phase outputs are summed in the transform domain: one accumulated
    (P, R, .) tensor, ONE inverse transform, one output scatter -- the four
    phases cost four input transforms and four GEMM banks, not four full
    pipelines.

    Args:
      x: (N, H, W, C) input, NHWC.
      u: (2, 2, th, tw, Cg, M') pre-transformed phase filters
         (strided_phase_filters); for depthwise Cg = C and M' = the channel
         multiplier, for grouped Cg = C/groups and M' = M (group-major).
      groups: feature_group_count; selects the phase-2 contraction (dense
         GEMM / depthwise Hadamard / grouped block-diagonal), mirroring the
         stride-1 executor family.
      geometry: conv2d_strided_geometry record (built once at plan time).

    Returns:
      (N, H', W', M), matching lax.conv_general_dilated with stride (2, 2).
    """
    n, h, wdt, c = x.shape
    th, tw = ct_h.t, ct_w.t
    mh, mw = ct_h.m, ct_w.m
    nh, nw = geometry.n_h, geometry.n_w
    depthwise = groups > 1 and groups == c
    xp = jnp.pad(x, ((0, 0), (geometry.lo_h, geometry.hi_h),
                     (geometry.lo_w, geometry.hi_w), (0, 0)))
    len_h = nh * mh + ct_h.r - 1          # phase sub-grid extents
    len_w = nw * mw + ct_w.r - 1
    dt = jnp.float32 if depthwise else x.dtype
    bt_h = jnp.asarray(ct_h.BT, dt)
    bt_w = jnp.asarray(ct_w.BT, dt)

    pp = th * tw
    r_tot = n * nh * nw

    # phase 1: per-phase tiling + input transform, scattered into ONE
    # (4P, R, C) tensor (phase-major points) so phase 2 stays a single
    # batched contraction over all phases and regions -- the strided
    # analogue of the dense scheme's (P, R, C) scatter.
    vs = []
    for p in (0, 1):
        for q in (0, 1):
            ph = xp[:, p::2, q::2, :][:, :len_h, :len_w, :]
            tiles = _extract_tiles_1d(ph, 1, th, mh, nh)
            tiles = _extract_tiles_1d(tiles, 3, tw, mw, nw)
            v = jnp.einsum("it,nhtwuc,ju->nhwijc", bt_h, tiles.astype(dt),
                           bt_w)                    # (N, nh, nw, th, tw, C)
            vs.append(v.reshape(r_tot, pp, c).transpose(1, 0, 2))
    v4 = jnp.concatenate(vs, 0)                     # (4P, R, C)
    u4 = u.astype(dt).reshape(4 * pp, *u.shape[4:])  # (4P, Cg, M')

    # phase 2: 4P batched contractions; the cross-phase sum then happens in
    # the transform domain (every phase shares A^T), so ONE inverse follows.
    if groups == 1:
        y = jnp.einsum("prc,pcm->prm", v4, u4, precision=precision,
                       preferred_element_type=preferred_element_type)
        mout = y.shape[-1]
    elif depthwise:
        # Hadamard phase 2, batched over the channel multiplier.
        y = jnp.einsum("prc,pcm->prcm", v4, u4)
        mout = c * u4.shape[-1]
        y = y.reshape(4 * pp, r_tot, mout)
    else:
        cg = c // groups
        mg = u4.shape[-1] // groups
        vg = v4.reshape(4 * pp, r_tot, groups, cg)
        ug = u4.reshape(4 * pp, cg, groups, mg)
        y = jnp.einsum("prgc,pcgm->prgm", vg, ug, precision=precision,
                       preferred_element_type=preferred_element_type)
        mout = groups * mg
        y = y.reshape(4 * pp, r_tot, mout)
    y = y.reshape(4, pp, r_tot, mout).sum(0)        # transform-domain sum

    # phase 3: one gather + inverse transform + NHWC scatter, as in the
    # stride-1 scheme.
    y = y.transpose(1, 0, 2).reshape(n, nh, nw, th, tw, mout)
    at_h = jnp.asarray(ct_h.AT, y.dtype)
    at_w = jnp.asarray(ct_w.AT, y.dtype)
    out = jnp.einsum("it,nhwtum,ju->nhiwjm", at_h, y, at_w)
    out = out.reshape(n, nh * mh, nw * mw, mout)
    return out[:, :geometry.out_h, :geometry.out_w, :].astype(x.dtype)


def pointwise_conv2d(x: jax.Array, u: jax.Array, *, precision=None,
                     preferred_element_type=jnp.float32) -> jax.Array:
    """1x1 convolution: a pure channel GEMM.  u: (C, M)."""
    return jnp.einsum("nhwc,cm->nhwm", x, u, precision=precision,
                      preferred_element_type=preferred_element_type
                      ).astype(x.dtype)


def winograd_conv1d_axis_pretransformed(
    x: jax.Array,
    u: jax.Array,
    ct: CookToom,
    geometry: Axis1DGeometry,
    *,
    precision=None,
    preferred_element_type=jnp.float32,
) -> jax.Array:
    """1xN / Nx1 executor over a pre-transformed (t, C, M) filter and a
    precomputed axis geometry: 1D Cook-Toom along geometry.axis, plain
    channel GEMM along the unit axis."""
    n, h, wdt, _ = x.shape
    axis, lo, hi, nt = geometry.axis, geometry.lo, geometry.hi, geometry.n_t
    m, mout = ct.m, u.shape[-1]
    pad = [(0, 0)] * 4
    pad[axis] = (lo, hi)
    xp = jnp.pad(x, pad)
    tiles = _extract_tiles_1d(xp, axis, ct.t, m, nt)     # axis -> (nt, t)
    bt = jnp.asarray(ct.BT, x.dtype)
    at = jnp.asarray(ct.AT, x.dtype)
    if axis == 1:
        v = jnp.einsum("it,nstwc->nsiwc", bt, tiles)     # (N, nt, t, W, C)
        y = jnp.einsum("nsiwc,icm->nsiwm", v, u, precision=precision,
                       preferred_element_type=preferred_element_type)
        out = jnp.einsum("ot,nstwm->nsowm", at.astype(y.dtype), y)
        out = out.reshape(n, nt * m, wdt, mout)
        return out[:, :geometry.out_size].astype(x.dtype)
    else:
        v = jnp.einsum("it,nhstc->nhsic", bt, tiles)     # (N, H, nt, t, C)
        y = jnp.einsum("nhsic,icm->nhsim", v, u, precision=precision,
                       preferred_element_type=preferred_element_type)
        out = jnp.einsum("ot,nhstm->nhsom", at.astype(y.dtype), y)
        out = out.reshape(n, h, nt * m, mout)
        return out[:, :, :geometry.out_size].astype(x.dtype)


def _winograd_conv2d_1d_kernel(
    x: jax.Array, w: jax.Array, *, output_tile, padding: Padding,
    precision, preferred_element_type,
) -> jax.Array:
    """1xN / Nx1 layers (paper's Inception-v3 case): derive the filter
    transform and geometry, then run the pretransformed executor."""
    kh, kw, c, mout = w.shape
    axis = 1 if kh > 1 else 2          # spatial axis the filter runs along
    k = max(kh, kw)
    if k == 1:                          # 1x1: pure channel GEMM (pointwise)
        return pointwise_conv2d(x, w[0, 0], precision=precision,
                                preferred_element_type=preferred_element_type)
    m = output_tile if isinstance(output_tile, int) else output_tile[axis - 1]
    ct = cook_toom(m, k)
    u = transform_filter_1d(w.reshape(k, c, mout), ct)   # (t, C, M)
    geometry = conv1d_axis_geometry(x.shape[axis], axis, k, m, padding)
    return winograd_conv1d_axis_pretransformed(
        x, u, ct, geometry, precision=precision,
        preferred_element_type=preferred_element_type)


# ---------------------------------------------------------------------------
# 1D depthwise causal Cook-Toom convolution (Mamba's short conv). This is the
# paper's 1D algorithm specialized to depthwise form: the per-point GEMM over
# channels degenerates to an elementwise product, but the multiplication
# reduction (m*r/t) still applies per channel.
# ---------------------------------------------------------------------------

def ct_depthwise_causal_conv1d(
    x: jax.Array, w: jax.Array, *, output_tile: int = 4,
) -> jax.Array:
    """Causal depthwise conv: y[b, l, c] = sum_k w[k, c] * x[b, l - (r-1) + k, c].

    Args:
      x: (B, L, C).
      w: (r, C) depthwise taps.
    Returns:
      (B, L, C), same length (causal left pad of r - 1).
    """
    r, c = w.shape
    b, length, _ = x.shape
    ct = cook_toom(output_tile, r)
    nt = -(-length // ct.m)
    u = jnp.einsum("ij,jc->ic", jnp.asarray(ct.G, w.dtype), w)   # (t, C)
    return ct_depthwise_causal_conv1d_pretransformed(
        x, u, ct, n_tiles=nt, pad_hi=nt * ct.m - length)


def ct_depthwise_causal_conv1d_pretransformed(
    x: jax.Array, u: jax.Array, ct: CookToom, *, n_tiles: int, pad_hi: int,
) -> jax.Array:
    """Planned executor for the depthwise causal Cook-Toom conv: `u` is the
    pre-transformed (t, C) taps and the tile count / padding come from the
    plan (core.plan.plan_depthwise_conv1d) -- no per-call cook_toom or
    geometry derivation."""
    b, length, c = x.shape
    r = ct.r
    # causal pad left r-1; pad right so tiles cover n_tiles * m outputs.
    xp = jnp.pad(x, ((0, 0), (r - 1, pad_hi), (0, 0)))
    tiles = _extract_tiles_1d(xp, 1, ct.t, ct.m, n_tiles)   # (B, nt, t, C)
    bt = jnp.asarray(ct.BT, x.dtype)
    at = jnp.asarray(ct.AT, x.dtype)
    v = jnp.einsum("it,bstc->bsic", bt, tiles)
    y = v * u.astype(x.dtype)[None, None]                 # Hadamard, per channel
    out = jnp.einsum("ot,bstc->bsoc", at, y).reshape(b, n_tiles * ct.m, c)
    return out[:, :length].astype(x.dtype)
