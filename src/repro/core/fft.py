"""Tiled FFT (rfft2) convolution executor.

"FFT Convolutions are Faster than Winograd on Modern CPUs" (PAPERS.md)
shows the Winograd/FFT crossover is real and shape-dependent: FFT's
transform cost per output point is O(log t) and *independent of the filter
size*, so it wins on large filters and large spatial extents where
F(4, 3)-class tiles amortize poorly. This module is that contender as a
pure registry citizen: it declares a Capability in core/registry.py and
plugs into plan/compile with zero compiler changes.

The executor reuses the Winograd overlap tiling verbatim (the math is the
same scheme with the polynomial transform swapped for the DFT -- see
winograd.conv2d_fft_geometry): the input is cut into t x t tiles whose
origins advance by m = t - k + 1, each tile is sent through rfft2, the
channel reduction happens as a complex pointwise GEMM against the
pre-transformed (conjugated) filter spectrum, and irfft2 brings each tile
back to m x m valid outputs. Because the filter spectrum is conjugated,
the circular theorem yields cross-correlation,

    irfft2(rfft2(x_tile) * conj(rfft2(pad(w))))[i] = sum_n x[n + i] w[n],

and the first m outputs per axis are wraparound-free (n + i <= t - 1 for
i < m), so no overlap-add scatter is needed -- tiles write disjoint output
blocks, the overlap-save dual of the textbook overlap-add formulation.

The filter transform U = conj(rfft2(zero-padded w)) runs once at plan time
(plan._bind_weights) and is persisted complex64 in NetworkPlan artifacts,
exactly like the Winograd-domain filters.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import winograd as _wg


class FFTGeometry(NamedTuple):
    """Plan-time decisions of the FFT executor for one layer: the rfft2
    transform length per axis and the valid outputs per tile
    (m = fft - k + 1). Derived deterministically from the layer shape
    (choose_fft_geometry), so artifacts only need to persist the output
    tile to rebuild it."""

    fft_h: int
    fft_w: int
    m_h: int
    m_w: int


#: Candidate transform lengths. Powers of two keep rfft2 on its fastest
#: path and make the plan-time choice reproducible from the output tile
#: alone (fft = m + k - 1 lands back on the same power of two).
FFT_SIZES = (8, 16, 32)


def _pick_axis(size: int, k: int) -> int:
    """Transform length for one spatial axis: the smallest candidate that
    covers the axis in a single tile (m = f - k + 1 >= size), else the
    largest candidate with m >= 1. Single-tile when possible bounds edge
    waste on small axes; otherwise the biggest tile amortizes the
    O(f log f) transforms over the most outputs."""
    for f in FFT_SIZES:
        if f - k + 1 >= size:
            return f
    for f in reversed(FFT_SIZES):
        if f - k + 1 >= 1:
            return f
    raise ValueError(f"filter size {k} exceeds every FFT candidate "
                     f"length {FFT_SIZES}")


def choose_fft_geometry(h: int, w: int, kh: int, kw: int,
                        output_tile: tuple[int, int] | None = None
                        ) -> FFTGeometry:
    """Pick the per-axis transform lengths for an (h, w) layer with a
    (kh, kw) filter. With `output_tile` given (artifact reload, or an
    explicit request), the lengths are m + k - 1 -- the inverse of the
    default choice, so saved plans rebuild bit-identically."""
    if output_tile is not None:
        m_h, m_w = output_tile
        return FFTGeometry(m_h + kh - 1, m_w + kw - 1, m_h, m_w)
    fh, fw = _pick_axis(h, kh), _pick_axis(w, kw)
    return FFTGeometry(fh, fw, fh - kh + 1, fw - kw + 1)


def fft_transform_filter(w: jax.Array, fft_h: int, fft_w: int) -> jax.Array:
    """(kh, kw, C, M) -> (fft_h, fft_w//2+1, C, M) complex64: the conjugated
    rfft2 spectrum of the zero-padded filter. The FFT analogue of
    winograd.transform_filter_2d; runs once at plan time."""
    kh, kw = w.shape[0], w.shape[1]
    wp = jnp.pad(w.astype(jnp.float32),
                 ((0, fft_h - kh), (0, fft_w - kw), (0, 0), (0, 0)))
    return jnp.conj(jnp.fft.rfft2(wp, axes=(0, 1)))


def fft_conv2d_pretransformed(x: jax.Array, u: jax.Array, fft: FFTGeometry,
                              *, padding: _wg.Padding = "SAME",
                              geometry: _wg.Conv2DGeometry | None = None,
                              precision=None) -> jax.Array:
    """NHWC conv with a plan-time pre-transformed filter spectrum `u`.

    Same three phases as the Winograd executor: overlap tiling -> forward
    transform (rfft2) -> complex channel GEMM -> inverse transform (irfft2)
    -> crop. The per-tile valid region is [:m_h, :m_w]; tiles write
    disjoint output blocks (overlap-save)."""
    n, h, w, c = x.shape
    kh = fft.fft_h - fft.m_h + 1
    kw = fft.fft_w - fft.m_w + 1
    if geometry is None:
        geometry = _wg.conv2d_fft_geometry(h, w, kh, kw, fft.fft_h,
                                           fft.fft_w, padding)
    g = geometry
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (g.lo_h, g.hi_h), (g.lo_w, g.hi_w), (0, 0)))
    tiles = _wg._extract_tiles_1d(xp, 1, fft.fft_h, fft.m_h, g.n_h)
    tiles = _wg._extract_tiles_1d(tiles, 3, fft.fft_w, fft.m_w, g.n_w)
    # (N, n_h, fft_h, n_w, fft_w, C) -> spectrum over the tile axes
    v = jnp.fft.rfft2(tiles, axes=(2, 4))
    y = jnp.einsum("nhawbc,abcm->nhawbm", v, u, precision=precision)
    y = jnp.fft.irfft2(y, s=(fft.fft_h, fft.fft_w), axes=(2, 4))
    y = y[:, :, :fft.m_h, :, :fft.m_w, :]
    y = y.reshape(n, g.n_h * fft.m_h, g.n_w * fft.m_w, u.shape[-1])
    return y[:, :g.out_h, :g.out_w, :].astype(x.dtype)
