# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core import registry
from repro.core.compile import ArtifactMismatchError, LayerIR, NetworkPlan
# Exported under an alias: binding the name `compile` on the package would
# shadow the repro.core.compile SUBMODULE attribute (and the builtin).
from repro.core.compile import compile as compile_network
from repro.core.dispatch import ALGORITHMS, Algorithm, conv1d, conv2d
from repro.core.plan import (Conv1DPlan, ConvPlan, ConvSpec,
                             DepthwiseConv1DPlan, InvertedResidualPlan,
                             SeparableBlockPlan, algorithm_supported,
                             clear_plan_cache, plan_cache_info, plan_conv1d,
                             plan_conv2d, plan_depthwise_conv1d,
                             plan_from_artifact, plan_inverted_residual,
                             plan_separable_block, winograd_amortizes,
                             winograd_suitable)

__all__ = [
    "ALGORITHMS", "Algorithm", "ArtifactMismatchError", "Conv1DPlan",
    "ConvPlan", "ConvSpec", "DepthwiseConv1DPlan", "InvertedResidualPlan",
    "LayerIR", "NetworkPlan", "SeparableBlockPlan", "algorithm_supported",
    "clear_plan_cache", "compile_network", "conv1d", "conv2d",
    "plan_cache_info",
    "plan_conv1d", "plan_conv2d", "plan_depthwise_conv1d",
    "plan_from_artifact", "plan_inverted_residual", "plan_separable_block",
    "registry", "winograd_amortizes", "winograd_suitable",
]
