# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core import registry
from repro.core.dispatch import ALGORITHMS, Algorithm, conv1d, conv2d
from repro.core.plan import (Conv1DPlan, ConvPlan, ConvSpec,
                             DepthwiseConv1DPlan, InvertedResidualPlan,
                             SeparableBlockPlan, algorithm_supported,
                             clear_plan_cache, plan_cache_info, plan_conv1d,
                             plan_conv2d, plan_depthwise_conv1d,
                             plan_inverted_residual, plan_separable_block,
                             winograd_amortizes, winograd_suitable)

__all__ = [
    "ALGORITHMS", "Algorithm", "Conv1DPlan", "ConvPlan", "ConvSpec",
    "DepthwiseConv1DPlan", "InvertedResidualPlan", "SeparableBlockPlan",
    "algorithm_supported", "clear_plan_cache", "conv1d", "conv2d",
    "plan_cache_info", "plan_conv1d", "plan_conv2d",
    "plan_depthwise_conv1d", "plan_inverted_residual",
    "plan_separable_block", "registry", "winograd_amortizes",
    "winograd_suitable",
]
