"""Graph-level convolution compiler: spec list -> layer IR -> pass pipeline
-> one deployable, serializable NetworkPlan.

Before this module the paper's section-4 deployment insight (transform
filters once offline, run inference with zero per-call transform work) was
scattered across six ad-hoc entry points (plan_conv2d, plan_separable_block,
plan_inverted_residual, plan_conv1d, plan_depthwise_conv1d, plan_cnn /
plan_stem), each with its own plan class and apply signature, and the
fusion decisions (dw+pw -> one kernel) were hand-written branches inside
models/cnn.py:plan_cnn. This module is the compiler those entry points
become shims over:

  * `LayerIR` -- a declarative graph node (conv2d / conv1d / pool / concat /
    add / dense / ...). `lower()` turns the models/cnn.py spec lists (and
    the models/audio.py stem) into IR; SeparableConv and InvertedResidual
    specs lower to their *unfused* conv chains.
  * the pass pipeline `lower -> fuse -> place -> bind`:
      - `fuse` is registry-aware pattern rewriting over the IR: a depthwise
        conv followed 1:1 by a pointwise 1x1 rewrites to a `separable`
        node (SeparableBlockPlan -- the fused streamed kernel where the
        capability matches, the composed pair otherwise), and the
        expand -> depthwise -> linear-project [-> residual add] chain
        rewrites to an `inverted_residual` node. No model file hand-codes a
        fusion decision anymore; new fusions are new patterns here.
      - `place` maps the caller's global algorithm request onto each node
        via capability-registry queries (the per-layer fallback the paper's
        mixed policy needs).
      - `bind` builds the concrete LayerPlan objects (all per-layer
        decisions + the one-time filter transforms) and collects the
        epilogue constants (biases, dense weights).
  * `compile(params, graph, *, res, ...) -> NetworkPlan` -- the one entry
    point. NetworkPlan executes the graph (`apply`), renders the per-layer
    algorithm table (`describe`, same markdown generator as the registry's
    README table), and round-trips to disk (`save`/`load`): the artifact
    holds the pre-transformed execution-domain weights plus every per-layer
    algorithm decision under a versioned header, so a second process starts
    warm -- no re-planning, no re-measuring, no filter-transform ops. A
    header mismatch (format/version, dtype, layout, capability-registry
    fingerprint) refuses with an actionable error instead of silently
    recomputing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import time
import zipfile
from typing import Any, Iterator, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as _partition
from repro.core import plan as _plan
from repro.core import registry
from repro.obs import trace as _obs_trace

ARTIFACT_FORMAT = "repro.network_plan"
# v2: conv layer metas gained the fft/winograd_f63 algorithms plus N-way
# autotune evidence (winner/winner_tile and per-contender timings); v1
# readers would mis-plan those layers, so the version gates them out.
# v3: the header carries per-array sha256 digests and load() verifies every
# array against them, so silent storage corruption (bit rot, truncated
# copies) raises ArtifactMismatchError -- and triggers the serving layer's
# recompile-in-place path -- instead of producing wrong outputs. A v2
# artifact has no digests to verify, so the version gates it out.
# v4: the header carries the network-level compute_dtype policy and conv
# plan metas may store reduced-precision (bf16/int8) transform-domain
# filters plus their per-output-channel dequantization scale arrays. A v3
# reader would drop the scales and serve un-dequantized int8 outputs, so
# the version gates it out.
# v5: the header carries the partition record (mesh kind/axis/shard count
# plus the spatial walk's per-node modes, halos and re-scatter points), and
# partitioned plans are bound at shard-LOCAL geometry -- a v4 reader would
# apply those plans to global-shape inputs and fail or mis-shape, so the
# version gates it out. Warm starts restore the recorded partitioning
# without re-deciding; the device mesh itself is never serialized (attach
# one with with_mesh() / compile(mesh=)).
ARTIFACT_VERSION = 5

#: IR ops that bind to a LayerPlan (everything else is structural/XLA-only).
PLAN_OPS = ("conv2d", "conv1d", "separable", "inverted_residual")


_DEPRECATION_WARNED: set[str] = set()


def warn_deprecated(api: str, replacement: str) -> None:
    """Emit ONE actionable DeprecationWarning per legacy entry point per
    process (the legacy plan_* shims call this on their way into
    compile())."""
    if api in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(api)
    import warnings
    warnings.warn(
        f"{api} is deprecated; use {replacement} -- the compile() API "
        f"subsumes it (fusion passes, per-layer placement, and "
        f"NetworkPlan.save/load deployment artifacts).",
        DeprecationWarning, stacklevel=3)


class ArtifactMismatchError(ValueError):
    """A saved NetworkPlan artifact cannot be loaded by this build: wrong
    format/version, stale capability registry, dtype/layout mismatch, or an
    array that fails its recorded sha256 integrity digest (storage
    corruption). The message states the mismatch and the fix (recompile +
    save)."""


class LayerExecutionError(RuntimeError):
    """One graph node's executor raised during NetworkPlan.apply. Carries
    `node_id` so a supervisor (repro.runtime.serve) can re-place exactly the
    failing layer onto a fallback executor; the original exception is
    chained as __cause__. Only raised when apply(annotate_errors=True)."""

    def __init__(self, node_id: str, cause: BaseException):
        super().__init__(f"layer {node_id!r} failed: {cause!r}")
        self.node_id = node_id


def _meta_compute_dtypes(meta: dict) -> tuple[tuple[str, str], ...]:
    """(executor, compute_dtype) leaves of one plan meta, recursing through
    the block kinds (separable / inverted residual hold nested conv metas).
    Feeds the dtype-mismatch refusal's per-layer enumeration."""
    kind = meta.get("kind")
    if kind == "conv2d":
        return ((meta.get("algorithm", "?"),
                 meta.get("compute_dtype", "float32")),)
    if kind == "separable":
        if meta.get("mode") == "fused_pallas":
            return (("separable_streamed", "float32"),)
        return (_meta_compute_dtypes(meta["dw"])
                + _meta_compute_dtypes(meta["pw"]))
    if kind == "inverted_residual":
        out = ()
        if meta.get("expand") is not None:
            out += _meta_compute_dtypes(meta["expand"])
        return out + _meta_compute_dtypes(meta["sep"])
    return ()


def _artifact_dtype_report(header: dict) -> str:
    """Per-layer enumeration for dtype-mismatch refusals: each layer's
    on-disk transform-domain compute dtype(s) next to what THIS build's
    capability registry declares its executor(s) can run -- so the caller
    sees at a glance which layers a recompile at the expected precision
    would actually change."""
    lines = []
    for nid, meta in header.get("plans", {}).items():
        leaves = _meta_compute_dtypes(meta)
        if not leaves:
            continue
        part = ", ".join(
            f"{ex}={cd}"
            f"(registry: {'/'.join(registry.compute_dtypes_for(ex))})"
            for ex, cd in leaves)
        lines.append(f"{nid}[{part}]")
    return "; ".join(lines)


def _array_digest(a: np.ndarray) -> str:
    """sha256 over dtype + shape + raw bytes of one artifact array -- the
    per-array integrity record save() writes and load() verifies."""
    a = np.ascontiguousarray(a)
    h = hashlib.sha256()
    h.update(f"{a.dtype}:{a.shape}".encode())
    h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Layer IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerIR:
    """One node of the layer IR: an op name, graph edges (`inputs` name
    producer nodes), and op attributes (filter geometry, activation,
    parameter paths into the params pytree). The graph is a tuple of nodes
    in topological order whose first node is the single `input` and whose
    last node is the network output."""

    id: str
    op: str                    # input | conv2d | conv1d | separable |
                               # inverted_residual | pool | concat | add |
                               # global_avg_pool | dense
    inputs: tuple[str, ...] = ()
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    block: str | None = None   # origin spec name; fusion rewrites name the
                               # fused node after the shared block


def _is_ir(graph) -> bool:
    return (len(graph) > 0
            and all(isinstance(n, LayerIR) for n in graph))


# ---------------------------------------------------------------------------
# lower: models/cnn.py spec lists -> IR
# ---------------------------------------------------------------------------

def lower(specs: Sequence, c_in: int = 3) -> tuple[LayerIR, ...]:
    """Lower a models/cnn.py spec list to the layer IR. Composite specs
    (SeparableConv, InvertedResidual) lower to their UNFUSED conv chains --
    reconstituting the fused execution units is the fuse pass's job, so
    fusion is a graph rewrite, not a property of the input format. Channel
    counts are tracked through the walk (they determine depthwise groups
    and residual feasibility); spatial shapes are inferred later."""
    from repro.models import cnn as _cnn

    nodes = [LayerIR(id="input", op="input")]
    counter = itertools.count()

    def uid(prefix: str) -> str:
        return f"{prefix}_{next(counter)}"

    def conv_node(nid, head, *, kh, kw, c_out, stride, padding, groups,
                  depthwise, activation, w_path, b_path, block):
        nodes.append(LayerIR(
            id=nid, op="conv2d", inputs=(head,),
            attrs=dict(kh=kh, kw=kw, c_out=c_out, stride=(stride, stride),
                       padding=padding, groups=groups, depthwise=depthwise,
                       activation=activation, w_path=w_path, b_path=b_path),
            block=block))
        return nid

    def walk(specs, head: str, c: int) -> tuple[str, int]:
        for spec in specs:
            if isinstance(spec, _cnn.Conv):
                head = conv_node(
                    spec.name, head, kh=spec.kh, kw=spec.kw,
                    c_out=spec.c_out, stride=spec.stride,
                    padding=spec.padding, groups=spec.groups,
                    depthwise=spec.groups > 1 and spec.groups == c,
                    activation=spec.act, w_path=(spec.name, "w"),
                    b_path=(spec.name, "b"), block=spec.name)
                c = spec.c_out
            elif isinstance(spec, _cnn.SeparableConv):
                head = conv_node(
                    f"{spec.name}.dw", head, kh=spec.k, kw=spec.k, c_out=c,
                    stride=spec.stride, padding=spec.padding, groups=c,
                    depthwise=True, activation="relu",
                    w_path=(spec.name, "dw", "w"),
                    b_path=(spec.name, "dw", "b"), block=spec.name)
                head = conv_node(
                    f"{spec.name}.pw", head, kh=1, kw=1, c_out=spec.c_out,
                    stride=1, padding="SAME", groups=1, depthwise=False,
                    activation="relu", w_path=(spec.name, "pw", "w"),
                    b_path=(spec.name, "pw", "b"), block=spec.name)
                c = spec.c_out
            elif isinstance(spec, _cnn.InvertedResidual):
                src = head
                ce = c * spec.expand
                if spec.expand != 1:
                    head = conv_node(
                        f"{spec.name}.exp", head, kh=1, kw=1, c_out=ce,
                        stride=1, padding="SAME", groups=1, depthwise=False,
                        activation="relu6", w_path=(spec.name, "exp", "w"),
                        b_path=(spec.name, "exp", "b"), block=spec.name)
                head = conv_node(
                    f"{spec.name}.dw", head, kh=spec.k, kw=spec.k, c_out=ce,
                    stride=spec.stride, padding="SAME", groups=ce,
                    depthwise=True, activation="relu6",
                    w_path=(spec.name, "dw", "w"),
                    b_path=(spec.name, "dw", "b"), block=spec.name)
                head = conv_node(
                    f"{spec.name}.pw", head, kh=1, kw=1, c_out=spec.c_out,
                    stride=1, padding="SAME", groups=1, depthwise=False,
                    activation="none", w_path=(spec.name, "pw", "w"),
                    b_path=(spec.name, "pw", "b"), block=spec.name)
                if spec.stride == 1 and c == spec.c_out:
                    add_id = f"{spec.name}.add"
                    nodes.append(LayerIR(id=add_id, op="add",
                                         inputs=(src, head),
                                         block=spec.name))
                    head = add_id
                c = spec.c_out
            elif isinstance(spec, _cnn.Pool):
                pid = uid("pool")
                nodes.append(LayerIR(
                    id=pid, op="pool", inputs=(head,),
                    attrs=dict(kind=spec.kind, k=spec.k, stride=spec.stride,
                               padding=spec.padding)))
                head = pid
            elif isinstance(spec, _cnn.Concat):
                tails, c_total = [], 0
                for br in spec.branches:
                    tail, cb = walk(br, head, c)
                    tails.append(tail)
                    c_total += cb
                cid = uid("concat")
                nodes.append(LayerIR(id=cid, op="concat",
                                     inputs=tuple(tails)))
                head, c = cid, c_total
            elif isinstance(spec, _cnn.GlobalAvgPool):
                gid = uid("gap")
                nodes.append(LayerIR(id=gid, op="global_avg_pool",
                                     inputs=(head,)))
                head = gid
            elif isinstance(spec, _cnn.Dense):
                nodes.append(LayerIR(
                    id=spec.name, op="dense", inputs=(head,),
                    attrs=dict(n_out=spec.n_out, relu=spec.relu,
                               w_path=(spec.name, "w"))))
                head, c = spec.name, spec.n_out
            else:
                raise TypeError(
                    f"cannot lower spec {spec!r}; expected one of the "
                    f"models.cnn layer specs or a pre-lowered LayerIR graph")
        return head, c

    walk(specs, "input", c_in)
    return tuple(nodes)


# ---------------------------------------------------------------------------
# shape inference
# ---------------------------------------------------------------------------

def _out_size(size: int, k: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return -(-size // stride)
    return (size - k) // stride + 1


def infer_shapes(graph: Sequence[LayerIR],
                 input_shape: Sequence[int]) -> dict[str, tuple[int, ...]]:
    """Output shape of every node, walking the graph once."""
    shapes: dict[str, tuple[int, ...]] = {}
    for node in graph:
        a = node.attrs
        if node.op == "input":
            shapes[node.id] = tuple(input_shape)
            continue
        ins = [shapes[i] for i in node.inputs]
        s = ins[0]
        if node.op == "conv2d":
            n, h, w, _ = s
            shapes[node.id] = (
                n, _out_size(h, a["kh"], a["stride"][0], a["padding"]),
                _out_size(w, a["kw"], a["stride"][1], a["padding"]),
                a["c_out"])
        elif node.op in ("separable", "inverted_residual"):
            n, h, w, _ = s
            shapes[node.id] = (
                n, _out_size(h, a["k"], a["stride"][0], a["padding"]),
                _out_size(w, a["k"], a["stride"][1], a["padding"]),
                a["c_out"])
        elif node.op == "conv1d":
            b, t, _ = s
            shapes[node.id] = (
                b, _out_size(t, a["k"], a["stride"], a["padding"]),
                a["c_out"])
        elif node.op == "pool":
            n, h, w, c = s
            shapes[node.id] = (
                n, _out_size(h, a["k"], a["stride"], a["padding"]),
                _out_size(w, a["k"], a["stride"], a["padding"]), c)
        elif node.op == "concat":
            shapes[node.id] = s[:-1] + (sum(i[-1] for i in ins),)
        elif node.op == "add":
            shapes[node.id] = s
        elif node.op == "global_avg_pool":
            shapes[node.id] = (s[0], s[-1])
        elif node.op == "dense":
            shapes[node.id] = (s[0], a["n_out"])
        else:
            raise ValueError(f"unknown IR op {node.op!r} ({node.id})")
    return shapes


# ---------------------------------------------------------------------------
# fuse: registry-aware pattern rewrites
# ---------------------------------------------------------------------------

def _consumers(graph: Sequence[LayerIR]) -> dict[str, list[str]]:
    cons: dict[str, list[str]] = {n.id: [] for n in graph}
    for n in graph:
        for i in n.inputs:
            cons[i].append(n.id)
    return cons


def _rewrite(graph, remove: set, replace: dict) -> tuple[LayerIR, ...]:
    """Drop `remove` nodes, swap pattern tails for their fused nodes, and
    rewire edges that referenced a swapped tail."""
    rename = {old: new.id for old, new in replace.items()}
    out = []
    for n in graph:
        if n.id in remove:
            continue
        n = replace.get(n.id, n)
        out.append(dataclasses.replace(
            n, inputs=tuple(rename.get(i, i) for i in n.inputs)))
    return tuple(out)


def _fused_name(tail: LayerIR, parts: list[LayerIR]) -> str:
    blocks = {p.block for p in parts}
    if len(blocks) == 1 and tail.block:
        return tail.block
    return "+".join(p.id for p in parts if p.op == "conv2d")


def _fuse_inverted_residual(graph: Sequence[LayerIR]) -> tuple[LayerIR, ...]:
    """Pattern: [1x1 expand conv (act)] -> kxk depthwise (same act, mult 1)
    -> 1x1 linear projection [-> residual add with the chain input], each
    intermediate consumed exactly once => one `inverted_residual` node
    (bound to plan_inverted_residual: the dw+project pair rides the
    separable-block machinery, fusing to a single streamed kernel where the
    capability registry covers it)."""
    by_id = {n.id: n for n in graph}
    cons = _consumers(graph)
    remove: set[str] = set()
    replace: dict[str, LayerIR] = {}
    for pw in graph:
        if pw.op != "conv2d" or pw.id in remove:
            continue
        pa = pw.attrs
        if not (pa["kh"] == pa["kw"] == 1 and pa["groups"] == 1
                and tuple(pa["stride"]) == (1, 1)
                and pa["activation"] == "none"):
            continue
        dw = by_id.get(pw.inputs[0])
        if (dw is None or dw.op != "conv2d"
                or not dw.attrs.get("depthwise")
                or dw.attrs["kh"] != dw.attrs["kw"]
                or dw.attrs["c_out"] != dw.attrs["groups"]   # multiplier 1
                or cons[dw.id] != [pw.id] or dw.id in remove):
            continue
        head = dw.inputs[0]
        exp = by_id.get(head)
        exp_node = None
        if (exp is not None and exp.op == "conv2d" and exp.id not in remove
                and exp.attrs["kh"] == exp.attrs["kw"] == 1
                and exp.attrs["groups"] == 1
                and tuple(exp.attrs["stride"]) == (1, 1)
                and exp.attrs["activation"] == dw.attrs["activation"]
                and cons[exp.id] == [dw.id]):
            exp_node = exp
            head = exp.inputs[0]
        tail, residual = pw, False
        if len(cons[pw.id]) == 1:
            cand = by_id[cons[pw.id][0]]
            if cand.op == "add" and set(cand.inputs) == {head, pw.id}:
                tail, residual = cand, True
        parts = ([exp_node] if exp_node else []) + [dw, pw]
        attrs = dict(
            k=dw.attrs["kh"], stride=tuple(dw.attrs["stride"]),
            padding=dw.attrs["padding"], c_out=pa["c_out"],
            activation=dw.attrs["activation"], residual=residual,
            exp_w=exp_node.attrs["w_path"] if exp_node else None,
            exp_b=exp_node.attrs["b_path"] if exp_node else None,
            dw_w=dw.attrs["w_path"], dw_b=dw.attrs["b_path"],
            pw_w=pw.attrs["w_path"], pw_b=pw.attrs["b_path"])
        fused = LayerIR(id=_fused_name(tail, parts), op="inverted_residual",
                        inputs=(head,), attrs=attrs,
                        block=tail.block or dw.block)
        replace[tail.id] = fused
        remove |= {p.id for p in parts} - {tail.id}
    return _rewrite(graph, remove, replace) if replace else tuple(graph)


def _fuse_separable(graph: Sequence[LayerIR]) -> tuple[LayerIR, ...]:
    """Pattern: kxk depthwise conv consumed exactly once by a stride-1
    dense 1x1 conv => one `separable` node (bound to plan_separable_block:
    the fused streamed kernel where the registry capability matches --
    stride 1, suitable k, multiplier 1 -- and the composed pair otherwise,
    so the rewrite is always semantics-preserving)."""
    by_id = {n.id: n for n in graph}
    cons = _consumers(graph)
    remove: set[str] = set()
    replace: dict[str, LayerIR] = {}
    for pw in graph:
        if pw.op != "conv2d" or pw.id in remove:
            continue
        pa = pw.attrs
        if not (pa["kh"] == pa["kw"] == 1 and pa["groups"] == 1
                and tuple(pa["stride"]) == (1, 1)):
            continue
        dw = by_id.get(pw.inputs[0])
        if (dw is None or dw.op != "conv2d"
                or not dw.attrs.get("depthwise")
                or dw.attrs["kh"] != dw.attrs["kw"]
                or cons[dw.id] != [pw.id] or dw.id in remove):
            continue
        attrs = dict(
            k=dw.attrs["kh"], stride=tuple(dw.attrs["stride"]),
            padding=dw.attrs["padding"], c_out=pa["c_out"],
            inner_activation=dw.attrs["activation"],
            activation=pa["activation"],
            dw_w=dw.attrs["w_path"], dw_b=dw.attrs["b_path"],
            pw_w=pa["w_path"], pw_b=pa["b_path"])
        fused = LayerIR(id=_fused_name(pw, [dw, pw]), op="separable",
                        inputs=dw.inputs, attrs=attrs,
                        block=pw.block or dw.block)
        replace[pw.id] = fused
        remove.add(dw.id)
    return _rewrite(graph, remove, replace) if replace else tuple(graph)


#: The fusion pass pipeline, most specific pattern first (the inverted
#: residual's linear-projection chain would otherwise be half-claimed by the
#: generic separable rewrite).
FUSION_PASSES = (_fuse_inverted_residual, _fuse_separable)


def fuse(graph: Sequence[LayerIR]) -> tuple[LayerIR, ...]:
    """Run the registered fusion rewrites over the IR."""
    for p in FUSION_PASSES:
        graph = p(graph)
    return tuple(graph)


# ---------------------------------------------------------------------------
# place: per-node algorithm decisions (registry queries)
# ---------------------------------------------------------------------------

def place(graph: Sequence[LayerIR], shapes: dict[str, tuple[int, ...]],
          algorithm: str = "auto",
          compute_dtype: str = "float32") -> dict[str, dict]:
    """Map the global algorithm request onto each plan-bearing node. A
    forced family falls back to im2col on layers its executors do not cover
    (the paper's mixed policy applied to a forced setting) -- a capability-
    registry query, exactly like the legacy models/cnn.py:_layer_algorithm.
    The same per-layer fallback applies to a reduced compute_dtype: a conv
    layer none of whose covering executors declare the dtype is placed back
    at fp32 instead of refusing the whole network. Block nodes (separable /
    inverted residual) keep the family request: their plan builders run
    their own capability-aware internal placement (fused streamed kernel vs
    composed sub-plans)."""
    placements: dict[str, dict] = {}
    for node in graph:
        if node.op not in PLAN_OPS:
            continue
        a = node.attrs
        if node.op == "conv2d":
            c_in = shapes[node.inputs[0]][-1]
            groups = c_in if a.get("depthwise") else a["groups"]
            q = registry.as_query(a["kh"], a["kw"], tuple(a["stride"]),
                                  groups=groups, c_in=c_in, c_out=a["c_out"])
            alg = (algorithm if registry.supported(algorithm, q)
                   else "im2col")
            cd = compute_dtype
            if cd != "float32":
                fam = None if alg in ("auto", "auto_tuned") else alg
                if not any(cd in cap.compute_dtypes
                           for cap in registry.matching(q, fam)):
                    cd = "float32"
            placements[node.id] = {"algorithm": alg, "groups": groups,
                                   "compute_dtype": cd}
        else:
            placements[node.id] = {"algorithm": algorithm,
                                   "compute_dtype": compute_dtype}
    return placements


# ---------------------------------------------------------------------------
# bind: build the LayerPlans + epilogue constants
# ---------------------------------------------------------------------------

def _param(params, path):
    v = params
    for k in path:
        v = v[k]
    return v


def bind(graph: Sequence[LayerIR], shapes: dict[str, tuple[int, ...]],
         placements: dict[str, dict], params, *,
         dtype=None) -> tuple[dict, dict]:
    """Build one LayerPlan per plan-bearing node (every per-layer decision
    and every filter transform happens here, once) and collect the epilogue
    constants (biases, dense weights) the graph executor feeds them."""
    plans: dict[str, Any] = {}
    consts: dict[str, jax.Array] = {}

    def const(nid, tag, path):
        if path is not None:
            consts[f"{nid}.{tag}"] = jnp.asarray(_param(params, path))

    for node in graph:
        a = node.attrs
        in_shape = shapes[node.inputs[0]] if node.inputs else None
        if node.op == "conv2d":
            pl = placements[node.id]
            plans[node.id] = _plan.plan_conv2d(
                in_shape, _param(params, a["w_path"]),
                stride=tuple(a["stride"]), padding=a["padding"],
                groups=pl["groups"], algorithm=pl["algorithm"], dtype=dtype,
                compute_dtype=pl.get("compute_dtype", "float32"))
            const(node.id, "b", a.get("b_path"))
        elif node.op == "separable":
            pl = placements[node.id]
            plans[node.id] = _plan.plan_separable_block(
                in_shape, _param(params, a["dw_w"]),
                _param(params, a["pw_w"]), stride=tuple(a["stride"]),
                padding=a["padding"],
                algorithm=pl["algorithm"], dtype=dtype,
                compute_dtype=pl.get("compute_dtype", "float32"))
            const(node.id, "b_dw", a.get("dw_b"))
            const(node.id, "b_pw", a.get("pw_b"))
        elif node.op == "inverted_residual":
            pl = placements[node.id]
            p = _plan.plan_inverted_residual(
                in_shape,
                _param(params, a["exp_w"]) if a.get("exp_w") else None,
                _param(params, a["dw_w"]), _param(params, a["pw_w"]),
                stride=tuple(a["stride"]), padding=a["padding"],
                algorithm=pl["algorithm"], dtype=dtype,
                compute_dtype=pl.get("compute_dtype", "float32"))
            if p.residual != a["residual"]:
                # the graph is the source of truth for the skip edge (a
                # hand-built IR may omit the add even where shapes allow it)
                p = dataclasses.replace(p, residual=a["residual"])
            plans[node.id] = p
            const(node.id, "b_exp", a.get("exp_b"))
            const(node.id, "b_dw", a.get("dw_b"))
            const(node.id, "b_pw", a.get("pw_b"))
        elif node.op == "conv1d":
            plans[node.id] = _plan.plan_conv1d(
                in_shape, _param(params, a["w_path"]), stride=a["stride"],
                padding=a["padding"],
                algorithm=placements[node.id]["algorithm"])
            const(node.id, "b", a.get("b_path"))
        elif node.op == "dense":
            const(node.id, "w", a["w_path"])
    return plans, consts


# ---------------------------------------------------------------------------
# NetworkPlan: the compiled, executable, serializable network
# ---------------------------------------------------------------------------

def _pool_apply(x, a):
    from repro.models.layers import pool2d
    return pool2d(x, a["kind"], a["k"], a["stride"], a["padding"])


#: attrs keys that are tuples in memory but lists in the JSON header.
_TUPLE_ATTRS = ("stride", "w_path", "b_path", "dw_w", "dw_b", "pw_w",
                "pw_b", "exp_w", "exp_b")


def _node_to_json(n: LayerIR) -> dict:
    attrs = {k: (list(v) if isinstance(v, tuple) else v)
             for k, v in n.attrs.items()}
    return {"id": n.id, "op": n.op, "inputs": list(n.inputs),
            "attrs": attrs, "block": n.block}


def _node_from_json(d: dict) -> LayerIR:
    attrs = dict(d["attrs"])
    for k in _TUPLE_ATTRS:
        if isinstance(attrs.get(k), list):
            attrs[k] = tuple(attrs[k])
    return LayerIR(id=d["id"], op=d["op"], inputs=tuple(d["inputs"]),
                   attrs=attrs, block=d.get("block"))


def _plan_weight_arrays(p) -> list[jax.Array]:
    """The execution-domain weight arrays a bound LayerPlan holds (what
    plan build materializes; benchmarks block_until_ready on these)."""
    if isinstance(p, _plan.ConvPlan) or isinstance(
            p, _plan.DepthwiseConv1DPlan):
        scale = getattr(p, "scale", None)
        return [p.u] if scale is None else [p.u, scale]
    if isinstance(p, _plan.SeparableBlockPlan):
        if p.mode == "fused_pallas":
            return [p.u_dw, p.u_pw]
        return _plan_weight_arrays(p.dw) + _plan_weight_arrays(p.pw)
    if isinstance(p, _plan.InvertedResidualPlan):
        out = _plan_weight_arrays(p.sep)
        if p.expand is not None:
            out = _plan_weight_arrays(p.expand) + out
        return out
    if isinstance(p, _plan.Conv1DPlan):
        if p.mode in ("as2d", "im2col"):
            return _plan_weight_arrays(p.inner)
        return [a for s in p.subplans for a in _plan_weight_arrays(s)]
    raise TypeError(f"not a LayerPlan: {type(p)!r}")


@dataclasses.dataclass
class NetworkPlan:
    """A compiled network: the layer IR, one bound LayerPlan per
    plan-bearing node, and the epilogue constants. apply(x) executes the
    graph with zero per-call filter-transform or geometry work; save/load
    round-trips the whole thing (pre-transformed weights + per-layer
    algorithm decisions) through a versioned artifact -- the paper's
    ship-transformed-weights deployment path.

    Also behaves as a read-only mapping from layer name to its bound plan
    (`net["conv1"]`, `net.values()`, ...) for compatibility with the
    pre-compiler plan_cnn dict."""

    graph: tuple[LayerIR, ...]
    plans: dict[str, Any]
    consts: dict[str, jax.Array]
    input_shape: tuple[int, ...]
    algorithm: str
    dtype: str
    compute_dtype: str = "float32"     # requested transform-domain policy;
                                       # per-layer outcomes (fallbacks, the
                                       # auto_tuned race) live in each
                                       # plan's describe()
    build_time_s: float = 0.0
    params_digest: str | None = None   # digest of the raw params the plan
                                       # was compiled from; compile(artifact=)
                                       # refuses to warm-start from weights
                                       # that have since changed
    partition: dict | None = None      # partition record (see
                                       # core/partition.py); plans are bound
                                       # at shard-local geometry when
                                       # num_shards > 1. Persisted in the
                                       # artifact header.
    mesh: Any = dataclasses.field(default=None, repr=False, compare=False)
                                       # live jax.sharding.Mesh; NEVER
                                       # serialized -- load() leaves it None,
                                       # with_mesh() re-attaches one.

    # ---- execution -------------------------------------------------------

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)

    def is_sharded(self) -> bool:
        return (self.partition is not None
                and self.partition.get("num_shards", 1) > 1)

    def with_mesh(self, mesh) -> "NetworkPlan":
        """Attach a device mesh to a partitioned plan (artifacts do not
        serialize meshes). Validates the mesh's partition axis against the
        recorded shard count; returns self."""
        if self.partition is None:
            raise ValueError(
                "this NetworkPlan was compiled without a partition; "
                "recompile with compile(mesh=...) to shard it")
        axis, n = _partition.mesh_num_shards(mesh)
        want = self.partition["num_shards"]
        if self.is_sharded() and (axis != self.partition["axis"]
                                  or n != want):
            raise ValueError(
                f"mesh axis {axis!r} x{n} does not match the recorded "
                f"partition ({self.partition['axis']!r} x{want}); build a "
                f"matching mesh (launch.mesh.make_data_mesh({want})) or "
                f"recompile with mesh=")
        self.mesh = mesh
        self.invalidate_executables()
        return self

    def invalidate_executables(self) -> None:
        """Drop cached jitted/sharded callables. Anything that swaps a
        bound plan object (replace_layer, the fault-injection harness)
        must call this, or a jitted program keeps executing the old
        closure."""
        self.__dict__.pop("_sharded_fn", None)

    def _sharded_callable(self):
        fn = self.__dict__.get("_sharded_fn")
        if fn is None:
            fn = _partition.build_sharded_fn(self)
            self.__dict__["_sharded_fn"] = fn
        return fn

    def apply(self, x: jax.Array, *, layer_hook=None,
              annotate_errors: bool = False) -> jax.Array:
        """Execute the graph. `layer_hook(node_id, seconds)` is called after
        every plan-bearing node with its synchronous wall time (the result
        is block_until_ready'd first -- eager-mode only; do not jit an apply
        with a hook installed). `annotate_errors=True` wraps any exception a
        node raises in LayerExecutionError carrying the node id, so a
        serving supervisor can re-place exactly the failing layer.

        A plan compiled with a partition over >1 shards routes through the
        jitted shard_map program instead of the eager walk (hooks and error
        annotation need the single-logical-device plan)."""
        if self.is_sharded():
            if layer_hook is not None or annotate_errors:
                raise ValueError(
                    "layer_hook / annotate_errors need the eager "
                    "single-device walk, but this plan is partitioned "
                    f"({self.partition['kind']} x"
                    f"{self.partition['num_shards']}); compile without "
                    "mesh= for supervised execution")
            if self.mesh is None:
                raise ValueError(
                    f"this NetworkPlan records a {self.partition['kind']} "
                    f"partition over {self.partition['num_shards']} shards "
                    f"but no mesh is attached (artifacts never serialize "
                    f"device meshes); call "
                    f".with_mesh(launch.mesh.make_data_mesh("
                    f"{self.partition['num_shards']})) first")
            return self._sharded_callable()(x)
        return self._eval_graph(x, layer_hook=layer_hook,
                                annotate_errors=annotate_errors)

    def _eval_graph(self, x: jax.Array, *, layer_hook=None,
                    annotate_errors: bool = False) -> jax.Array:
        """The eager graph walk (also the shard_map body of a data-parallel
        partition, where each shard evaluates its local batch)."""
        # Liveness: drop each activation after its last consumer runs, so
        # eager execution holds only the live frontier (as the spec-walk
        # interpreter did), not every feature map of the whole network.
        remaining = {nid: len(cons)
                     for nid, cons in _consumers(self.graph).items()}
        env = {"input": x}
        c = self.consts
        for node in self.graph[1:]:
            a = node.attrs
            v = env[node.inputs[0]] if node.inputs else None
            t0 = (time.perf_counter()
                  if layer_hook is not None and node.id in self.plans
                  else None)
            try:
                y = self._eval_node(node, a, v, env, c)
            except Exception as e:
                if annotate_errors and not isinstance(e, LayerExecutionError):
                    raise LayerExecutionError(node.id, e) from e
                raise
            if t0 is not None:
                jax.block_until_ready(y)
                layer_hook(node.id, time.perf_counter() - t0)
            env[node.id] = y
            for i in node.inputs:
                remaining[i] -= 1
                if remaining[i] == 0:
                    del env[i]
        return env[self.graph[-1].id]

    def _eval_node(self, node, a, v, env, c):
            if node.op == "conv2d":
                y = self.plans[node.id].apply(
                    v, bias=c.get(f"{node.id}.b"),
                    activation=a["activation"])
            elif node.op == "separable":
                y = self.plans[node.id].apply(
                    v, bias_dw=c.get(f"{node.id}.b_dw"),
                    bias_pw=c.get(f"{node.id}.b_pw"),
                    inner_activation=a["inner_activation"],
                    activation=a["activation"])
            elif node.op == "inverted_residual":
                y = self.plans[node.id].apply(
                    v, bias_exp=c.get(f"{node.id}.b_exp"),
                    bias_dw=c.get(f"{node.id}.b_dw"),
                    bias_pw=c.get(f"{node.id}.b_pw"),
                    activation=a["activation"])
            elif node.op == "conv1d":
                y = self.plans[node.id].apply(
                    v, bias=c.get(f"{node.id}.b"),
                    activation=a["activation"])
            elif node.op == "pool":
                y = _pool_apply(v, a)
            elif node.op == "concat":
                y = jnp.concatenate([env[i] for i in node.inputs], axis=-1)
            elif node.op == "add":
                y = env[node.inputs[0]] + env[node.inputs[1]]
            elif node.op == "global_avg_pool":
                y = jnp.mean(v, axis=(1, 2))
            elif node.op == "dense":
                from repro.models.layers import dense_head
                y = dense_head(v, c[f"{node.id}.w"], a["relu"])
            else:
                raise ValueError(f"unknown IR op {node.op!r} ({node.id})")
            return y

    @property
    def out_shape(self) -> tuple[int, ...]:
        return infer_shapes(self.graph, self.input_shape)[self.graph[-1].id]

    def weight_arrays(self) -> list[jax.Array]:
        """Every bound execution-domain array (plan weights + epilogue
        constants) -- jax.block_until_ready(net.weight_arrays()) fences the
        whole plan build."""
        out = [a for p in self.plans.values()
               for a in _plan_weight_arrays(p)]
        return out + list(self.consts.values())

    def replace_layer(self, node_id: str, params, *,
                      algorithm: str = "im2col",
                      compute_dtype: str = "float32") -> Any:
        """Re-place ONE plan-bearing node onto a different algorithm family
        (and/or transform-domain compute dtype) and re-bind its plan (and
        epilogue constants) from the raw params -- the serving supervisor's
        degrade path when a layer's executor misbehaves, and its precision
        promotion path when a reduced-precision layer trips the accuracy
        probe (compute_dtype="float32" is the always-safe landing spot).
        The replacement is a capability-registry placement, exactly like
        compile-time place(): an algorithm the registry does not cover for
        this layer raises the registry's resolution error. Returns the
        freshly bound plan. `params` must be the pytree the network was
        compiled from (checked against params_digest when the plan carries
        one)."""
        if self.is_sharded():
            raise ValueError(
                "replace_layer operates on single-logical-device plans "
                f"(this one is partitioned {self.partition['kind']} x"
                f"{self.partition['num_shards']}); supervisor repairs run "
                "on the unsharded plan, which is then recompiled with "
                "mesh= if sharding should resume")
        by_id = {n.id: n for n in self.graph}
        node = by_id.get(node_id)
        if node is None or node.op not in PLAN_OPS:
            raise ValueError(
                f"{node_id!r} is not a plan-bearing node; replaceable "
                f"layers: {sorted(self.plans)}")
        if self.params_digest is not None \
                and params_digest(params) != self.params_digest:
            raise ValueError(
                "params do not match the weights this NetworkPlan was "
                "compiled from (params_digest mismatch); re-placement from "
                "foreign weights would silently change the served model")
        shapes = infer_shapes(self.graph, self.input_shape)
        a = node.attrs
        if node.op == "conv2d":
            c_in = shapes[node.inputs[0]][-1]
            groups = c_in if a.get("depthwise") else a["groups"]
            q = registry.as_query(a["kh"], a["kw"], tuple(a["stride"]),
                                  groups=groups, c_in=c_in, c_out=a["c_out"])
            if not registry.supported(algorithm, q):
                raise registry.resolution_error(algorithm, q)
            placement = {"algorithm": algorithm, "groups": groups,
                         "compute_dtype": compute_dtype}
        else:
            placement = {"algorithm": algorithm,
                         "compute_dtype": compute_dtype}
        plans, consts = bind((node,), shapes, {node_id: placement}, params,
                             dtype=self.dtype)
        self.plans.update(plans)
        self.consts.update(consts)
        self.invalidate_executables()
        return self.plans[node_id]

    # ---- mapping compatibility (the old plan_cnn dict interface) ---------

    def __getitem__(self, key: str):
        return self.plans[key]

    def get(self, key: str, default=None):
        return self.plans.get(key, default)

    def __contains__(self, key: str) -> bool:
        return key in self.plans

    def __iter__(self) -> Iterator[str]:
        return iter(self.plans)

    def __len__(self) -> int:
        return len(self.plans)

    def keys(self):
        return self.plans.keys()

    def values(self):
        return self.plans.values()

    def items(self):
        return self.plans.items()

    # ---- describe --------------------------------------------------------

    def describe(self) -> str:
        """The per-layer algorithm table, rendered through the SAME
        markdown generator as the registry's README capability table
        (repro.core.registry.markdown_table) -- drift-tested."""
        shapes = infer_shapes(self.graph, self.input_shape)
        rows = []
        for node in self.graph:
            if node.id not in self.plans:
                continue
            d = self.plans[node.id].describe()
            rows.append((node.id, d["kind"], f"`{d['executor']}`",
                         d["filter"], d["stride"], d["groups"], d["tile"],
                         d.get("compute_dtype", "float32"),
                         d.get("decision", "static"),
                         "x".join(map(str, shapes[node.id]))))
        return registry.markdown_table(
            ["layer", "kind", "executor", "filter", "stride", "groups",
             "tile", "compute", "decision", "output"], rows)

    # ---- serialization ---------------------------------------------------

    def save(self, path: str) -> None:
        """Serialize the compiled network: a versioned JSON header (graph,
        per-layer plan metas, dtype/layout/registry-fingerprint cache keys)
        plus every execution-domain weight array, in one .npz file. A
        second process NetworkPlan.load()s this and starts warm: no
        re-planning, no re-measuring, no filter-transform work."""
        header = {
            "format": ARTIFACT_FORMAT,
            "version": ARTIFACT_VERSION,
            "registry_fingerprint": registry.fingerprint(),
            "jax_version": jax.__version__,
            "dtype": self.dtype,
            "compute_dtype": self.compute_dtype,
            "layout": "NHWC",
            "input_shape": list(self.input_shape),
            "algorithm": self.algorithm,
            "params_digest": self.params_digest,
            "partition": self.partition,
            "graph": [_node_to_json(n) for n in self.graph],
            "plans": {},
        }
        arrays: dict[str, np.ndarray] = {}
        for nid, p in self.plans.items():
            meta, arr = p.to_artifact()
            header["plans"][nid] = meta
            for k, v in arr.items():
                arrays[f"plan:{nid}:{k}"] = v
        for k, v in self.consts.items():
            arrays[f"const:{k}"] = np.asarray(v)
        # Per-array integrity digests: load() re-hashes every array against
        # these, so silent corruption between save and load is detected
        # instead of silently serving wrong outputs.
        header["checksums"] = {k: _array_digest(v) for k, v in arrays.items()}
        arrays["__header__"] = np.array(json.dumps(header))
        # atomic emit: a crash mid-write must never leave a truncated file
        # at the final path (a corrupt artifact would poison every later
        # warm start until manually deleted).
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    @classmethod
    def load(cls, path: str, *, expect_dtype=None,
             expect_layout: str | None = None,
             _record: bool = True) -> "NetworkPlan":
        """Load a saved artifact. Refuses -- with the mismatch and the fix
        spelled out -- when the header does not match this build: wrong
        format or version, a capability registry whose fingerprint changed
        since the plan was compiled (its per-layer executor decisions may
        be stale), or a dtype/layout other than the caller expects.
        Successful loads count as artifact hits in plan_cache_info()
        (compile(artifact=) passes _record=False and does its own
        one-hit-or-one-miss accounting per warm-start attempt)."""
        fix = ("; recompile with repro.core.compile.compile(...) and "
               "save() a fresh artifact")

        def refuse(msg: str) -> ArtifactMismatchError:
            if _record:
                _plan.record_artifact_load(False)
            return ArtifactMismatchError(msg + fix)

        with np.load(path, allow_pickle=False) as data:
            if "__header__" not in data:
                raise refuse(f"{path} is not a serialized NetworkPlan "
                             f"(no header)")
            header = json.loads(str(data["__header__"][()]))
            if header.get("format") != ARTIFACT_FORMAT:
                raise refuse(
                    f"{path} has format {header.get('format')!r}, expected "
                    f"{ARTIFACT_FORMAT!r}")
            if header.get("version") != ARTIFACT_VERSION:
                raise refuse(
                    f"{path} is artifact version {header.get('version')}, "
                    f"this build reads version {ARTIFACT_VERSION}")
            if header.get("registry_fingerprint") != registry.fingerprint():
                raise refuse(
                    f"{path} was compiled against capability registry "
                    f"{header.get('registry_fingerprint')}, but this "
                    f"build's registry is {registry.fingerprint()} -- the "
                    f"saved per-layer executor decisions may be stale")
            if expect_dtype is not None and str(
                    jnp.dtype(expect_dtype)) != header.get("dtype"):
                report = _artifact_dtype_report(header)
                raise refuse(
                    f"{path} holds {header.get('dtype')} weights, caller "
                    f"expects {jnp.dtype(expect_dtype)}"
                    + (f"; per-layer transform-domain compute dtypes on "
                       f"disk vs this registry: {report}" if report else ""))
            if header.get("layout") not in registry.LAYOUTS or (
                    expect_layout is not None
                    and expect_layout != header.get("layout")):
                raise refuse(
                    f"{path} uses layout {header.get('layout')!r}, "
                    f"expected {expect_layout or '/'.join(registry.LAYOUTS)}")
            checksums = header.get("checksums", {})
            payload = [k for k in data.files if k != "__header__"]
            missing = sorted(set(checksums) - set(payload))
            if missing:
                raise refuse(
                    f"{path} is missing array(s) {missing} recorded in its "
                    f"integrity header -- the artifact is truncated or "
                    f"corrupt")
            for k in payload:
                expect = checksums.get(k)
                if expect is None or _array_digest(data[k]) != expect:
                    raise refuse(
                        f"{path} array {k!r} fails its sha256 integrity "
                        f"digest -- the artifact is corrupt on disk")
            graph = tuple(_node_from_json(d) for d in header["graph"])
            plans = {}
            for nid, meta in header["plans"].items():
                arrays = {k.split(":", 2)[2]: data[k] for k in data.files
                          if k.startswith(f"plan:{nid}:")}
                plans[nid] = _plan.plan_from_artifact(meta, arrays)
            consts = {k[len("const:"):]: jnp.asarray(data[k])
                      for k in data.files if k.startswith("const:")}
        if _record:
            _plan.record_artifact_load(True)
        return cls(graph=graph, plans=plans, consts=consts,
                   input_shape=tuple(header["input_shape"]),
                   algorithm=header["algorithm"], dtype=header["dtype"],
                   compute_dtype=header.get("compute_dtype", "float32"),
                   params_digest=header.get("params_digest"),
                   partition=header.get("partition"))


def verify_artifact(path: str) -> list[str]:
    """Integrity-check a saved NetworkPlan artifact against its per-array
    sha256 digests WITHOUT loading it as a plan. Returns the names of the
    offending arrays (missing from the file, or failing their digest), or
    `["__header__"]` when the file itself is unreadable / has no integrity
    header -- an empty list means the artifact is intact. The serving
    supervisor runs this to decide between 'executor bug' (artifact intact,
    re-place the layer) and 'corrupt artifact' (recompile in place)."""
    try:
        with np.load(path, allow_pickle=False) as data:
            if "__header__" not in data:
                return ["__header__"]
            header = json.loads(str(data["__header__"][()]))
            checksums = header.get("checksums")
            if not isinstance(checksums, dict):
                return ["__header__"]
            payload = [k for k in data.files if k != "__header__"]
            bad = sorted(set(checksums) - set(payload))
            for k in payload:
                expect = checksums.get(k)
                if expect is None or _array_digest(data[k]) != expect:
                    bad.append(k)
            return bad
    except _ARTIFACT_FALLBACK_ERRORS:
        return ["__header__"]


# ---------------------------------------------------------------------------
# compile: the entry point
# ---------------------------------------------------------------------------

def params_digest(params) -> str:
    """Order-independent digest of a params pytree (dict-of-dicts of
    arrays): key paths + shapes + raw bytes. compile(artifact=) stamps this
    into the artifact and refuses to warm-start from an artifact whose
    weights no longer match the params in hand (e.g. after retraining)."""
    h = hashlib.sha256()

    def walk(node, prefix):
        if isinstance(node, Mapping):
            for k in sorted(node):
                walk(node[k], f"{prefix}/{k}")
            return
        a = np.asarray(node)
        h.update(f"{prefix}:{a.dtype}:{a.shape}".encode())
        h.update(np.ascontiguousarray(a).tobytes())

    walk(params, "")
    return h.hexdigest()[:16]


#: Errors a warm-start attempt treats as "artifact unusable, recompile":
#: header mismatches, plus anything a truncated / corrupt / foreign file
#: can raise out of np.load or the header parse. Genuine bugs (TypeError,
#: AssertionError, ...) still propagate.
_ARTIFACT_FALLBACK_ERRORS = (ArtifactMismatchError, OSError, EOFError,
                             KeyError, ValueError, zipfile.BadZipFile,
                             json.JSONDecodeError)


def _try_load_artifact(path: str, *, input_shape, algorithm, digest: str,
                       dtype=None,
                       compute_dtype: str = "float32",
                       mesh=None, partition: str | None = None
                       ) -> "NetworkPlan | None":
    """The compile(artifact=) warm-start attempt: load without counting,
    then validate the artifact against THIS call's arguments -- input
    shape, algorithm request, params digest, compute_dtype policy, the
    partition request (kind + shard count vs the recorded record), and
    (when explicitly requested) dtype -- so a stale artifact (different
    resolution, different policy, retrained weights, other precision or
    mesh shape) recompiles instead of silently serving old decisions.
    A partition-matched artifact gets the caller's mesh attached; its
    recorded modes/halos are used verbatim (no re-deciding). Returns
    None when the artifact is unusable; the caller does the one-miss
    accounting."""
    try:
        loaded = NetworkPlan.load(path, _record=False)
    except _ARTIFACT_FALLBACK_ERRORS:
        return None
    if (loaded.input_shape != tuple(input_shape)
            or loaded.algorithm != algorithm
            or loaded.params_digest != digest
            or loaded.compute_dtype != compute_dtype
            or (dtype is not None
                and loaded.dtype != str(jnp.dtype(dtype)))):
        return None
    part = loaded.partition
    if mesh is None:
        if part is not None:
            return None
    else:
        axis, n = _partition.mesh_num_shards(mesh)
        want_kind = partition or "data"
        if (part is None or part["kind"] != want_kind
                or part["axis"] != axis
                or part.get("requested_shards", part["num_shards"]) != n):
            return None
        loaded.mesh = mesh
    return loaded


def _plans_dtype(plans: dict) -> str:
    for p in plans.values():
        spec = getattr(p, "spec", None)
        if spec is not None and getattr(spec, "dtype", None):
            return spec.dtype
        inner = getattr(p, "inner", None) or getattr(p, "expand", None) \
            or getattr(p, "sep", None)
        if inner is not None:
            d = _plans_dtype({"_": inner})
            if d:
                return d
    return "float32"


def _bind_partitioned(ir, shapes, placements, params, part: dict,
                      dtype) -> tuple[dict, dict]:
    """bind() under a partition record: data-parallel plans bind at the
    local batch; spatial halo-mode plans bind VALID at their exchanged
    local strip; full-mode (re-gathered) nodes bind at the global shape."""
    if part["kind"] == "data":
        return bind(ir, _partition.local_bind_shapes(part, shapes),
                    placements, params, dtype=dtype)
    plans: dict[str, Any] = {}
    consts: dict[str, jax.Array] = {}
    modes = part["modes"]
    for node in ir:
        if not node.inputs:
            continue
        if node.op in PLAN_OPS and modes.get(node.id) == "halo":
            node_v = dataclasses.replace(
                node, attrs={**node.attrs, "padding": "VALID"})
            in_shape = _partition.spatial_halo_in_shape(part, node, shapes)
            p, cs = bind((node_v,), {node.inputs[0]: in_shape}, placements,
                         params, dtype=dtype)
        elif node.op in PLAN_OPS or node.op == "dense":
            p, cs = bind((node,), {node.inputs[0]: shapes[node.inputs[0]]},
                         placements, params, dtype=dtype)
        else:
            continue
        plans.update(p)
        consts.update(cs)
    return plans, consts


def compile(params, graph, *, res: int | None = None, c_in: int = 3,
            batch: int = 1, algorithm: str = "auto",
            input_shape: Sequence[int] | None = None, dtype=None,
            compute_dtype: str = "float32",
            artifact: str | None = None,
            mesh=None, partition: str | None = None) -> NetworkPlan:
    """Compile a network description into one NetworkPlan.

    `graph` is either a models/cnn.py spec list (lowered to the layer IR
    here) or a pre-lowered tuple of LayerIR nodes (e.g.
    models/audio.py:stem_graph). The pass pipeline runs
    lower -> fuse -> place -> bind: composite blocks are reconstituted by
    registry-aware pattern rewrites (dw+pw -> separable,
    expand+dw+project[+residual] -> inverted residual), each node gets its
    algorithm via capability-registry queries, and every per-layer decision
    plus every filter transform happens exactly once, here.

    `res` describes an image network's (batch, res, res, c_in) input;
    sequence networks pass `input_shape` instead. `algorithm` is the global
    request (plan.ALGORITHMS); uncovered layers fall back to im2col, the
    paper's mixed policy.

    `compute_dtype` is the network-level transform-domain precision policy
    ("float32" / "bfloat16" / "int8"): reduced dtypes quantize/cast each
    conv layer's transform-domain filter at bind time (per-output-channel
    scales folded into the epilogue); layers whose covering executors do
    not declare the dtype are placed back at fp32, the same per-layer
    fallback shape as the algorithm request. The policy is persisted in
    the artifact header, and a warm start requires it to match.

    With `artifact=path`, compile() first tries NetworkPlan.load(path) and
    validates the artifact against THIS call (input shape, algorithm,
    params digest, partition request) -- a usable artifact is the warm
    start (one artifact hit in plan_cache_info()); a missing, corrupt,
    header-mismatched, or argument-stale artifact falls back to a cold
    compile whose result is saved back to `path` (one artifact miss).

    With `mesh=` (a jax.sharding.Mesh), the plan executes sharded over the
    mesh's "data" axis: `partition="data"` (the default) shards the batch
    dim with weights replicated; `partition="spatial"` splits H across
    devices with per-layer halo exchange / re-gather decisions recorded in
    the plan's partition record (core/partition.py). Indivisible batches
    or heights degrade to a replicated single-logical-device plan with the
    reason recorded -- never an error. The record persists in version-5
    artifacts so warm starts restore the partitioning without re-deciding;
    the mesh itself is re-attached per process (it never serializes).
    """
    t0 = time.perf_counter()
    if partition is not None:
        if mesh is None:
            raise ValueError(
                f"partition={partition!r} needs mesh= (a jax.sharding.Mesh "
                f"with a 'data' axis; see launch.mesh.make_data_mesh)")
        if partition not in ("data", "spatial"):
            raise ValueError(f"unknown partition {partition!r}; expected "
                             f"'data' or 'spatial'")
    if input_shape is None:
        if res is None:
            raise ValueError("compile() needs res= (image networks, "
                             "input (batch, res, res, c_in)) or "
                             "input_shape=")
        input_shape = (batch, res, res, c_in)
    input_shape = tuple(input_shape)
    if algorithm not in _plan.ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; expected one "
                         f"of {_plan.ALGORITHMS}")
    compute_dtype = str(jnp.dtype(compute_dtype))
    if compute_dtype not in registry.COMPUTE_DTYPES:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                         f"expected one of {registry.COMPUTE_DTYPES}")
    digest = params_digest(params) if artifact is not None else None
    if artifact is not None and os.path.exists(artifact):
        with _obs_trace.span("compile.artifact_load", path=artifact):
            loaded = _try_load_artifact(
                artifact, input_shape=input_shape, algorithm=algorithm,
                digest=digest, dtype=dtype, compute_dtype=compute_dtype,
                mesh=mesh, partition=partition)
        if loaded is not None:
            _plan.record_artifact_load(True)
            return loaded
    with _obs_trace.span("compile.lower"):
        ir = tuple(graph) if _is_ir(graph) else lower(graph,
                                                      c_in=input_shape[-1])
    with _obs_trace.span("compile.fuse") as _sp:
        ir = fuse(ir)
        _sp.set(nodes=len(ir))
    with _obs_trace.span("compile.infer_shapes"):
        shapes = infer_shapes(ir, input_shape)
    with _obs_trace.span("compile.place", algorithm=algorithm):
        placements = place(ir, shapes, algorithm, compute_dtype)
    part = None
    if mesh is not None:
        with _obs_trace.span("compile.decide_partition"):
            axis, n = _partition.mesh_num_shards(mesh)
            part = _partition.decide_partition(ir, shapes, n,
                                               partition or "data", axis)
    with _obs_trace.span("compile.bind",
                         partitioned=bool(part
                                          and part["num_shards"] > 1)):
        if part is not None and part["num_shards"] > 1:
            plans, consts = _bind_partitioned(ir, shapes, placements,
                                              params, part, dtype)
        else:
            plans, consts = bind(ir, shapes, placements, params,
                                 dtype=dtype)
    net = NetworkPlan(
        graph=ir, plans=plans, consts=consts, input_shape=input_shape,
        algorithm=algorithm,
        dtype=str(jnp.dtype(dtype)) if dtype else _plans_dtype(plans),
        compute_dtype=compute_dtype,
        build_time_s=time.perf_counter() - t0, params_digest=digest,
        partition=part, mesh=mesh)
    if artifact is not None:
        _plan.record_artifact_load(False)
        with _obs_trace.span("compile.artifact_save", path=artifact):
            net.save(artifact)
    return net
