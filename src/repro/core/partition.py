"""Multi-device partitioning of compiled NetworkPlans.

Two partition kinds over a 1-D ("data",) mesh axis:

  * "data" -- data-parallel batch sharding: the batch dim splits across
    devices, weights replicate, and every shard runs the exact same
    streamed Pallas kernels at the local batch. Legal whenever the batch
    divides the axis; otherwise the plan degrades to replication (a
    single-logical-device plan) with the reason recorded.
  * "spatial" -- halo partitioning of H for large-resolution inputs: each
    device owns a contiguous strip of output rows. Stride-1 SAME odd-k
    convs (dense/depthwise/separable, and residual-free inverted-residual
    blocks) run on their strip after exchanging (k-1)//2 halo rows with
    mesh neighbors (`jax.lax.ppermute`; edge shards receive zeros, which
    IS the SAME zero padding) -- the same overlap the streamed kernels'
    halo-strip BlockSpecs derive per tile. Layers the walk cannot keep
    row-local (stride-2, pooling, residual adds against a haloed input)
    re-gather the full plane at a recorded cut point and re-shard after
    when the new H still divides the axis.

`decide_partition` is a pure function over the layer IR + global shapes:
it emits a JSON-serializable record (modes, halos, re-scatter points,
per-node shardedness) that compile() persists in version-5 artifacts, so
a warm start restores the recorded partitioning without re-deciding.
`build_sharded_fn` turns a partitioned NetworkPlan + attached mesh into
the jitted shard_map program `NetworkPlan.apply` routes through.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.plan import spatial_halo
from repro.distributed.sharding import (data_axis_name, gather_rows,
                                        halo_exchange, scatter_rows,
                                        shard_map)


def mesh_num_shards(mesh) -> tuple[str, int]:
    """(axis_name, size) of the partition axis of a NetworkPlan mesh."""
    axis = data_axis_name(mesh)
    return axis, int(mesh.shape[axis])


def _degraded(kind: str, axis: str, requested: int, reason: str) -> dict:
    return {"kind": kind, "axis": axis, "num_shards": 1,
            "requested_shards": requested, "degraded": reason}


def decide_partition(graph: Sequence, shapes: dict[str, tuple[int, ...]],
                     num_shards: int, kind: str = "data",
                     axis: str = "data") -> dict:
    """Decide how a lowered+fused graph partitions over `num_shards`.

    Pure IR walk (no device state), so it unit-tests without a mesh. The
    returned record is everything the sharded executor needs; degradation
    to replication (num_shards=1 + reason) is a record, not an error --
    indivisible batches/heights must keep serving.
    """
    if kind not in ("data", "spatial"):
        raise ValueError(f"unknown partition kind {kind!r}; expected "
                         f"'data' or 'spatial'")
    in_shape = shapes["input"]
    if num_shards <= 1:
        return _degraded(kind, axis, num_shards, "single-device mesh axis")

    if kind == "data":
        b = in_shape[0]
        if b % num_shards:
            return _degraded(
                kind, axis, num_shards,
                f"batch {b} does not divide over {num_shards} shards")
        return {"kind": "data", "axis": axis, "num_shards": num_shards,
                "requested_shards": num_shards, "degraded": None}

    # -- spatial: walk the graph deciding a mode per node -------------------
    if len(in_shape) != 4:
        return _degraded(kind, axis, num_shards,
                         f"spatial partitioning needs NHWC input, got "
                         f"{in_shape}")
    if in_shape[1] % num_shards:
        return _degraded(
            kind, axis, num_shards,
            f"H={in_shape[1]} does not divide over {num_shards} shards")

    sharded: dict[str, bool] = {"input": True}
    modes: dict[str, str] = {}
    halo: dict[str, int] = {}
    rescatter: dict[str, bool] = {}

    def halo_ok(node, k: int, stride, padding) -> bool:
        s_in = shapes[node.inputs[0]]
        local_h = s_in[1] // num_shards
        return (sharded[node.inputs[0]] and tuple(stride) == (1, 1)
                and padding == "SAME" and k % 2 == 1
                and spatial_halo(k) <= local_h)

    for node in graph[1:]:
        a = node.attrs
        ins = node.inputs
        if node.op == "conv2d":
            if a["kh"] == a["kw"] and halo_ok(node, a["kh"], a["stride"],
                                              a["padding"]):
                modes[node.id] = "halo"
                halo[node.id] = spatial_halo(a["kh"])
                sharded[node.id] = True
                continue
        elif node.op == "separable":
            if halo_ok(node, a["k"], a["stride"], a["padding"]):
                modes[node.id] = "halo"
                halo[node.id] = spatial_halo(a["k"])
                sharded[node.id] = True
                continue
        elif node.op == "inverted_residual":
            # The residual add happens inside the block plan against the
            # (haloed) block input -- shapes no longer line up, so residual
            # blocks re-gather instead.
            if not a["residual"] and halo_ok(node, a["k"], a["stride"],
                                             a["padding"]):
                modes[node.id] = "halo"
                halo[node.id] = spatial_halo(a["k"])
                sharded[node.id] = True
                continue
        elif node.op == "global_avg_pool":
            if sharded[ins[0]]:
                # local spatial mean + pmean over equal-height strips is
                # exactly the global mean; output is replicated.
                modes[node.id] = "reduce"
                sharded[node.id] = False
                continue
        elif node.op in ("concat", "add"):
            if all(sharded[i] for i in ins):
                modes[node.id] = "local"
                sharded[node.id] = True
                continue
        elif node.op in ("dense",):
            if not sharded[ins[0]]:
                modes[node.id] = "local"      # replicated in, replicated out
                sharded[node.id] = False
                continue

        # Everything else (strided/even-k convs, pooling, conv1d, mixed
        # concat inputs, dense over a sharded map): re-gather the full
        # plane, evaluate at the global shape, and re-shard the output
        # when its H still divides the axis -- a recorded graph cut point.
        modes[node.id] = "full"
        s_out = shapes[node.id]
        re = len(s_out) == 4 and s_out[1] % num_shards == 0
        rescatter[node.id] = re
        sharded[node.id] = re

    out_id = graph[-1].id
    return {"kind": "spatial", "axis": axis, "num_shards": num_shards,
            "requested_shards": num_shards, "degraded": None,
            "modes": modes, "halo": halo, "rescatter": rescatter,
            "sharded": sharded, "out_sharded": bool(sharded[out_id])}


def local_bind_shapes(partition: dict,
                      shapes: dict[str, tuple[int, ...]]) -> dict:
    """Per-node *plan-binding* input geometry under a partition.

    data: every shape carries the local batch. spatial: halo-mode nodes
    bind at their exchanged local strip (H/D + 2p rows, W + 2p cols --
    the conv runs VALID over it); everything else binds at the global
    shape (full-mode nodes evaluate gathered)."""
    d = partition["num_shards"]
    if partition["kind"] == "data":
        return {nid: (s[0] // d,) + tuple(s[1:]) for nid, s in shapes.items()}
    out = dict(shapes)
    # keyed by the *consumer* node id (bind reads shapes[node.inputs[0]],
    # so spatial binding calls bind() per node with its own shapes view)
    return out


def spatial_halo_in_shape(partition: dict, node,
                          shapes: dict[str, tuple[int, ...]]) -> tuple:
    """The local exchanged input shape a halo-mode node's plan binds at."""
    p = partition["halo"][node.id]
    b, h, w, c = shapes[node.inputs[0]]
    local_h = h // partition["num_shards"]
    return (b, local_h + 2 * p, w + 2 * p, c)


def build_sharded_fn(net):
    """The jitted shard_map program a partitioned NetworkPlan executes.

    Weights/consts replicate via closure capture; only the activation is
    device-sharded (batch dim for "data", H for "spatial"). Pallas kernels
    trace unchanged inside the shard_map body."""
    part = net.partition
    mesh = net.mesh
    axis, d = mesh_num_shards(mesh)

    if part["kind"] == "data":
        body = net._eval_graph
        in_specs = out_specs = P(axis)
    else:
        modes = part["modes"]
        halo = part["halo"]
        rescatter = part["rescatter"]
        sharded = part["sharded"]
        from repro.core.compile import _consumers

        def body(xs):
            remaining = {nid: len(cons)
                         for nid, cons in _consumers(net.graph).items()}
            env = {"input": xs}
            c = net.consts
            for node in net.graph[1:]:
                a = node.attrs
                mode = modes[node.id]
                if mode == "halo":
                    p = halo[node.id]
                    v = halo_exchange(env[node.inputs[0]], axis, d, p)
                    v = jnp.pad(v, ((0, 0), (0, 0), (p, p), (0, 0)))
                    y = net._eval_node(node, a, v, env, c)
                elif mode == "full":
                    vals = {i: (gather_rows(env[i], axis) if sharded[i]
                                else env[i]) for i in node.inputs}
                    y = net._eval_node(node, a, vals[node.inputs[0]],
                                       {**env, **vals}, c)
                    if rescatter[node.id]:
                        y = scatter_rows(y, axis, d)
                elif mode == "reduce":
                    y = jax.lax.pmean(
                        jnp.mean(env[node.inputs[0]], axis=(1, 2)), axis)
                else:                                        # local
                    v = env[node.inputs[0]] if node.inputs else None
                    y = net._eval_node(node, a, v, env, c)
                env[node.id] = y
                for i in node.inputs:
                    remaining[i] -= 1
                    if remaining[i] == 0 and i in env:
                        del env[i]
            return env[net.graph[-1].id]

        in_specs = P(None, axis)
        out_specs = P(None, axis) if part["out_sharded"] else P()

    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_replication=False))
