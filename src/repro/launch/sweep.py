import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Resumable driver for the full dry-run sweep.

Reads every results/*.jsonl, figures out which (arch x shape x mesh) cells are
missing or errored, and runs only those, appending to --out. Safe to re-run
after a crash or preemption -- this is the same restart-from-manifest posture
the training driver uses (runtime/fault.py), applied to the compile farm.
"""

import argparse
import glob
import json
import traceback

from repro import configs as cfglib
from repro.launch import dryrun


def done_cells(results_dir: str) -> set:
    done = set()
    for f in glob.glob(os.path.join(results_dir, "*.jsonl")):
        with open(f) as fh:
            for line in fh:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") in ("ok", "skip"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--out", default="results/dryrun_main.jsonl")
    ap.add_argument("--arch", default=None, help="restrict to one arch")
    args = ap.parse_args()

    done = done_cells(args.results_dir)
    archs = [cfglib.canonical(args.arch)] if args.arch else list(cfglib.ARCH_IDS)
    todo = [(a, s, m)
            for a in archs
            for s in cfglib.SHAPES
            for m in ("single", "multi")
            if (a, s, m) not in done]
    print(f"sweep: {len(done)} cells done, {len(todo)} to run", flush=True)

    n_err = 0
    for i, (arch, shape, mesh) in enumerate(todo):
        print(f"--- [{i + 1}/{len(todo)}] {arch} {shape} {mesh}", flush=True)
        try:
            rec = dryrun.run_cell(arch, shape, multi_pod=(mesh == "multi"))
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-2000:]}
            print(f"FAILED {arch} {shape} {mesh}: {e!r}", flush=True)
            n_err += 1
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(f"sweep finished: {n_err} errors of {len(todo)}", flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
