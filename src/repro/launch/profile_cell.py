import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-instruction HBM/collective profile of one dry-run cell (the perf-loop
'profiler': reads the compiled HLO, no hardware).

  PYTHONPATH=src python -m repro.launch.profile_cell --arch falcon_mamba_7b \
      --shape train_4k [--multi] [--top 25]
"""

import argparse

from repro.launch import dryrun, hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    # reuse run_cell's lowering path but keep the compiled object
    import json

    from repro import configs as cfglib
    rec = dryrun.run_cell(args.arch, args.shape, multi_pod=args.multi,
                          verbose=True, return_compiled=True)
    compiled = rec.pop("_compiled")
    text = compiled.as_text()
    print(f"\n== top {args.top} instructions by trip-aware HBM bytes ==")
    total = hlo.HloCost(text).total()
    print(f"total bytes/dev: {total.bytes:.3e}  flops/dev: {total.flops:.3e} "
          f" coll/dev: {total.coll_bytes:.3e}")
    for b, op, txt in hlo.profile_bytes(text, args.top):
        print(f"{b:12.3e}  {100*b/total.bytes:5.1f}%  {op:22s} {txt[:110]}")


if __name__ == "__main__":
    main()
