"""Batched serving driver: continuous-batching prefill + decode loop.

Requests enter a queue; the scheduler packs up to `max_batch` active
sequences, prefills new arrivals (padded into the shared KV cache) and steps
decode for all active slots each tick. Slot lifecycle (free -> prefill ->
decode -> done) is the standard continuous-batching state machine,
implemented host-side; the device work is the jitted prefill/decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
      --requests 12 --max-batch 4
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.distributed import context as dist
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step
from repro.models import transformer as tf


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,)
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_len: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh or make_host_mesh()
        self.serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        self.cache = tf.init_decode_cache(cfg, max_batch, max_len,
                                          jnp.float32)
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)

    def _prefill_into_slot(self, slot: int, req: Request):
        """Single-request prefill via the decode step (token-at-a-time warm
        start keeps one compiled program; the batched prefill path is
        exercised by the dry-run)."""
        for tok in req.prompt:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            tokens[slot, 0] = tok
            logits, self.cache = self.serve_step(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        self.slots[slot] = req

    def run(self, requests: list[Request], greedy: bool = True):
        pending = list(requests)
        completed = []
        ticks = 0
        while pending or any(s is not None for s in self.slots):
            # admit
            for i in range(self.max_batch):
                if self.slots[i] is None and pending:
                    req = pending.pop(0)
                    self.pos[i] = 0
                    self._prefill_into_slot(i, req)
            # decode one token for every active slot
            tokens = np.zeros((self.max_batch, 1), np.int32)
            for i, req in enumerate(self.slots):
                if req is not None:
                    tokens[i, 0] = req.out[-1] if req.out else req.prompt[-1]
            # single shared cache_pos: slots decode in lockstep off their own
            # positions via the max (padding slots attend to zeros).
            pos = int(self.pos.max())
            logits, self.cache = self.serve_step(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos))
            ticks += 1
            logits = np.asarray(logits)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                nxt = int(np.argmax(logits[i])) if greedy else \
                    int(np.random.default_rng(ticks).choice(
                        len(logits[i]), p=jax.nn.softmax(logits[i])))
                req.out.append(nxt)
                self.pos[i] += 1
                if len(req.out) >= req.max_new or self.pos[i] >= self.max_len - 1:
                    req.done = True
                    completed.append(req)
                    self.slots[i] = None
        return completed, ticks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    if cfg.encoder is not None:
        raise SystemExit("serve driver targets decoder-only archs; "
                         "whisper decode is exercised via the dry-run")
    mesh = make_host_mesh()
    with dist.use_mesh(mesh):
        params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, size=(4,)),
                        max_new=args.max_new)
                for i in range(args.requests)]
        srv = Server(cfg, params, max_batch=args.max_batch, mesh=mesh)
        t0 = time.time()
        done, ticks = srv.run(reqs)
        dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {ticks} decode ticks)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> {r.out[:8]}")


if __name__ == "__main__":
    main()
