"""Post-compile HLO analysis: trip-count-aware roofline terms.

XLA's cost_analysis() counts while-loop (lax.scan) bodies ONCE, which under-
counts a 96-layer scanned transformer by ~100x. This module parses the
optimized per-device HLO text instead and computes:

  * flops       -- 2*M*N*K for every `dot` (+ convolution), multiplied by the
                   enclosing while-loops' trip counts;
  * hbm_bytes   -- per-instruction (write output + read operands) over all
                   materialized buffers (fusion granularity: post-fusion HLO
                   instructions correspond ~1:1 to HBM buffers), trip-aware;
  * coll_bytes  -- result bytes of all-gather / all-reduce / reduce-scatter /
                   all-to-all / collective-permute, trip-aware.

All numbers are per-device (post-SPMD HLO is the per-device program). Trip
counts come from the integer constant in each while condition (all our loops
are lax.scan counting from 0).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^((?:\([^()]*\)|[\w\[\],{}\d.*/]+))\s+([\w\-]+)\(")
_TRIP_CFG = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_dims(shape_str: str) -> list[tuple[str, int]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def _shape_bytes(shape_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_dims(shape_str))


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str      # result type text
    opcode: str
    rest: str           # full text after '='


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if (not line.startswith(" ") and line.endswith("{")
                and ") -> " in line):
            hdr = _COMP_HDR.match(line.strip())
            if hdr:
                cur = Computation(name=hdr.group(1), instrs=[])
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPCODE.match(rest)
        if om:
            shape_str, opcode = om.groups()
        else:
            # e.g. "%x = s32[] parameter(0)" matches; anything else: skip
            continue
        cur.instrs.append(Instr(name=name, shape_str=shape_str,
                                opcode=opcode, rest=rest))
    return comps


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems = sum(n for _, n in _shape_dims(instr.shape_str))
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    ops = _OPERANDS.findall(instr.rest.split("(", 1)[1])
    if not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0], "")
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 0.0
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    if mc:
        for idx in mc.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems = sum(n for _, n in _shape_dims(instr.shape_str))
    ops = _OPERANDS.findall(instr.rest.split("(", 1)[1])
    if len(ops) < 2:
        return 0.0
    ker = shapes.get(ops[1], "")
    dims_m = _SHAPE_RE.search(ker)
    if not dims_m:
        return 0.0
    k_elems = 1
    for d in dims_m.group(2).split(","):
        if d:
            k_elems *= int(d)
    out_feat_m = _SHAPE_RE.search(instr.shape_str)
    # flops = 2 * out_elems * (kernel_elems / out_features)
    out_dims = [int(d) for d in out_feat_m.group(2).split(",") if d]
    out_features = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * (k_elems / max(out_features, 1))


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for c in _CONST_INT.findall(ins.rest):
            best = max(best, int(c))
    return best


class HloCost:
    """Trip-aware cost walker.

    Host-backend dtype correction: the XLA *CPU* backend has no native bf16
    arithmetic, so every bf16 dot is rewritten as convert(bf16->f32) + f32
    dot. The SPMD partitioner then places weight all-gathers AFTER the
    convert, so collectives that would travel in bf16 on the TPU target are
    counted as f32 here -- a 2x overcount. When a collective's operand is a
    convert-from-bf16 fusion of the same element count, we count its bytes at
    the bf16 width and record the raw value too (EXPERIMENTS.md section
    Roofline documents the correction)."""

    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, CostTotals] = {}
        # computations reached via fusion `calls=` are represented by their
        # callsite's bytes; mark them so we only take their dot flops.
        self.fusion_called: set[str] = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.opcode == "fusion":
                    for callee in _CALLS.findall(ins.rest):
                        self.fusion_called.add(callee)

    def _coll_scale(self, comp: Computation, ins: Instr,
                    shapes: Dict[str, str]) -> float:
        """0.5 if this f32 collective's operand is an upcast from bf16."""
        if not ins.shape_str.startswith("f32"):
            return 1.0
        argtext = ins.rest.split("(", 1)[1] if "(" in ins.rest else ""
        ops = _OPERANDS.findall(argtext.split("), ")[0])
        if not ops:
            return 1.0
        src = ops[0]
        by_name = {i.name: i for i in comp.instrs}
        producer = by_name.get(src)
        if producer is None:
            return 1.0
        if "convert" not in producer.name and producer.opcode != "convert":
            return 1.0
        # confirm a bf16 input of matching element count feeds the fusion
        n_out = sum(n for _, n in _shape_dims(ins.shape_str))
        for operand in _OPERANDS.findall(
                producer.rest.split("(", 1)[1] if "(" in producer.rest else ""):
            osh = shapes.get(operand, "")
            if osh.startswith("bf16") and \
                    sum(n for _, n in _shape_dims(osh)) == n_out:
                return 0.5
        # fall back: fusion named convert_* with a bf16 parameter in its body
        for callee in _CALLS.findall(producer.rest):
            sub = self.comps.get(callee)
            if sub and any(i.shape_str.startswith("bf16") and
                           sum(n for _, n in _shape_dims(i.shape_str)) == n_out
                           for i in sub.instrs):
                return 0.5
        return 1.0

    def total(self, entry: str | None = None) -> CostTotals:
        if entry is None:
            entry = next((n for n in self.comps if n.startswith("main")),
                         list(self.comps)[-1])
        return self._comp_cost(entry, bytes_mode=True)

    def _comp_cost(self, name: str, bytes_mode: bool) -> CostTotals:
        key = f"{name}:{bytes_mode}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        tot = CostTotals()
        if comp is None:
            return tot
        shapes = {i.name: i.shape_str for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                b = _shape_bytes(ins.shape_str) * self._coll_scale(
                    comp, ins, shapes)
                tot.coll_bytes += b
                tot.coll_by_kind[base] += b
            if op == "dot":
                tot.flops += _dot_flops(ins, shapes)
            elif op == "convolution":
                tot.flops += _conv_flops(ins, shapes)
            if bytes_mode and op not in _SKIP_BYTES_OPS and op != "while":
                out_b = _shape_bytes(ins.shape_str)
                in_b = 0
                argtext = ins.rest.split("(", 1)[1] if "(" in ins.rest else ""
                argtext = argtext.split("), ")[0]
                for operand in _OPERANDS.findall(argtext):
                    in_b += _shape_bytes(shapes.get(operand, ""))
                tot.bytes += out_b + in_b
            # recurse
            if op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                m_cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                m_cfg = _TRIP_CFG.search(ins.rest)
                if m_cfg:
                    trips = int(m_cfg.group(1))
                elif m_cond and m_cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[m_cond.group(1)])
                else:
                    trips = 1
                if m_body:
                    sub = self._comp_cost(m_body.group(1), bytes_mode)
                    tot.flops += sub.flops * trips
                    tot.bytes += sub.bytes * trips
                    tot.coll_bytes += sub.coll_bytes * trips
                    for k, v in sub.coll_by_kind.items():
                        tot.coll_by_kind[k] += v * trips
            elif op in ("fusion", "call", "custom-call", "reduce", "sort",
                        "map", "scatter", "select-and-scatter", "conditional"):
                for callee in _CALLS.findall(ins.rest):
                    # fusion internals: dots only (bytes live at the callsite)
                    sub = self._comp_cost(callee, bytes_mode=False)
                    tot.flops += sub.flops
                    tot.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        tot.coll_by_kind[k] += v
        self._memo[key] = tot
        return tot


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    cost = HloCost(hlo_text).total()
    return dict(cost.coll_by_kind)


def profile_bytes(text: str, top: int = 25) -> list[tuple[float, str, str]]:
    """Trip-aware per-instruction HBM bytes, descending -- the dry-run
    'profiler' the perf loop reads instead of a wall-clock trace.

    Returns [(bytes, opcode, instr text prefix)], aggregated over loop trips.
    """
    comps = parse_hlo(text)
    rows: list[tuple[float, str, str]] = []

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        shapes = {i.name: i.shape_str for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                m_cfg = _TRIP_CFG.search(ins.rest)
                m_cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trips = int(m_cfg.group(1)) if m_cfg else (
                    _trip_count(comps[m_cond.group(1)])
                    if m_cond and m_cond.group(1) in comps else 1)
                if m_body:
                    walk(m_body.group(1), mult * trips)
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            out_b = _shape_bytes(ins.shape_str)
            in_b = 0
            argtext = ins.rest.split("(", 1)[1] if "(" in ins.rest else ""
            argtext = argtext.split("), ")[0]
            for operand in _OPERANDS.findall(argtext):
                in_b += _shape_bytes(shapes.get(operand, ""))
            rows.append((mult * (out_b + in_b), op,
                         f"{name}/%{ins.name} = {ins.shape_str}"))

    entry = next((n for n in comps if n.startswith("main")),
                 list(comps)[-1] if comps else None)
    if entry:
        walk(entry, 1.0)
    rows.sort(key=lambda r: -r[0])
    return rows[:top]


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float                # per-device HLO flops (trip-aware)
    hbm_bytes: float            # per-device HBM traffic (trip-aware)
    coll_bytes: float           # per-device collective bytes (trip-aware)
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes, "n_chips": self.n_chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled, n_chips: int) -> tuple[Roofline, dict]:
    """Returns (roofline, raw xla cost_analysis dict for reference)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = cost or {}
    hc = HloCost(compiled.as_text()).total()
    rf = Roofline(flops=hc.flops, hbm_bytes=hc.bytes,
                  coll_bytes=hc.coll_bytes, n_chips=n_chips)
    return rf, {"xla_flops_body_once": float(cost.get("flops", 0.0)),
                "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
                "coll_by_kind": hc.coll_by_kind}
