import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the jitted step (train / prefill / decode) with
full production shardings, lowers it against ShapeDtypeStruct inputs (no
allocation), compiles for the 16x16 single-pod mesh and the 2x16x16 multi-pod
mesh, and records memory_analysis / cost_analysis / collective bytes. The
multi-pod pass proves the "pod" axis shards; rooflines (EXPERIMENTS.md) read
the single-pod results.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_5_3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.distributed import context as dist
from repro.distributed import sharding as shd
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import transformer as tf
from repro.optim import adamw

#: gradient-accumulation steps for the train_4k shape, sized so checkpointed
#: activations + fp32 grad accumulators fit one v5e (16 GB) at 256 chips.
ACCUM_STEPS = {
    # 8 -> 2 (EXPERIMENTS.md section Perf, nemotron iteration 2): the FSDP
    # weight all-gathers and grad all-reduces are per-microbatch, so the
    # collective term scales with accum_steps; sequence-parallel activations
    # keep the larger microbatch within HBM.
    "nemotron_4_340b": 2,
    "llama4_maverick_400b_a17b": 8,
    "qwen1_5_32b": 4,
    "yi_34b": 4,
    "chameleon_34b": 4,
    # 4 -> 8 (EXPERIMENTS.md section Perf, jamba iteration 3): jamba is
    # memory-bound, so halving the microbatch halves the 8-layer remat
    # window's activations; the collective term it costs is far below the
    # memory term it buys.
    "jamba_v0_1_52b": 8,
    "falcon_mamba_7b": 2,
}

PARAM_DTYPE = jnp.bfloat16


def input_specs(cfg, seq: int, batch: int, kind: str):
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if kind == "train":
        specs = {"tokens": sd((batch, seq), i32),
                 "labels": sd((batch, seq), i32)}
        if cfg.encoder is not None:
            specs["frames"] = sd((batch, cfg.encoder.n_ctx, cfg.d_model),
                                 PARAM_DTYPE)
        return specs
    if kind == "prefill":
        specs = {"tokens": sd((batch, seq), i32)}
        if cfg.encoder is not None:
            specs["frames"] = sd((batch, cfg.encoder.n_ctx, cfg.d_model),
                                 PARAM_DTYPE)
        return specs
    if kind == "decode":
        return {
            "cache": tf.abstract_decode_cache(cfg, batch, seq, PARAM_DTYPE),
            "tokens": sd((batch, 1), i32),
            "cache_pos": sd((), i32),
        }
    raise ValueError(kind)


def _mem_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True,
             return_compiled: bool = False):
    """Lower+compile one cell; returns the result record."""
    cfg = cfglib.get_config(arch)
    seq, batch, kind = dict(
        (s, (q, b, k)) for s, q, b, k in cfglib.cells(arch))[shape]
    if kind == "skip":
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip",
                "reason": "full attention is quadratic at 500k; "
                          "sub-quadratic archs only (DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with dist.use_mesh(mesh):
        params_shape = tf.abstract_params(cfg, PARAM_DTYPE)
        p_shard = shd.param_shardings(params_shape, cfg, mesh)
        specs = input_specs(cfg, seq, batch, kind)

        if kind == "train":
            opt_cfg = adamw.AdamWConfig(
                state_dtype=jnp.bfloat16 if cfg.n_params > 50e9 else jnp.float32)
            opt_shape = adamw.abstract_state(params_shape, opt_cfg)
            o_shard = adamw.AdamWState(
                step=jax.sharding.NamedSharding(mesh, shd.P()),
                m=shd.param_shardings(params_shape, cfg, mesh),
                v=shd.param_shardings(params_shape, cfg, mesh))
            b_shard = shd.sharding_tree(shd.batch_specs(specs, mesh), mesh)
            accum = ACCUM_STEPS.get(arch, 1) if shape == "train_4k" else 1
            step = make_train_step(cfg, opt_cfg, accum_steps=accum)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, opt_shape, specs)
        elif kind == "prefill":
            b_shard = shd.sharding_tree(shd.batch_specs(specs, mesh), mesh)
            cache_shape = tf.abstract_decode_cache(cfg, batch, seq, PARAM_DTYPE)
            c_shard = shd.sharding_tree(
                shd.cache_specs(cache_shape, cfg, mesh), mesh)
            step = make_prefill_step(cfg, max_len=seq)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            c_shard = shd.sharding_tree(
                shd.cache_specs(specs["cache"], cfg, mesh), mesh)
            t_shard = shd.sharding_tree(
                shd.batch_specs({"t": specs["tokens"]}, mesh), mesh)["t"]
            step = make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, t_shard, None),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, specs["cache"],
                                   specs["tokens"], specs["cache_pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rf, xla_raw = hlo.roofline_from_compiled(compiled, n_chips)
    colls = xla_raw["coll_by_kind"]
    record = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "kind": kind, "seq": seq, "batch": batch, "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": _mem_summary(compiled),
        "cost_xla_body_once": {
            "flops": xla_raw["xla_flops_body_once"],
            "bytes_accessed": xla_raw["xla_bytes_body_once"]},
        "collectives": colls,
        "roofline": rf.as_dict(),
        "model_flops_6nd": 6.0 * cfg.n_active_params * seq * batch
        if kind == "train" else
        (2.0 * cfg.n_active_params * seq * batch if kind == "prefill"
         else 2.0 * cfg.n_active_params * batch),
    }
    if verbose:
        mem = record["memory"]
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
        print(f"[{record['mesh']}] {arch} {shape}: kind={kind} "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"flops={rf.flops:.3e} hbm={rf.hbm_bytes:.3e} "
              f"coll={rf.coll_bytes:.3e} bottleneck={rf.bottleneck} "
              f"mem/dev~{per_dev/1e9:.2f}GB", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis(body-once): {record['cost_xla_body_once']}",
              flush=True)
    if return_compiled:
        record["_compiled"] = compiled
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = cfglib.ARCH_IDS if (args.all or args.arch is None) \
        else [cfglib.canonical(args.arch)]
    shapes = list(cfglib.SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    rec = run_cell(arch, shape, multi_pod=multi)
                except Exception as e:  # a failed cell is a bug: surface it
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"FAILED {arch} {shape} multi={multi}: {e!r}",
                          flush=True)
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skip" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"dry-run: {ok} ok, {skip} skip, {err} error "
          f"of {len(records)} cells", flush=True)
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
