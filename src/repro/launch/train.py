"""Fault-tolerant training driver.

Wires together: config registry, sharded init, deterministic data pipeline
with prefetch, jitted train step (grad accumulation + ZeRO AdamW), async
checkpointing, preemption handling, straggler logging, and crash-retry from
the last committed checkpoint.

CPU-friendly: runs the reduced smoke config on the host mesh by default.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b --steps 50 \
      --batch 8 --seq 64 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed import context as dist
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime.fault import PreemptionGuard, StepTimer, run_with_retries


def train(arch: str, *, steps: int, batch: int, seq: int, smoke: bool,
          ckpt_dir: str | None, ckpt_every: int = 50, accum: int = 1,
          lr: float = 3e-4, param_dtype=jnp.float32, mesh=None,
          log_every: int = 10, max_failures: int = 3):
    cfg = (cfglib.get_smoke_config(arch) if smoke else cfglib.get_config(arch))
    mesh = mesh or make_host_mesh()
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=steps,
                                warmup_steps=max(steps // 20, 5))
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    guard = PreemptionGuard()
    timer = StepTimer()
    pipeline = SyntheticLM(cfg, batch, seq)
    history = []

    def body(_start):
        with dist.use_mesh(mesh):
            params_shape = tf.abstract_params(cfg, param_dtype)
            p_shard = shd.param_shardings(params_shape, cfg, mesh)
            step_fn = jax.jit(
                make_train_step(cfg, opt_cfg, accum_steps=accum),
                in_shardings=(p_shard, None, None),
                out_shardings=(p_shard, None, None),
                donate_argnums=(0, 1))

            start = 0
            if manager and manager.latest_step() is not None:
                start = manager.latest_step()
                opt_like = adamw.abstract_state(params_shape, opt_cfg)
                state_like = {"params": params_shape, "opt": opt_like}
                # shardings tree must be leaf-aligned with state_like:
                # moments inherit the param shardings (ZeRO), scalars replicate.
                rep = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())
                o_shard = adamw.AdamWState(step=rep, m=p_shard, v=p_shard)
                restored = manager.restore(start, state_like,
                                           {"params": p_shard, "opt": o_shard})
                params, opt_state = restored["params"], restored["opt"]
                print(f"[train] restored step {start} from {ckpt_dir}")
            else:
                params = jax.jit(
                    lambda k: tf.init_params(k, cfg, param_dtype),
                    out_shardings=p_shard)(jax.random.key(0))
                opt_state = adamw.init_state(params, opt_cfg)

            it = Prefetcher(pipeline.iterate(start), depth=2)
            try:
                for step in range(start, steps):
                    t0 = time.time()
                    batch_np = next(it)
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch_np)
                    loss = float(metrics["loss"])
                    dt = time.time() - t0
                    straggle = timer.record(dt)
                    history.append(loss)
                    if step % log_every == 0 or step == steps - 1:
                        print(f"[train] step={step} loss={loss:.4f} "
                              f"gnorm={float(metrics['grad_norm']):.3f} "
                              f"lr={float(metrics['lr']):.2e} dt={dt*1e3:.0f}ms"
                              + (" STRAGGLER" if straggle else ""), flush=True)
                    if np.isnan(loss):
                        raise FloatingPointError(f"NaN loss at step {step}")
                    if manager and ((step + 1) % ckpt_every == 0
                                    or step == steps - 1 or guard.requested):
                        manager.save(step + 1,
                                     {"params": params, "opt": opt_state})
                    if guard.requested:
                        print("[train] preemption requested; checkpointed, "
                              "exiting cleanly")
                        break
            finally:
                it.close()
                if manager:
                    manager.wait()
            return params, opt_state

    result = run_with_retries(
        lambda s: body(s), max_failures=max_failures,
        on_failure=lambda e: print(f"[train] step loop failed ({e!r}); "
                                   f"restarting from last checkpoint"))
    return result, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    _, history = train(args.arch, steps=args.steps, batch=args.batch,
                       seq=args.seq, smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, accum=args.accum,
                       lr=args.lr)
    print(f"[train] done. loss {history[0]:.3f} -> {history[-1]:.3f}")


if __name__ == "__main__":
    main()
