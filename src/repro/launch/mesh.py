"""Production mesh definitions.

Target: TPU v5e pods, 256 chips per pod (16 x 16). Single-pod mesh is
("data", "model") = (16, 16); the multi-pod mesh adds a leading "pod" axis
(pure DP across pods -- parameters replicate per pod, the global batch shards
over ("pod", "data")).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (CPU tests / examples)."""
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"make_host_mesh: model_parallel={model_parallel} must be a "
            f"positive divisor of the {n} available device(s) "
            f"({[d.platform for d in jax.devices()]}); force more host "
            f"devices with XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"or lower model_parallel")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_data_mesh(num_devices: int | None = None) -> Mesh:
    """1-D ("data",) mesh for sharded NetworkPlan execution.

    `num_devices` takes the first N devices (a 1->N scaling curve on forced
    host devices needs submeshes); default is all of them.
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if n < 1 or n > len(devs):
        raise ValueError(
            f"make_data_mesh: num_devices={num_devices} out of range for the "
            f"{len(devs)} available device(s); force more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.array(devs[:n]), ("data",))
