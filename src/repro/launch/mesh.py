"""Production mesh definitions.

Target: TPU v5e pods, 256 chips per pod (16 x 16). Single-pod mesh is
("data", "model") = (16, 16); the multi-pod mesh adds a leading "pod" axis
(pure DP across pods -- parameters replicate per pod, the global batch shards
over ("pod", "data")).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
