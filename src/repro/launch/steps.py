"""Jittable train / prefill / serve steps.

train_step supports gradient accumulation (scan over microbatches: only one
microbatch's activations are ever live, which is what lets the 340B config
compile within pod HBM at global batch 256) and returns scalar metrics.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ArchConfig
from repro.optim import adamw

_F32 = jnp.float32


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch leaves have leading dim = global_batch; with accum_steps > 1 the
    batch splits into microbatches scanned sequentially, gradients averaged.
    """

    def loss_fn(params, microbatch):
        return tf.forward(params, microbatch, cfg)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(_F32), grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, _F32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), _F32), zero_grads), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(
                lambda g, p: (g / accum_steps).astype(p.dtype), grads, params)

        grad_norm = adamw.global_norm(grads)
        params, opt_state = adamw.apply_updates(params, grads, opt_state,
                                                opt_cfg)
        metrics = {"loss": loss.astype(_F32), "grad_norm": grad_norm,
                   "lr": adamw.schedule(opt_state.step - 1, opt_cfg)}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        # bulk prefill uses capacity-bounded MoE routing (dropless buffers
        # are O(T) per expert; see tf.prefill docstring).
        return tf.prefill(params, batch["tokens"], cfg, max_len,
                          batch.get("frames"), dropless=False)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode: (params, cache, tokens (B,1), cache_pos) ->
    (next_token_logits, new_cache)."""
    def serve_step(params, cache, tokens, cache_pos):
        return tf.decode_step(params, cache, tokens, cache_pos, cfg)
    return serve_step
