"""Global distribution context.

Model code stays mesh-agnostic by calling shard_activations(x, kind); when a
mesh is active (set by the launcher / dry-run), that applies a
with_sharding_constraint from the active rule set, otherwise it is a no-op.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes the global batch shards over (pod axis folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def default_activation_rules(mesh: Mesh) -> dict[str, P]:
    """kind -> PartitionSpec for (B, S, D) activations."""
    ba = batch_axes(mesh)
    return {
        # residual stream: batch over data axes, sequence over the model axis
        # (sequence parallelism -- cuts checkpointed activations 16x; XLA
        # all-gathers around attention/matmul as needed).
        "residual": P(ba, "model", None),
        # decode-time activations: (B, 1, D) -- batch only.
        "decode": P(ba, None, None),
    }


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules if rules is not None
                  else default_activation_rules(mesh))
    try:
        with mesh:
            yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def shard_activations(x: jax.Array, kind: str) -> jax.Array:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(kind)
    if spec is None:
        return x
    # guard: do not constrain axes the array cannot shard (tiny smoke shapes).
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ok = True
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        need = 1
        for a in axs:
            need *= sizes[a]
        if dim % need:
            ok = False
    if not ok:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
