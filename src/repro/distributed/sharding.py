"""Parameter / optimizer / cache partition specs.

2-D sharding: tensor-parallel over the "model" axis (heads / ffn / experts /
vocab) x fully-sharded (ZeRO-3 style) over the "data" axis on the
complementary dimension. Pods replicate parameters (pure DP across the "pod"
axis); the batch shards over ("pod", "data").

Every proposed spec passes through a divisibility guard so reduced smoke
configs and odd dimensions (granite's 40 experts on a 16-way model axis,
whisper's d_model=384) degrade to replication on the offending axis instead of
failing to lower.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = True):
    """Version-portable shard_map.

    jax >= 0.5 exposes `jax.shard_map` (replication-check kwarg `check_vma`);
    the 0.4.x line keeps `jax.experimental.shard_map.shard_map` (kwarg
    `check_rep`). Everything in this repo (and its spawned-subprocess test
    snippets) should route through this shim instead of touching either
    attribute directly.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_replication)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_replication)


def _guard(mesh: Mesh, shape: tuple, spec: P) -> P:
    """Drop mesh axes that do not divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        need = int(np.prod([sizes[a] for a in axs]))
        fixed.append(ax if dim % need == 0 else None)
    return P(*fixed)


#: parameter-name -> (spec builder). Specs are written for the *unstacked*
#: leaf; the scan-unit axis is prepended automatically for block params.
_COL = {"wq", "wk", "wv", "up", "gate", "in_proj"}          # (D, out*) -> TP out
_ROW = {"wo", "down", "out_proj", "dt_proj"}                # (in*, D) -> TP in
_VEC_TP = {"bq", "bk", "bv", "conv_b", "d_skip", "dt_bias"}


def _leaf_spec(path: tuple[str, ...], shape: tuple, cfg: ArchConfig) -> P:
    name = path[-1]
    in_moe = "moe" in path
    if in_moe:
        mode = cfg.moe.shard_mode
        if name == "router":
            return P("data", None)
        if name in ("up", "gate"):                           # (E, D, F)
            return P("model", "data", None) if mode == "ep" \
                else P(None, "data", "model")
        if name == "down":                                   # (E, F, D)
            return P("model", None, "data") if mode == "ep" \
                else P(None, "model", "data")
    if name in ("embed", "lm_head"):                         # (V, D)
        return P("model", "data")
    if name == "pos_emb":
        return P(None, "data")
    if name in ("scale", "bias"):
        return P(None)
    if name in ("q_norm", "k_norm"):
        return P(None)
    if name == "conv_w":                                     # (k, d_in)
        return P(None, "model")
    if name == "a_log":                                      # (d_in, N)
        return P("model", None)
    if name == "x_proj":                                     # (d_in, dt+2N)
        return P("model", "data")
    if name in _COL:
        return P("data", "model")
    if name in _ROW:
        return P("model", "data")
    if name in _VEC_TP:
        return P("model")
    return P()                                               # replicate


def _path_names(path) -> tuple[str, ...]:
    names = []
    for part in path:
        if hasattr(part, "key"):
            names.append(str(part.key))
        elif hasattr(part, "name"):
            names.append(str(part.name))
        else:
            names.append(str(part))
    return tuple(names)


def param_specs(params_shape: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching a (possibly abstract) param tree."""
    def one(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        spec = _leaf_spec(names, shape, cfg)
        stacked = "blocks" in names or (
            "encoder" in names and "layers" in names)
        if stacked and len(spec) < len(shape):
            spec = P(None, *spec)                            # scan-unit axis
        return _guard(mesh, shape, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, cfg, mesh))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """tokens/labels (B, S): batch over data axes; frames (B, T, D) same."""
    ba = _batch_axes(mesh)

    def one(leaf):
        spec = P(ba, *([None] * (len(leaf.shape) - 1)))
        return _guard(mesh, tuple(leaf.shape), spec)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """Decode caches. Leading axis is n_units. KV caches (U, B, L, H, hd):
    batch over data axes, heads over model; if the batch cannot shard
    (long_500k has B=1) the sequence axis takes the data axes instead.
    Mamba caches (U, B, d_in, N)/(U, B, k-1, d_in): d_in over model."""
    ba = _batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = int(np.prod([sizes[a] for a in ba]))

    n_model = sizes.get("model", 1)

    def one(leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 5:                                  # KV cache
            # TP the KV heads if they divide the model axis, else the head
            # dim (GQA kv=8 on a 16-way model axis); batch over data axes,
            # falling back to the sequence axis when B = 1 (long_500k).
            hax = "model" if shape[3] % n_model == 0 else None
            dax = "model" if hax is None and shape[4] % n_model == 0 else None
            if shape[1] % n_data == 0:
                spec = P(None, ba, None, hax, dax)
            else:
                spec = P(None, None, ba, hax, dax)
        elif len(shape) == 4:                                # conv or ssm state
            # (U, B, k-1, d_in) or (U, B, d_in, N): shard widest trailing dim.
            if shape[2] >= shape[3]:
                spec = P(None, ba, "model", None)
            else:
                spec = P(None, ba, None, "model")
        else:
            spec = P()
        return _guard(mesh, shape, spec)

    return jax.tree.map(one, cache_shape)


def sharding_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


# ---------------------------------------------------------------------------
# Conv-network partition primitives (sharded NetworkPlan execution)
# ---------------------------------------------------------------------------
# NHWC activations partitioned over a 1-D ("data",) axis, either on the batch
# dim (data parallel) or on H (spatial halo partitioning). These run *inside*
# a shard_map body, so each sees the device-local shard.

def data_axis_name(mesh: Mesh) -> str:
    """The batch/spatial partition axis: "data" if present, else axis 0."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


def halo_exchange(x: jax.Array, axis_name: str, num_shards: int,
                  halo: int) -> jax.Array:
    """Exchange `halo` boundary rows (axis 1, NHWC H) with mesh neighbors.

    Returns the local shard grown by `halo` rows on each side. Edge shards
    receive zeros (ppermute with no inbound edge), which is exactly SAME
    zero padding -- so a VALID conv over the exchanged tensor reproduces the
    unsharded SAME conv's rows owned by this shard.
    """
    if halo == 0:
        return x
    up = jax.lax.ppermute(x[:, -halo:], axis_name,
                          [(i, i + 1) for i in range(num_shards - 1)])
    dn = jax.lax.ppermute(x[:, :halo], axis_name,
                          [(i + 1, i) for i in range(num_shards - 1)])
    return jnp.concatenate([up, x, dn], axis=1)


def gather_rows(x: jax.Array, axis_name: str) -> jax.Array:
    """Reassemble the full H from row shards (all shards get the full copy)."""
    return jax.lax.all_gather(x, axis_name, axis=1, tiled=True)


def scatter_rows(full: jax.Array, axis_name: str,
                 num_shards: int) -> jax.Array:
    """Take back this shard's contiguous H rows from a replicated tensor."""
    local = full.shape[1] // num_shards
    i = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice_in_dim(full, i * local, local, axis=1)
