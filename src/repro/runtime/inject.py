"""Deterministic fault injection for the serving runtime.

Each fault class exercises one rung of repro.runtime.serve's degrade
ladder, deterministically (call-count schedules, not randomness), so tests
and the benchmark's fault runs are reproducible:

  * `ExecutorRaise` -- a layer's executor raises (stands in for a kernel
    crash / numerical abort). Drives retry-with-backoff and, when
    permanent, the registry re-placement rung.
  * `LatencySpike` -- a layer sleeps before executing (straggler). Drives
    the StepTimer straggler counter and the eviction rung.
  * `flip_bit` -- flips one bit of one array inside a saved NetworkPlan
    .npz WITHOUT touching the recorded checksums: silent storage
    corruption, which load() must catch via the per-array sha256 digests
    and the serving layer must answer with recompile-in-place.
  * Queue overload has no injector: it is produced by submitting a burst
    past `queue_capacity` (see benchmarks/serving.py / tests).

Faults install as a proxy around one bound LayerPlan (`install`). The
supervisor's re-placement and recompile rungs bind FRESH plan objects,
which drops the proxy -- exactly the semantics the degrade ladder assumes:
repair replaces the faulty executor.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


class InjectedExecutorError(RuntimeError):
    """Raised by an installed ExecutorRaise fault."""


@dataclass
class ExecutorRaise:
    """Raise InjectedExecutorError on calls [after, after + times)."""

    node_id: str
    times: int = 10**9          # default: permanent until repaired
    after: int = 0


@dataclass
class LatencySpike:
    """Sleep delay_s before executing on calls [after, after + times)."""

    node_id: str
    delay_s: float = 0.25
    times: int = 10**9
    after: int = 0


class FaultyPlan:
    """Proxy around one bound LayerPlan that consults a fault schedule on
    every apply() call; everything else delegates to the wrapped plan."""

    def __init__(self, inner, fault):
        self._inner = inner
        self._fault = fault
        self.calls = 0

    def apply(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        f = self._fault
        if f.after <= i < f.after + f.times:
            if isinstance(f, ExecutorRaise):
                raise InjectedExecutorError(
                    f"injected executor failure in layer {f.node_id!r} "
                    f"(call {i})")
            time.sleep(f.delay_s)
        return self._inner.apply(*args, **kwargs)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def install(net, fault) -> FaultyPlan:
    """Wrap `net.plans[fault.node_id]` (a NetworkPlan's bound layer plan)
    in a FaultyPlan following the fault's schedule. Returns the proxy (its
    `calls` counter is the test observability hook)."""
    if fault.node_id not in net.plans:
        raise KeyError(f"{fault.node_id!r} is not a plan-bearing node; "
                       f"have {sorted(net.plans)}")
    proxy = FaultyPlan(net.plans[fault.node_id], fault)
    net.plans[fault.node_id] = proxy
    if hasattr(net, "invalidate_executables"):
        net.invalidate_executables()      # drop any cached sharded program
    return proxy


def install_on_server(server, fault) -> list[FaultyPlan]:
    """Install the same fault on every bucket plan of a serve.Server --
    including any mesh-sharded bucket plans -- (a faulty executor is
    faulty at every batch size)."""
    nets = list(server.nets.values())
    nets += list(getattr(server, "sharded_nets", {}).values())
    return [install(net, fault) for net in nets]


def flip_bit(path: str, match: str = "plan:", *, byte: int = 0,
             bit: int = 0) -> str:
    """Silently corrupt a saved NetworkPlan artifact: flip one bit in the
    first array whose npz key contains `match`, re-writing the file with
    the ORIGINAL header (checksums untouched). Returns the corrupted
    array's key. NetworkPlan.load must now fail that array's sha256
    digest with ArtifactMismatchError."""
    with np.load(path, allow_pickle=False) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    name = next((k for k in arrays
                 if k != "__header__" and match in k
                 and arrays[k].dtype.kind in "fiu"), None)
    if name is None:
        raise KeyError(f"no numeric array matching {match!r} in {path}")
    a = arrays[name]
    raw = bytearray(a.tobytes())
    raw[byte % len(raw)] ^= 1 << (bit % 8)
    arrays[name] = np.frombuffer(bytes(raw), a.dtype).reshape(a.shape)
    with open(path, "wb") as f:
        np.savez(f, **arrays)
    return name
