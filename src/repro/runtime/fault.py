"""Fault-tolerance runtime: retry supervisor, preemption hook, straggler log.

At thousand-node scale the failure model is: (a) hard worker loss -> the jax
runtime raises from the collective; (b) SIGTERM preemption warning; (c)
stragglers -> step-time outliers. The supervisor owns (a) and (b) by
restarting the step loop from the last committed checkpoint; (c) is surfaced
by the StepTimer so the scheduler can evict (synchronous SPMD bounds the cost
of a straggler at the collective -- mitigation = replacement, not async).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class PreemptionGuard:
    """Converts SIGTERM into a checkpoint-and-exit request."""

    def __init__(self):
        self.requested = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            pass  # not main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True


@dataclass
class StepTimer:
    """Rolling step-time stats; flags straggler steps (> k sigma)."""
    window: int = 50
    sigma: float = 3.0
    times: list = field(default_factory=list)
    stragglers: int = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        hist = self.times[-self.window:]
        is_out = False
        if len(hist) >= 10:
            mean = sum(hist) / len(hist)
            var = sum((t - mean) ** 2 for t in hist) / len(hist)
            if dt > mean + self.sigma * max(var ** 0.5, 0.05 * mean):
                self.stragglers += 1
                is_out = True
        self.times.append(dt)
        return is_out


def run_with_retries(body: Callable[[int], int], *, max_failures: int = 3,
                     on_failure: Optional[Callable[[BaseException], None]] = None
                     ) -> int:
    """Supervise `body(start_step) -> last_step`, restarting on failure.

    `body` must be restartable from its checkpoint store. Each retry calls
    body again; the restored start step comes from the checkpoint manager
    inside body. Raises after max_failures consecutive failures.
    """
    failures = 0
    last = 0
    while True:
        try:
            return body(last)
        except KeyboardInterrupt:
            raise
        except BaseException as e:
            failures += 1
            if on_failure:
                on_failure(e)
            if failures > max_failures:
                raise
            time.sleep(0.1)
