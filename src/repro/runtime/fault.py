"""Fault-tolerance runtime: retry supervisor, preemption hook, straggler log.

At thousand-node scale the failure model is: (a) hard worker loss -> the jax
runtime raises from the collective; (b) SIGTERM preemption warning; (c)
stragglers -> step-time outliers. The supervisor owns (a) and (b) by
restarting the step loop from the last committed checkpoint; (c) is surfaced
by the StepTimer so the scheduler can evict (synchronous SPMD bounds the cost
of a straggler at the collective -- mitigation = replacement, not async).

The serving runtime (repro.runtime.serve) reuses the same three primitives
at per-batch granularity: Backoff paces its in-place retry stage, and
StepTimer flags straggler batches so the supervisor can evict a slow layer
onto the fallback executor.
"""

from __future__ import annotations

import random
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class PreemptionGuard:
    """Converts SIGTERM into a checkpoint-and-exit request."""

    def __init__(self):
        self.requested = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            pass  # not main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True


class Backoff:
    """Exponential backoff with deterministic jitter.

    `next()` returns the delay for the next retry: `base * factor**attempt`
    capped at `cap`, scaled by a jitter factor drawn uniformly from
    [1 - jitter, 1] off a seeded RNG -- deterministic per instance (tests,
    reproducible fault drills) while still decorrelating retry storms across
    differently seeded instances.
    """

    def __init__(self, base: float = 0.05, factor: float = 2.0,
                 cap: float = 2.0, jitter: float = 0.5, seed: int = 0):
        if not 0.0 <= jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {jitter}")
        self.base = base
        self.factor = factor
        self.cap = cap
        self.jitter = jitter
        self.attempt = 0
        self._rng = random.Random(seed)

    def next(self) -> float:
        d = min(self.cap, self.base * self.factor ** self.attempt)
        self.attempt += 1
        return d * (1.0 - self.jitter * self._rng.random())

    def reset(self) -> None:
        self.attempt = 0


@dataclass
class StepTimer:
    """Rolling step-time stats; flags straggler steps (> k sigma).

    Baseline hygiene: the window that judges a sample contains only
    *previously* recorded, *non-straggler* samples -- the current sample
    never contributes to the mean/variance used to flag it, and flagged
    outliers are kept out of the baseline so one straggler cannot inflate
    the stats and mask the next one. `times` still records every sample
    verbatim for reporting.
    """
    window: int = 50
    sigma: float = 3.0
    min_baseline: int = 10
    times: list = field(default_factory=list)      # every sample, in order
    baseline: list = field(default_factory=list)   # non-straggler samples
    stragglers: int = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        hist = self.baseline[-self.window:]
        is_out = False
        if len(hist) >= self.min_baseline:
            mean = sum(hist) / len(hist)
            var = sum((t - mean) ** 2 for t in hist) / len(hist)
            if dt > mean + self.sigma * max(var ** 0.5, 0.05 * mean):
                self.stragglers += 1
                is_out = True
        self.times.append(dt)
        if not is_out:
            self.baseline.append(dt)
        return is_out


def run_with_retries(body: Callable[[int], int], *, max_failures: int = 3,
                     on_failure: Optional[Callable[[Exception], None]] = None,
                     base_delay_s: float = 0.05, max_delay_s: float = 2.0,
                     jitter: float = 0.5,
                     sleep: Callable[[float], None] = time.sleep) -> int:
    """Supervise `body(start_step) -> last_step`, restarting on failure.

    `body` must be restartable from its checkpoint store. Each retry calls
    body again; the restored start step comes from the checkpoint manager
    inside body. Raises after max_failures consecutive failures.

    Consecutive failures are paced by exponential backoff with jitter
    (base_delay_s doubling up to max_delay_s) so a crash-looping fleet does
    not hammer shared infrastructure in lockstep. Only `Exception` is
    caught: `SystemExit` and `KeyboardInterrupt` (preemption, operator
    interrupt) escape immediately instead of burning the retry budget.
    """
    failures = 0
    last = 0
    backoff = Backoff(base=base_delay_s, cap=max_delay_s, jitter=jitter)
    while True:
        try:
            return body(last)
        except Exception as e:
            failures += 1
            if on_failure:
                on_failure(e)
            if failures > max_failures:
                raise
            sleep(backoff.next())
