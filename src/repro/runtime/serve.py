"""Fault-tolerant batched serving runtime over compiled NetworkPlan artifacts.

The deployment story PR 5/6 built -- compile once, ship the transformed
weights as a versioned artifact, warm-start with zero filter transforms --
stops at process startup. This module is the layer that drives those
artifacts under load, the production path the paper's
resource-constrained-CPU setting implies:

  * **Admission with backpressure.** A bounded queue; `submit()` on a full
    queue raises `QueueFullError` carrying `retry_after_s` (queue depth over
    the measured batch service rate), so overload degrades into bounded
    rejection instead of unbounded latency.
  * **Dynamic batch formation into bucketed batch sizes.** Plan geometry is
    batch-shape-specific, so the server compiles ONE NetworkPlan per bucket
    (each warm-started from its own artifact when `artifact_dir` is given)
    and pre-warms every bucket's executables before traffic arrives.
    Arrivals coalesce for `batch_wait_s`, are dispatched
    earliest-deadline-first, and are padded up to the smallest covering
    bucket.
  * **Deadlines.** Per-request deadlines; requests that expire while queued
    are timeout-cancelled before dispatch (never executed), and responses
    that land past their deadline are flagged `deadline_missed`.
  * **The degrade ladder.** A supervisor wraps every batch execution:
      1. in-place retries paced by exponential backoff with jitter
         (`fault.Backoff`);
      2. re-place the failing layer (identified via
         `compile.LayerExecutionError.node_id`) onto the im2row fallback
         through the capability registry -- across every bucket plan;
      3. recompile in place from raw params when the rung above does not
         cure it, counting per-array checksum findings against the on-disk
         artifacts (`compile.verify_artifact`) -- the corrupt-artifact path.
    The failing batch is retried after each rung, so in-flight requests
    survive every recoverable fault; only a fully exhausted ladder answers
    tickets with the error (failed, but never silently dropped).
  * **Mixed-precision supervision.** A server compiled with a reduced
    `compute_dtype` (bf16/int8 transform-domain plans) runs an accuracy
    probe at warmup (and on demand via `probe_precision()`): each quantized
    conv layer is checked against a fresh fp32 plan on its real shape, and
    a layer outside its per-dtype error budget is promoted back to fp32
    across every bucket plan before traffic sees it. `stats` surfaces the
    per-layer compute dtypes currently being served.
  * **Straggler eviction.** A `fault.StepTimer` per bucket flags outlier
    batches; per-layer times (NetworkPlan.apply's layer_hook) attribute the
    spike, and a layer that stragglers `straggler_evict_after` times is
    evicted onto the fallback executor.

Deterministic fault injection for all of this lives in
`repro.runtime.inject`; the latency/throughput benchmark under Poisson
arrivals (with and without injected faults) is `benchmarks/serving.py`.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile as _compile
from repro.core import plan as _plan
from repro.obs import metrics as _obs_metrics
from repro.obs import profile as _obs_profile
from repro.runtime.fault import Backoff, StepTimer


class QueueFullError(RuntimeError):
    """Admission rejected: the bounded queue is full. `retry_after_s` is the
    server's estimate of when capacity frees (queue depth over the measured
    batch service rate) -- the client-visible backpressure signal."""

    def __init__(self, retry_after_s: float, capacity: int):
        super().__init__(
            f"admission queue full (capacity {capacity}); retry in "
            f"{retry_after_s:.3f}s")
        self.retry_after_s = retry_after_s
        self.capacity = capacity


@dataclass
class ServeConfig:
    """Serving-runtime knobs (batching, admission, supervision)."""

    buckets: Sequence[int] = (1, 2, 4, 8)
    queue_capacity: int = 64
    #: dynamic batch formation window: how long the scheduler lets a
    #: non-full queue coalesce before dispatching what it has.
    batch_wait_s: float = 0.002
    default_deadline_s: float | None = None
    #: supervisor rung 1: in-place retries before degrading.
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.25
    #: straggler detection (per-bucket StepTimer) + eviction policy.
    straggler_sigma: float = 3.0
    straggler_window: int = 32
    straggler_min_baseline: int = 8
    straggler_evict_after: int = 3
    #: a layer is blamed for a straggler batch only when its time exceeds
    #: this multiple of its own non-straggler EWMA baseline.
    straggler_layer_ratio: float = 2.0
    fallback_algorithm: str = "im2col"
    ewma_alpha: float = 0.3
    #: the jitted happy path: batch dispatch runs a jitted NetworkPlan.apply
    #: (per bucket, invalidated whenever a bound plan is swapped) until the
    #: FIRST fault on that bucket, then falls back to the eager supervised
    #: path -- where per-layer hooks, error annotation, and the degrade
    #: ladder can see every layer -- for that bucket. Disable for tests or
    #: drills that need per-layer observability from the first batch.
    jit_dispatch: bool = True
    #: continuous re-placement: a layer evicted onto the fallback executor
    #: gets a probation window of this many CLEAN batches (no executor
    #: failures), after which the supervisor re-probes the original
    #: algorithm against the serving fallback on a real-shape input and
    #: promotes the layer back when it passes; a failed probe doubles the
    #: window. 0 pins evicted layers on the fallback forever (the PR 7
    #: behavior).
    probation_batches: int = 256
    #: max relative error of the re-probe vs the serving fallback plan.
    probation_tol: float = 2e-3
    #: run the reduced-precision accuracy probe during warmup (servers with
    #: compute_dtype="float32" never probe); per-dtype relative max-abs
    #: error budgets default to plan.AUTOTUNE_ACCURACY_BUDGET.
    precision_probe: bool = True
    precision_budget: dict | None = None
    verbose: bool = True


class Ticket:
    """One admitted request: the Future-ish handle the client waits on.

    Terminal states: 'ok' (result ready), 'timeout' (deadline expired while
    queued), 'cancelled', 'error' (the supervisor's degrade ladder was
    exhausted). Exactly one terminal transition wins; every admitted ticket
    reaches one -- the zero-drop contract."""

    def __init__(self, rid: int, x: np.ndarray, deadline: float | None,
                 submitted_at: float):
        self.rid = rid
        self.x = x
        self.deadline = deadline          # absolute perf_counter time
        self.submitted_at = submitted_at
        self.finished_at: float | None = None
        self.deadline_missed = False
        self.status = "pending"
        self._value = None
        self._error: BaseException | None = None
        self._done = threading.Event()
        self._once = threading.Lock()

    def _finish(self, status: str, value=None,
                error: BaseException | None = None) -> bool:
        with self._once:
            if self._done.is_set():
                return False
            self.status = status
            self._value = value
            self._error = error
            self.finished_at = time.perf_counter()
            self._done.set()
            return True

    def done(self) -> bool:
        return self._done.is_set()

    def cancel(self) -> bool:
        """Best-effort cancel; wins only if the request was not already
        dispatched into a batch."""
        return self._finish("cancelled",
                            error=RuntimeError(f"request {self.rid} "
                                               f"cancelled"))

    def result(self, timeout: float | None = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still pending")
        if self._error is not None:
            raise self._error
        return self._value

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


#: every ServerStats counter, in snapshot order. Each is a live view over
#: a repro.obs.metrics Counter in the server's own registry ("serve.<name>"),
#: so attribute reads/writes, the metrics snapshot, and the CI gate all see
#: one value.
_STAT_COUNTERS = (
    "admitted", "rejected", "completed", "timed_out", "cancelled",
    "failed", "deadline_missed", "batches", "executor_failures", "retries",
    "replacements", "evictions", "stragglers", "recompiles",
    "corrupt_artifacts", "corrupt_arrays", "artifact_warm_starts",
    "artifact_cold_starts",
    # layers the accuracy probe promoted back to fp32 (reduced-precision
    # outputs outside budget never keep serving).
    "precision_promotions",
    # jitted-happy-path accounting: batches served by the jitted apply,
    # and buckets that fell back to the eager supervised path on their
    # first fault.
    "jit_dispatches", "jit_fallbacks",
    # continuous re-placement: probation re-probes run, and evicted layers
    # promoted back onto their original algorithm.
    "probation_reprobes", "probation_promotions",
)
#: dict-shaped stats state, guarded by the SAME registry lock as the
#: counters so snapshot() is one atomic cut across everything.
_STAT_DICTS = ("bucket_batches", "sharded_buckets", "layer_compute_dtypes")


class ServerStats:
    """Serving counters; `snapshot()` is the JSON-safe view benchmarks and
    the CI gate read. `in_flight` is admitted minus every terminal state --
    zero after a drained stop, or requests were dropped.

    Counters are views over a repro.obs.metrics registry (one registry per
    server, enrolled in `metrics.snapshot_all()`): attribute reads return
    the counter value, attribute writes and `inc()` mutate it under the
    registry lock. The dict fields -- `bucket_batches` (per-bucket batch
    counts, int keys), `sharded_buckets` ({bucket: num_shards} served by a
    mesh-sharded plan on the jitted path), `layer_compute_dtypes` (the
    transform-domain dtype per layer of the CURRENTLY served plans,
    refreshed after compile / re-place / recompile / promotion) -- share
    that lock, so `snapshot()` returns an atomic deep copy: no torn
    multi-counter reads, and never a RuntimeError from a dict resized
    mid-iteration while the scheduler thread keeps serving."""

    def __init__(self, registry: "_obs_metrics.MetricsRegistry | None"
                 = None):
        reg = registry or _obs_metrics.new_registry("serve")
        d = self.__dict__
        d["registry"] = reg
        d["_lock"] = reg.lock
        d["_counters"] = {n: reg.counter(f"serve.{n}")
                          for n in _STAT_COUNTERS}
        d["bucket_batches"] = {}
        d["sharded_buckets"] = {}
        d["layer_compute_dtypes"] = {}

    # -- counter views: stats.admitted reads, stats.admitted = v writes --

    def __getattr__(self, name: str):
        try:
            return self.__dict__["_counters"][name].value
        except KeyError:
            raise AttributeError(name) from None

    def __setattr__(self, name: str, value) -> None:
        c = self.__dict__["_counters"].get(name)
        if c is not None:
            c.set(value)
        elif name in _STAT_DICTS:
            with self.__dict__["_lock"]:
                self.__dict__[name] = value
        else:
            self.__dict__[name] = value

    def inc(self, name: str, n: int = 1) -> None:
        self.__dict__["_counters"][name].inc(n)

    def bump_bucket(self, bucket: int) -> None:
        with self._lock:
            self.bucket_batches[bucket] = \
                self.bucket_batches.get(bucket, 0) + 1

    def set_sharded(self, bucket: int, num_shards: int) -> None:
        with self._lock:
            self.sharded_buckets[str(bucket)] = int(num_shards)

    @property
    def in_flight(self) -> int:
        with self._lock:
            c = self.__dict__["_counters"]
            return (c["admitted"].value - c["completed"].value
                    - c["timed_out"].value - c["cancelled"].value
                    - c["failed"].value)

    def snapshot(self) -> dict:
        """Atomic deep-copied JSON-safe view: taken under the registry
        lock, so no counter increment, dict mutation, or in-flight
        transition interleaves with the copy."""
        with self._lock:
            d: dict[str, Any] = {n: c.value
                                 for n, c in
                                 self.__dict__["_counters"].items()}
            d["bucket_batches"] = {str(k): v
                                   for k, v in self.bucket_batches.items()}
            d["sharded_buckets"] = dict(self.sharded_buckets)
            d["layer_compute_dtypes"] = dict(self.layer_compute_dtypes)
            d["in_flight"] = (d["admitted"] - d["completed"]
                              - d["timed_out"] - d["cancelled"]
                              - d["failed"])
            return d


#: the ISSUE/docs name for the stats object; same class.
ServeStats = ServerStats


class Server:
    """Batched inference server over per-bucket compiled NetworkPlans.

    `params` + `graph` describe the network exactly as for
    `repro.core.compile.compile()`; the server compiles (or warm-starts
    from `artifact_dir`) one plan per batch bucket. `start()` launches the
    scheduler thread; `submit()` admits single examples of shape
    `example_shape`; `stop()` drains. Usable as a context manager."""

    def __init__(self, params, graph, *, res: int | None = None,
                 c_in: int = 3, input_shape: Sequence[int] | None = None,
                 algorithm: str = "auto", dtype=None,
                 compute_dtype: str = "float32",
                 config: ServeConfig | None = None,
                 artifact_dir: str | None = None,
                 mesh=None, partition: str | None = None):
        self.config = cfg = config or ServeConfig()
        self.params = params
        self._graph_desc = graph
        self._algorithm = algorithm
        self._dtype = dtype
        self.compute_dtype = str(jnp.dtype(compute_dtype))
        self.mesh = mesh
        self._partition = partition
        self._artifact_dir = artifact_dir
        if artifact_dir is not None:
            os.makedirs(artifact_dir, exist_ok=True)
        if input_shape is not None:
            self.example_shape = tuple(input_shape)[1:]
        elif res is not None:
            self.example_shape = (res, res, c_in)
        else:
            raise ValueError("Server needs res= (image networks) or "
                             "input_shape= (leading dim is the batch)")
        self.buckets = tuple(sorted(set(int(b) for b in cfg.buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got "
                             f"{cfg.buckets}")
        self.stats = ServerStats()
        self.nets: dict[int, _compile.NetworkPlan] = {
            b: self._compile_bucket(b) for b in self.buckets}
        # Mesh binding: buckets the partition covers additionally get a
        # sharded plan that ONLY the jitted happy path dispatches to.
        # Supervision (per-layer hooks, the degrade ladder, replace_layer)
        # stays on the single-logical-device plans above.
        self.sharded_nets: dict[int, _compile.NetworkPlan] = {}
        if mesh is not None:
            for b in self.buckets:
                net = self._compile_bucket(b, sharded=True)
                if net is not None and net.is_sharded():
                    self.sharded_nets[b] = net
                    self.stats.set_sharded(b, net.partition["num_shards"])
        self.np_dtype = np.dtype(self.nets[self.buckets[0]].dtype)
        self._refresh_layer_dtypes()
        # scheduling state
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: list[Ticket] = []
        self._rid = itertools.count()
        self._stop = False
        self._draining = True
        self._thread: threading.Thread | None = None
        # supervision state
        self._batch_timer = {
            b: StepTimer(window=cfg.straggler_window,
                         sigma=cfg.straggler_sigma,
                         min_baseline=cfg.straggler_min_baseline)
            for b in self.buckets}
        self._layer_ewma: dict[tuple[int, str], float] = {}
        self._straggler_counts: dict[str, int] = {}
        self._replaced: set[str] = set()
        self._recompiled = False
        self._service_ewma: float | None = None
        # jitted happy path: per-bucket (plan-identity token, callable);
        # a bucket lands in _jit_broken on its first jitted-path fault and
        # serves eagerly (supervised) from then on.
        self._jit: dict[int, tuple[tuple, Any]] = {}
        self._jit_broken: set[int] = set()
        # continuous re-placement: evicted layer -> {clean, need}; the
        # per-layer window doubles on every failed re-probe.
        self._probation: dict[str, dict] = {}
        self._probation_window: dict[str, int] = {}

    # ---- plan lifecycle --------------------------------------------------

    def _log(self, msg: str) -> None:
        if self.config.verbose:
            print(f"[serve] {msg}", flush=True)

    def _artifact_path(self, bucket: int,
                       sharded: bool = False) -> str | None:
        if self._artifact_dir is None:
            return None
        if sharded:
            from repro.core.partition import mesh_num_shards
            axis, n = mesh_num_shards(self.mesh)
            kind = self._partition or "data"
            return os.path.join(self._artifact_dir,
                                f"plan_b{bucket}_{kind}{n}.npz")
        return os.path.join(self._artifact_dir, f"plan_b{bucket}.npz")

    def _compile_bucket(self, bucket: int, force_cold: bool = False,
                        sharded: bool = False
                        ) -> "_compile.NetworkPlan | None":
        art = self._artifact_path(bucket, sharded=sharded)
        if art is not None and os.path.exists(art):
            if force_cold:
                os.remove(art)
            else:
                bad = _compile.verify_artifact(art)
                if bad:
                    # detected by the per-array checksums: count it, then
                    # let compile()'s load fallback recompile in place.
                    self.stats.inc("corrupt_artifacts")
                    self.stats.inc("corrupt_arrays", len(bad))
                    self._log(f"bucket {bucket} artifact fails integrity "
                              f"check ({len(bad)} arrays, e.g. {bad[0]!r}); "
                              f"recompiling in place")
        before = _plan.plan_cache_info()["artifact_hits"]
        try:
            net = _compile.compile(
                self.params, self._graph_desc,
                input_shape=(bucket,) + self.example_shape,
                algorithm=self._algorithm, dtype=self._dtype,
                compute_dtype=self.compute_dtype, artifact=art,
                mesh=self.mesh if sharded else None,
                partition=self._partition if sharded else None)
        except Exception as e:
            if not sharded:
                raise
            # a bucket the mesh cannot serve is not fatal: the jitted path
            # simply runs that bucket's single-logical-device plan.
            self._log(f"bucket {bucket}: sharded compile unavailable "
                      f"({e!r}); serving the unsharded plan")
            return None
        if art is not None:
            if _plan.plan_cache_info()["artifact_hits"] > before:
                self.stats.inc("artifact_warm_starts")
            else:
                self.stats.inc("artifact_cold_starts")
        return net

    def _refresh_layer_dtypes(self) -> None:
        """Re-derive stats.layer_compute_dtypes from the currently served
        plans (the smallest bucket; placement is identical across
        buckets)."""
        net = self.nets[self.buckets[0]]
        self.stats.layer_compute_dtypes = {
            nid: p.describe().get("compute_dtype", "float32")
            for nid, p in net.plans.items()}

    def warmup(self) -> None:
        """Pre-warm every bucket: one zero batch per bucket plan, so every
        per-layer executable is compiled and cached before traffic. Runs
        under the same supervisor as live batches -- a faulty executor
        discovered at warmup degrades instead of failing startup. Servers
        with a reduced compute_dtype also run the accuracy probe here, so a
        layer whose quantized output is outside budget is promoted to fp32
        before any client traffic sees it."""
        for b in self.buckets:
            x = jnp.zeros((b,) + self.example_shape, self.np_dtype)
            y, _ = self._supervised_apply(b, jnp.asarray(x))
            jax.block_until_ready(y)
            if self.config.jit_dispatch:
                try:
                    jax.block_until_ready(self._jitted_apply(b, x))
                except Exception as e:
                    self._jit_broken.add(b)
                    self.stats.inc("jit_fallbacks")
                    self._log(f"bucket {b}: jitted path failed at warmup "
                              f"({e!r}); serving eagerly")
        if self.compute_dtype != "float32" and self.config.precision_probe:
            self.probe_precision()

    def _fresh_plan(self, node, in_shape, *, algorithm: str,
                    compute_dtype: str = "float32", groups: int = 1):
        """A freshly planned executor for one conv-family node at its real
        serving shape -- the shared oracle builder behind the precision
        probe and probation re-probes."""
        a = node.attrs
        param = lambda path: _compile._param(self.params, path)
        if node.op == "conv2d":
            return _plan.plan_conv2d(
                in_shape, param(a["w_path"]), stride=tuple(a["stride"]),
                padding=a["padding"], groups=groups, algorithm=algorithm,
                dtype=self._dtype, compute_dtype=compute_dtype)
        if node.op == "separable":
            return _plan.plan_separable_block(
                in_shape, param(a["dw_w"]), param(a["pw_w"]),
                stride=tuple(a["stride"]), padding=a["padding"],
                algorithm=algorithm, dtype=self._dtype,
                compute_dtype=compute_dtype)
        if node.op == "inverted_residual":
            return _plan.plan_inverted_residual(
                in_shape,
                param(a["exp_w"]) if a.get("exp_w") else None,
                param(a["dw_w"]), param(a["pw_w"]),
                stride=tuple(a["stride"]), padding=a["padding"],
                algorithm=algorithm, dtype=self._dtype,
                compute_dtype=compute_dtype)
        raise ValueError(f"no fresh-plan recipe for op {node.op!r}")

    def probe_precision(self, *, seed: int = 0) -> dict:
        """The reduced-precision accuracy probe: every conv layer currently
        serving a bf16/int8 transform-domain plan is checked against a
        freshly planned fp32 executor on a random input of the layer's real
        shape (relative max-abs error -- the same oracle shape as the
        auto_tuned dtype gate). A layer whose error exceeds its per-dtype
        budget (config.precision_budget, defaulting to
        plan.AUTOTUNE_ACCURACY_BUDGET) is promoted back to fp32 across
        EVERY bucket plan, counted in stats.precision_promotions. Returns
        {layer: {compute_dtype, rel_err, budget, promoted}}."""
        budget = dict(_plan.AUTOTUNE_ACCURACY_BUDGET,
                      **(self.config.precision_budget or {}))
        net = self.nets[self.buckets[0]]
        shapes = _compile.infer_shapes(net.graph, net.input_shape)
        rng = np.random.default_rng(seed)
        report: dict[str, dict] = {}
        for node in net.graph:
            p = net.plans.get(node.id)
            if p is None or node.op not in ("conv2d", "separable",
                                            "inverted_residual"):
                continue
            cd = p.describe().get("compute_dtype", "float32")
            if cd == "float32":
                continue
            in_shape = shapes[node.inputs[0]]
            x = jnp.asarray(rng.standard_normal(in_shape), np.float32)
            ref = self._fresh_plan(node, in_shape, algorithm="auto",
                                   groups=getattr(
                                       getattr(p, "spec", None), "groups", 1))
            y = np.asarray(p.apply(x), np.float32)
            y0 = np.asarray(ref.apply(x), np.float32)
            err = float(np.max(np.abs(y - y0))
                        / (float(np.max(np.abs(y0))) or 1.0))
            # block describes may join differing sub-plan dtypes with "+";
            # the tightest component budget judges the whole block.
            bud = min((budget.get(c, math.inf) for c in cd.split("+")),
                      default=math.inf)
            promoted = False
            if err > bud:
                try:
                    for n in self.nets.values():
                        n.replace_layer(node.id, self.params,
                                        algorithm=self._algorithm,
                                        compute_dtype="float32")
                    promoted = True
                    self.stats.inc("precision_promotions")
                    self._log(f"promoted layer {node.id!r} {cd} -> float32 "
                              f"(probe rel err {err:.3g} > budget {bud:g})")
                except Exception as e:
                    self._log(f"could not promote layer {node.id!r} to "
                              f"fp32: {e!r}")
            report[node.id] = {"compute_dtype": cd, "rel_err": err,
                               "budget": bud, "promoted": promoted}
        if any(r["promoted"] for r in report.values()):
            self._refresh_layer_dtypes()
        return report

    # ---- lifecycle -------------------------------------------------------

    def start(self, warmup: bool = True) -> "Server":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if warmup:
            self.warmup()
        self._stop = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-serve-scheduler")
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler. `drain=True` (default) serves everything
        already admitted first; `drain=False` cancels the queue."""
        with self._cv:
            self._stop = True
            self._draining = drain
            if not drain:
                for t in self._queue:
                    if t.cancel():
                        self.stats.inc("cancelled")
                self._queue.clear()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=120)
            self._thread = None

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- admission -------------------------------------------------------

    def submit(self, x, *, deadline_s: float | None = None) -> Ticket:
        """Admit one example (shape `example_shape`). Raises QueueFullError
        (with retry_after_s) when the bounded queue is full."""
        x = np.asarray(x, self.np_dtype)
        if x.shape != self.example_shape:
            raise ValueError(f"expected example of shape "
                             f"{self.example_shape}, got {x.shape}")
        now = time.perf_counter()
        dl = (deadline_s if deadline_s is not None
              else self.config.default_deadline_s)
        deadline = now + dl if dl is not None else None
        with self._cv:
            if self._stop:
                raise RuntimeError("server is stopped")
            if len(self._queue) >= self.config.queue_capacity:
                self.stats.inc("rejected")
                raise QueueFullError(self._retry_after_locked(),
                                     self.config.queue_capacity)
            t = Ticket(next(self._rid), x, deadline, now)
            self._queue.append(t)
            self.stats.inc("admitted")
            self._cv.notify()
        return t

    def _retry_after_locked(self) -> float:
        est = self._service_ewma if self._service_ewma else 0.05
        waves = math.ceil((len(self._queue) + 1) / self.buckets[-1])
        return waves * est

    # ---- scheduling ------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _loop(self) -> None:
        cfg = self.config
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(0.1)
                if self._stop and (not self._queue or not self._draining):
                    return
                # dynamic batch formation: let a burst coalesce into a
                # fuller bucket instead of dispatching singles.
                if (0 < len(self._queue) < self.buckets[-1]
                        and not self._stop and cfg.batch_wait_s > 0):
                    self._cv.wait(cfg.batch_wait_s)
                now = time.perf_counter()
                live = []
                for t in self._queue:
                    if t.done():                    # client-side cancel
                        self.stats.inc("cancelled")
                    elif t.deadline is not None and t.deadline <= now:
                        # timeout-cancel while queued: never executed
                        t._finish("timeout", error=TimeoutError(
                            f"request {t.rid} deadline expired "
                            f"{now - t.deadline:.3f}s before dispatch"))
                        self.stats.inc("timed_out")
                    else:
                        live.append(t)
                # EDF: earliest deadline first, FIFO among deadline-less.
                live.sort(key=lambda t: (
                    t.deadline if t.deadline is not None else math.inf,
                    t.rid))
                take = min(len(live), self.buckets[-1])
                batch, self._queue = live[:take], live[take:]
                # queue-wait / batch-formation boundary for the profiler:
                # everything before this stamp is time spent queued,
                # everything until dispatch start is batch assembly.
                t_select = time.perf_counter()
            if batch:
                self._run_batch(batch, t_select)

    def _run_batch(self, batch: list[Ticket],
                   t_select: float | None = None) -> None:
        prof = _obs_profile.active()   # ONE global read; None = disabled
        b = self._bucket_for(len(batch))
        X = np.zeros((b,) + self.example_shape, self.np_dtype)
        for i, t in enumerate(batch):
            X[i] = t.x
        t0 = time.perf_counter()
        fails_before = self.stats.executor_failures
        jit_before = self.stats.jit_dispatches
        try:
            y, layer_times = self._dispatch(b, jnp.asarray(X))
        except Exception as e:
            # ladder exhausted: answer every ticket with the error --
            # failed, but never silently dropped.
            for t in batch:
                if t._finish("error", error=e):
                    self.stats.inc("failed")
            self.stats.inc("batches")
            if prof is not None:
                prof.serve_batch_error(bucket=b, batch=batch, error=e)
            return
        t1 = time.perf_counter()
        dt = t1 - t0
        a = self.config.ewma_alpha
        self._service_ewma = (dt if self._service_ewma is None
                              else (1 - a) * self._service_ewma + a * dt)
        self._observe_stragglers(b, dt, layer_times)
        y = np.asarray(y)
        now = time.perf_counter()
        for i, t in enumerate(batch):
            if t.deadline is not None and t.deadline < now:
                t.deadline_missed = True
                self.stats.inc("deadline_missed")
            if t._finish("ok", value=y[i]):
                self.stats.inc("completed")
        self.stats.inc("batches")
        self.stats.bump_bucket(b)
        if prof is not None:
            prof.serve_batch(
                bucket=b, batch=batch, net=self.nets.get(b),
                t_select=t_select if t_select is not None else t0,
                t0=t0, t1=t1, layer_times=layer_times,
                jitted=self.stats.jit_dispatches > jit_before,
                sharded=b in self.sharded_nets)
        if self.stats.executor_failures == fails_before:
            self._note_clean_batch()

    # ---- dispatch: the jitted happy path ---------------------------------

    def _jitted_apply(self, bucket: int, X):
        """One batch through the jitted apply. The callable is cached per
        bucket keyed on a plan-identity token, so swapping ANY bound plan
        (re-placement, recompile, fault injection) forces a re-trace --
        a python-level fault proxy always executes at least once instead
        of being silently baked out of a stale jit cache. Prefers the
        mesh-sharded plan when the bucket has one."""
        net = self.sharded_nets.get(bucket) or self.nets[bucket]
        token = (id(net), *map(id, net.plans.values()))
        cached = self._jit.get(bucket)
        if cached is None or cached[0] != token:
            fn = net.apply if net.is_sharded() else jax.jit(net.apply)
            cached = (token, fn)
            self._jit[bucket] = cached
        return cached[1](X)

    def _dispatch(self, bucket: int, X) -> tuple[Any, dict]:
        """Jitted happy path until the bucket's first fault, then the eager
        supervised path (per-layer hooks + the degrade ladder) for that
        bucket from then on. The jitted-path failure counts as the batch's
        first failure+retry: the batch is immediately retried eagerly."""
        if self.config.jit_dispatch and bucket not in self._jit_broken:
            try:
                y = self._jitted_apply(bucket, X)
                jax.block_until_ready(y)
                self.stats.inc("jit_dispatches")
                return y, {}
            except Exception as e:
                self._jit_broken.add(bucket)
                self.stats.inc("jit_fallbacks")
                self.stats.inc("executor_failures")
                self.stats.inc("retries")
                self._log(f"bucket {bucket}: jitted path fault ({e!r}); "
                          f"falling back to the eager supervised path")
        return self._supervised_apply(bucket, X)

    # ---- supervision: the degrade ladder ---------------------------------

    def _supervised_apply(self, bucket: int, X) -> tuple[Any, dict]:
        """Retry with backoff -> re-place the failing layer -> recompile in
        place. The batch re-runs after every rung, so in-flight requests
        survive each recoverable fault; raises only when the whole ladder
        is exhausted."""
        cfg = self.config
        backoff = Backoff(base=cfg.backoff_base_s, cap=cfg.backoff_cap_s,
                          seed=self.stats.batches)
        failures = 0
        while True:
            layer_times: dict[str, float] = {}
            try:
                y = self.nets[bucket].apply(
                    X, layer_hook=layer_times.__setitem__,
                    annotate_errors=True)
                return y, layer_times
            except Exception as e:
                failures += 1
                self.stats.inc("executor_failures")
                if failures <= cfg.max_retries:
                    self.stats.inc("retries")
                    time.sleep(backoff.next())
                    continue
                node = getattr(e, "node_id", None)
                if (node is not None and node not in self._replaced
                        and node in self.nets[bucket].plans
                        and self._replace_layer(
                            node, reason=f"executor failure: "
                                         f"{e.__cause__ or e!r}")):
                    failures = 0
                    backoff.reset()
                    continue
                if self._recompile_in_place():
                    failures = 0
                    backoff.reset()
                    continue
                raise

    def _replace_layer(self, node_id: str, *, reason: str = "",
                       count_eviction: bool = False) -> bool:
        """Rung 2: re-place one layer onto the fallback executor across
        EVERY bucket plan (a bad executor is bad at every batch size)."""
        alg = self.config.fallback_algorithm
        try:
            for net in self.nets.values():
                net.replace_layer(node_id, self.params, algorithm=alg)
        except Exception as e:
            self._log(f"could not re-place layer {node_id!r} onto "
                      f"{alg!r}: {e!r}")
            return False
        self._replaced.add(node_id)
        self.stats.inc("replacements")
        self._refresh_layer_dtypes()
        if count_eviction:
            self.stats.inc("evictions")
        if self.config.probation_batches > 0:
            win = self._probation_window.setdefault(
                node_id, self.config.probation_batches)
            self._probation[node_id] = {"clean": 0, "need": win}
        self._log(f"re-placed layer {node_id!r} onto {alg!r} ({reason})")
        return True

    # ---- probation: continuous re-placement ------------------------------

    def _note_clean_batch(self) -> None:
        """Count a fault-free batch towards every on-probation layer; when
        a layer's window fills, re-probe it for promotion."""
        if not self._probation:
            return
        for nid in list(self._probation):
            st = self._probation[nid]
            st["clean"] += 1
            if st["clean"] >= st["need"]:
                self._probe_and_promote(nid)

    def _probe_and_promote(self, node_id: str) -> bool:
        """Probation window expired: re-probe the evicted layer's original
        algorithm against the serving fallback plan on a random input of
        the layer's real shape. On parity (rel err <= probation_tol) the
        layer is promoted back onto the primary algorithm across EVERY
        bucket plan; on a failed probe the window doubles and probation
        restarts, so a persistently bad executor is re-probed ever more
        rarely instead of flapping."""
        cfg = self.config
        self.stats.inc("probation_reprobes")
        net = self.nets[self.buckets[0]]
        node = next(n for n in net.graph if n.id == node_id)
        shapes = _compile.infer_shapes(net.graph, net.input_shape)
        in_shape = shapes[node.inputs[0]]
        rng = np.random.default_rng(self.stats.batches)
        x = jnp.asarray(rng.standard_normal(in_shape), np.float32)
        err = math.inf
        try:
            cand = self._fresh_plan(node, in_shape,
                                    algorithm=self._algorithm)
            cur = net.plans[node_id]
            if (hasattr(cand, "residual") and hasattr(cur, "residual")
                    and cand.residual != cur.residual):
                cand = dataclasses.replace(cand, residual=cur.residual)
            y = np.asarray(cand.apply(x), np.float32)
            y0 = np.asarray(cur.apply(x), np.float32)
            err = float(np.max(np.abs(y - y0))
                        / (float(np.max(np.abs(y0))) or 1.0))
            ok = err <= cfg.probation_tol
            if ok:
                for n in self.nets.values():
                    n.replace_layer(node_id, self.params,
                                    algorithm=self._algorithm)
        except Exception as e:
            self._log(f"probation re-probe of {node_id!r} raised {e!r}")
            ok = False
        if not ok:
            win = self._probation_window.get(
                node_id, cfg.probation_batches) * 2
            self._probation_window[node_id] = win
            self._probation[node_id] = {"clean": 0, "need": win}
            self._log(f"layer {node_id!r} failed its probation re-probe "
                      f"(rel err {err:.3g} > {cfg.probation_tol:g}); "
                      f"window doubled to {win} clean batches")
            return False
        self._replaced.discard(node_id)
        self._probation.pop(node_id, None)
        self._probation_window.pop(node_id, None)
        self._straggler_counts.pop(node_id, None)
        self.stats.inc("probation_promotions")
        self._refresh_layer_dtypes()
        self._log(f"promoted layer {node_id!r} back onto "
                  f"{self._algorithm!r} after probation "
                  f"(re-probe rel err {err:.3g})")
        return True

    def _recompile_in_place(self) -> bool:
        """Rung 3: rebuild every bucket plan from raw params, recording the
        per-array integrity findings of the on-disk artifacts (the
        corrupt-artifact fault class) and overwriting them with fresh
        ones. One shot per server lifetime -- a fault that survives a full
        recompile is not recoverable here."""
        if self._recompiled:
            return False
        self._recompiled = True
        corrupt = []
        for b in self.buckets:
            art = self._artifact_path(b)
            if art and os.path.exists(art):
                corrupt += [f"b{b}:{k}"
                            for k in _compile.verify_artifact(art)]
        if corrupt:
            self.stats.inc("corrupt_artifacts")
            self.stats.inc("corrupt_arrays", len(corrupt))
        for b in self.buckets:
            self.nets[b] = self._compile_bucket(b, force_cold=True)
        self._replaced.clear()
        self._straggler_counts.clear()
        self._probation.clear()
        self._probation_window.clear()
        self._jit_broken.clear()
        self._refresh_layer_dtypes()
        self.stats.inc("recompiles")
        self._log(f"recompiled all bucket plans in place "
                  f"({len(corrupt)} corrupt artifact arrays"
                  + (f", e.g. {corrupt[0]!r}" if corrupt else "") + ")")
        return True

    def _observe_stragglers(self, bucket: int, dt: float,
                            layer_times: dict[str, float]) -> None:
        cfg = self.config
        if self._batch_timer[bucket].record(dt):
            self.stats.inc("stragglers")
            worst, ratio = None, cfg.straggler_layer_ratio
            for nid, t in layer_times.items():
                base = self._layer_ewma.get((bucket, nid))
                if base and t / base >= ratio:
                    worst, ratio = nid, t / base
            if worst is not None:
                n = self._straggler_counts.get(worst, 0) + 1
                self._straggler_counts[worst] = n
                if (n >= cfg.straggler_evict_after
                        and worst not in self._replaced):
                    self._replace_layer(
                        worst, count_eviction=True,
                        reason=f"straggler x{n}, {ratio:.1f}x baseline")
            return
        # only non-straggler batches update the per-layer baselines
        # (mirrors StepTimer: outliers never pollute the window that
        # judges the next sample).
        a = cfg.ewma_alpha
        for nid, t in layer_times.items():
            k = (bucket, nid)
            old = self._layer_ewma.get(k)
            self._layer_ewma[k] = t if old is None else \
                (1 - a) * old + a * t


# ---------------------------------------------------------------------------
# CLI: artifact audit
# ---------------------------------------------------------------------------

def audit_artifact(path: str) -> list[tuple[str, str]]:
    """Per-array digest status of one NetworkPlan artifact: a list of
    (array_name, status) with status one of "ok", "corrupt" (digest
    mismatch), "missing" (named in the integrity header but absent from
    the file), or "unreadable" (the file / header itself is broken,
    reported as the pseudo-array "__header__"). Unlike
    `compile.verify_artifact` -- which only returns the offenders for the
    supervisor's corrupt-vs-bug decision -- this keeps the full roster so
    the CLI can show what was checked."""
    try:
        with np.load(path, allow_pickle=False) as data:
            if "__header__" not in data:
                return [("__header__", "unreadable")]
            header = json.loads(str(data["__header__"][()]))
            checksums = header.get("checksums")
            if not isinstance(checksums, dict):
                return [("__header__", "unreadable")]
            payload = {k for k in data.files if k != "__header__"}
            rows: list[tuple[str, str]] = []
            for name in sorted(set(checksums) | payload):
                if name not in payload:
                    rows.append((name, "missing"))
                elif checksums.get(name) is None:
                    rows.append((name, "corrupt"))
                elif _compile._array_digest(data[name]) \
                        == checksums[name]:
                    rows.append((name, "ok"))
                else:
                    rows.append((name, "corrupt"))
            return rows
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return [("__header__", "unreadable")]


def main(argv: Sequence[str] | None = None) -> int:
    """`python -m repro.runtime.serve verify-artifacts <dir>`: audit every
    plan_b<B>.npz bucket artifact in a server artifact directory and print
    per-array digest status. Exit 0 when every array in every bucket
    verifies, 1 on any corruption, 2 on usage / empty directory."""
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.runtime.serve",
        description="Serving-runtime maintenance commands.")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_verify = sub.add_parser(
        "verify-artifacts",
        help="integrity-audit every plan_b<B>.npz in an artifact dir")
    p_verify.add_argument("dir", help="artifact directory (the "
                          "`artifact_dir` a Server was compiled against)")
    p_verify.add_argument("-q", "--quiet", action="store_true",
                          help="only print per-file summaries and failures")
    args = parser.parse_args(argv)

    if not os.path.isdir(args.dir):
        print(f"error: not a directory: {args.dir}")
        return 2
    paths = sorted(
        os.path.join(args.dir, f) for f in os.listdir(args.dir)
        if f.startswith("plan_b") and f.endswith(".npz"))
    if not paths:
        print(f"error: no plan_b<B>.npz artifacts under {args.dir}")
        return 2

    corrupt_total = 0
    for path in paths:
        rows = audit_artifact(path)
        bad = [(n, s) for n, s in rows if s != "ok"]
        corrupt_total += len(bad)
        verdict = "OK" if not bad else "CORRUPT"
        print(f"{os.path.basename(path)}: {verdict} "
              f"({len(rows) - len(bad)}/{len(rows)} arrays verified)")
        for name, status in rows:
            if status == "ok" and args.quiet:
                continue
            mark = "ok     " if status == "ok" else status.upper().ljust(7)
            print(f"  [{mark}] {name}")
    total = len(paths)
    print(f"{total} artifact(s) audited, "
          f"{corrupt_total} bad array(s)" if corrupt_total
          else f"{total} artifact(s) audited, all digests verified")
    return 1 if corrupt_total else 0


if __name__ == "__main__":
    raise SystemExit(main())
