"""The Profiler: wires tracing + metrics through the serving hot path.

`enable()` installs the global tracer (repro.obs.trace) and a `Profiler`
that `runtime/serve.py` consults via `active()` -- one global read per
batch, None when profiling is off, so the disabled serve path records
nothing (tested). compile() pass phases and plan-cache / autotune-race
events in core/plan.py and core/compile.py report through the same
global tracer directly, so enabling the profiler lights up the whole
stack: plan -> compile -> serve in one trace.

Per-request decomposition (`serve_batch`): the server hands over the
batch's boundary timestamps -- submit (per ticket), batch selection,
dispatch start/end, finish (per ticket) -- plus the per-layer wall times
that `NetworkPlan.apply(layer_hook=)` measured on the eager supervised
path. The profiler turns those into spans:

    serve.queue_wait        submit -> batch selection        (per request)
    serve.batch_formation   selection -> dispatch start      (per request)
    serve.dispatch          dispatch start -> end            (per batch)
      layer:<node_id>         sequential children, one per planned layer,
                              tagged with the executing plan's executor
    serve.respond           dispatch end -> ticket finish    (per request)

Those four intervals tile [submit, finish] exactly (same perf_counter
clock, shared boundaries), so per request they sum to the measured
latency -- the acceptance contract tests/test_obs.py asserts. Layer spans
exist only when the eager supervised path ran; the jitted (and sharded)
happy path cannot observe layer boundaries inside the fused computation,
so its dispatch span stands alone (`jitted=True`).

Latency/queue-wait/dispatch histograms go to the default metrics
registry under `serve.*`.
"""

from __future__ import annotations

from typing import Any

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = ["Profiler", "enable", "disable", "active", "is_enabled"]


def _executor_of(plan: Any) -> str:
    """Best-effort executor label for a bound layer plan."""
    try:
        return str(plan.describe().get("executor", type(plan).__name__))
    except Exception:
        return type(plan).__name__


class Profiler:
    """Span + histogram emission for one process; see module docstring."""

    def __init__(self, tracer: _trace.Tracer,
                 registry: _metrics.MetricsRegistry | None = None):
        self.tracer = tracer
        self.registry = registry or _metrics.registry()

    # ---- the serve hot path ----------------------------------------------

    def serve_batch(self, *, bucket: int, batch: list, net: Any,
                    t_select: float, t0: float, t1: float,
                    layer_times: dict[str, float],
                    jitted: bool, sharded: bool = False) -> None:
        """Record one dispatched batch. `batch` is the ticket list
        (rid / submitted_at / finished_at), `t_select` the batch-selection
        stamp from the scheduler loop, [t0, t1] the dispatch interval,
        `layer_times` the per-node wall seconds from layer_hook (empty on
        the jitted path)."""
        tr, reg = self.tracer, self.registry
        tr.add_span("serve.dispatch", t0, t1, bucket=bucket,
                    batch=len(batch), jitted=jitted, sharded=sharded)
        reg.observe("serve.dispatch_s", t1 - t0)
        # Layer children: apply() runs nodes sequentially and the hook
        # fires with each node's own wall time, so laying the durations
        # end-to-end from t0 reconstructs starts to within the (un-hooked)
        # pad/pool/add glue between planned layers.
        cursor = t0
        for nid, dt in layer_times.items():
            plan = net.plans.get(nid) if net is not None else None
            tr.add_span(f"layer:{nid}", cursor, cursor + dt,
                        executor=_executor_of(plan))
            reg.observe("serve.layer_s", dt)
            cursor += dt
        for t in batch:
            rid = t.rid
            tr.add_span("serve.queue_wait", t.submitted_at, t_select,
                        rid=rid, bucket=bucket)
            tr.add_span("serve.batch_formation", t_select, t0,
                        rid=rid, bucket=bucket)
            reg.observe("serve.queue_wait_s", t_select - t.submitted_at)
            fin = t.finished_at
            if fin is not None:
                tr.add_span("serve.respond", t1, fin, rid=rid,
                            bucket=bucket)
                reg.observe("serve.latency_s", fin - t.submitted_at)

    def serve_batch_error(self, *, bucket: int, batch: list,
                          error: BaseException) -> None:
        self.tracer.instant("serve.batch_error", bucket=bucket,
                            batch=len(batch), error=repr(error))
        self.registry.count("serve.batch_errors")


# ---------------------------------------------------------------------------
# Global profiler: disabled (None) by default
# ---------------------------------------------------------------------------

_PROFILER: Profiler | None = None


def enable(capacity: int = _trace.DEFAULT_CAPACITY,
           registry: _metrics.MetricsRegistry | None = None) -> Profiler:
    """Turn on profiling: installs the global tracer (lighting up the
    compile/plan spans too) and the serve-path profiler."""
    global _PROFILER
    tracer = _trace.enable(capacity)
    if _PROFILER is None or _PROFILER.tracer is not tracer:
        _PROFILER = Profiler(tracer, registry)
    return _PROFILER


def disable(tracing: bool = True) -> None:
    """Turn the profiler off; `tracing=False` keeps the tracer (and its
    recorded spans) alive for inspection/export."""
    global _PROFILER
    _PROFILER = None
    if tracing:
        _trace.disable()


def active() -> Profiler | None:
    """The serve path's single disabled-check: None when profiling is off."""
    return _PROFILER


def is_enabled() -> bool:
    return _PROFILER is not None
