"""Process-level metrics: counters, gauges, log-bucketed histograms.
Stdlib only.

A `MetricsRegistry` owns a flat namespace of instruments behind ONE
re-entrant lock, so `snapshot()` is atomic: no counter increments, no
histogram records, and no dict-shaped state mutations interleave with the
deep copy it returns. That lock is deliberately exposed (`registry.lock`)
so composite owners -- the serving runtime's ServerStats, whose dict
fields (bucket_batches, ...) live next to its registry counters -- can
extend the same atomicity to their own state.

Instruments:

- `Counter`  -- monotone-by-convention int; `inc(n)` / `set(v)`.
- `Gauge`    -- last-write-wins float.
- `Histogram` -- base-2 log-bucketed distribution of positive floats
  (bucket i covers (2^(i-1), 2^i]); tracks count/sum/min/max and answers
  `percentile(q)` with the upper bound of the covering bucket, which for
  latencies is within 2x of the true quantile at ~200 bytes of state.

The module-level default registry (`registry()`, `count()`, `observe()`)
is always on -- an increment is one dict lookup plus a locked int add, a
few hundred nanoseconds, paid on plan/compile/serve *events* (not per
array element), so it needs no enable switch. `snapshot_all()` merges the
default registry and every live named registry (servers register theirs
on construction) into one JSON-safe dict.
"""

from __future__ import annotations

import math
import threading
import weakref
from typing import Any

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "registry", "count", "observe", "gauge", "snapshot_all",
           "reset"]


class Counter:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def set(self, v: int) -> None:
        with self._lock:
            self.value = int(v)

    def get(self) -> int:
        return self.value


class Gauge:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def get(self) -> float:
        return self.value


class Histogram:
    """Log-2 bucketed histogram of positive samples (seconds, bytes, ...).

    Bucket keyed by exponent e = ceil(log2(x)): x in (2^(e-1), 2^e].
    Zero/negative samples land in the dedicated underflow bucket (None)."""

    __slots__ = ("name", "buckets", "n", "total", "min", "max", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.buckets: dict[int | None, int] = {}
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = lock

    def record(self, x: float) -> None:
        key = None if x <= 0.0 else int(math.ceil(math.log2(x)))
        with self._lock:
            self.buckets[key] = self.buckets.get(key, 0) + 1
            self.n += 1
            self.total += x
            self.min = min(self.min, x)
            self.max = max(self.max, x)

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0 < q <= 1)."""
        with self._lock:
            if self.n == 0:
                return 0.0
            rank = q * self.n
            seen = 0
            for key in sorted(self.buckets,
                              key=lambda k: -math.inf if k is None else k):
                seen += self.buckets[key]
                if seen >= rank:
                    return 0.0 if key is None else min(2.0 ** key, self.max)
            return self.max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def state(self) -> dict:
        return {"count": self.n, "sum": self.total,
                "min": self.min if self.n else None,
                "max": self.max if self.n else None,
                "mean": self.mean,
                "p50": self.percentile(0.50),
                "p99": self.percentile(0.99),
                "buckets": {("underflow" if k is None else f"le_2^{k}"): v
                            for k, v in sorted(
                                self.buckets.items(),
                                key=lambda kv: (-math.inf
                                                if kv[0] is None
                                                else kv[0]))}}


class MetricsRegistry:
    """Get-or-create instrument registry; one lock covers every mutation
    and the snapshot, making `snapshot()` an atomic consistent cut."""

    def __init__(self, name: str = "default"):
        self.name = name
        self.lock = threading.RLock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self.lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self.lock)
            return c

    def gauge(self, name: str) -> Gauge:
        with self.lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self.lock)
            return g

    def histogram(self, name: str) -> Histogram:
        with self.lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, self.lock)
            return h

    def count(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, x: float) -> None:
        self.histogram(name).record(x)

    def snapshot(self) -> dict:
        """JSON-safe deep copy taken under the registry lock: atomic with
        respect to every instrument mutation AND any owner state guarded
        by the same lock (ServerStats dict fields)."""
        with self.lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.state()
                               for n, h in sorted(
                                   self._histograms.items())},
            }

    def reset(self) -> None:
        with self.lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# Default registry + the live-registry roster
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry("default")
#: every registry constructed through new_registry(), weakly held, so
#: snapshot_all() sees per-server registries exactly as long as they live.
_LIVE: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()
_LIVE.add(_DEFAULT)


def registry() -> MetricsRegistry:
    return _DEFAULT


def new_registry(name: str) -> MetricsRegistry:
    reg = MetricsRegistry(name)
    _LIVE.add(reg)
    return reg


def count(name: str, n: int = 1) -> None:
    _DEFAULT.count(name, n)


def observe(name: str, x: float) -> None:
    _DEFAULT.observe(name, x)


def gauge(name: str, v: float) -> None:
    _DEFAULT.gauge(name).set(v)


def snapshot_all() -> dict[str, Any]:
    """{registry_name: snapshot} over the default + every live registry.
    Registries sharing a name (several servers) get a numeric suffix."""
    out: dict[str, Any] = {}
    for reg in sorted(_LIVE, key=lambda r: (r.name != "default", r.name)):
        key, i = reg.name, 1
        while key in out:
            i += 1
            key = f"{reg.name}#{i}"
        out[key] = reg.snapshot()
    return out


def reset() -> None:
    """Clear the default registry (tests)."""
    _DEFAULT.reset()
