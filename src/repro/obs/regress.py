"""Tracked-metric extraction + regression compare over BENCH_*.json.

Every benchmark in this repo emits a JSON artifact (BENCH_PR2..PR10);
this module gives them one regression contract: `extract(doc)` maps any
known artifact format to a flat {metric_name: Metric} dict, and
`compare(base, current)` evaluates each shared metric against a
threshold in the metric's own improvement direction. The CLI wrapper is
benchmarks/regress.py; CI runs it over the committed trajectory.

Metric semantics (`kind`):

- "ratio":      regression when worse by more than `threshold` x
                (cur/base for lower-is-better, base/cur for higher).
- "pct_points": additive compare for percentage metrics (the PR10
                observability overhead): regression when worse by more
                than `pct_margin` points. Ratio compares break down when
                the base is ~0%, which a healthy overhead gauge is.
- "count":      zero-tolerance counters (dropped requests, incorrect
                responses): ANY worsening is a regression.
- "bool":       pass/fail gates: True -> False is a regression.

Direction `None` marks informational metrics -- reported, never gated
(e.g. absolute ms in the PR10 artifact, which CI compares across
unrelated machines; its machine-relative overhead metrics carry the
gate instead).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

__all__ = ["Metric", "Finding", "detect", "extract", "compare",
           "summarize", "load"]


@dataclasses.dataclass(frozen=True)
class Metric:
    value: float
    direction: str | None = "lower"   # "lower" | "higher" | None (info)
    kind: str = "ratio"               # "ratio" | "pct_points" | "count"
                                      # | "bool"
    gate: bool = True                 # participates in pass/fail


@dataclasses.dataclass(frozen=True)
class Finding:
    metric: str
    base: float
    current: float
    direction: str | None
    kind: str
    gate: bool
    ratio: float | None               # worsening factor (ratio kind)
    regressed: bool


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Format detection + per-format extractors
# ---------------------------------------------------------------------------

def detect(doc: dict) -> str:
    if doc.get("format") == "repro.observe/v1":
        return "observe"
    if "clean" in doc and "faults" in doc:
        return "serving"
    if "curve" in doc and "speedup_vs_1dev" in doc:
        return "scaling"
    if "rows" in doc and "res" in doc:
        return "startup"
    if "layers" in doc and "summary" in doc:
        return "per_layer"
    return "unknown"


def _num(x: Any) -> float | None:
    return float(x) if isinstance(x, (int, float)) \
        and not isinstance(x, bool) else None


def _extract_serving(doc: dict) -> dict[str, Metric]:
    out: dict[str, Metric] = {}
    for row in doc.get("clean", []):
        p = f"serving.rate{row.get('rate_rps', '?'):g}"
        for k, direction in (("p50_ms", "lower"), ("p99_ms", "lower"),
                             ("mean_ms", "lower"),
                             ("throughput_rps", "higher")):
            v = _num(row.get(k))
            if v is not None:
                out[f"{p}.{k}"] = Metric(v, direction)
        for k in ("dropped", "incorrect"):
            v = _num(row.get(k))
            if v is not None:
                out[f"{p}.{k}"] = Metric(v, "lower", kind="count")
    for k in ("zero_dropped", "zero_incorrect", "fault_survived"):
        if isinstance(doc.get(k), bool):
            out[f"serving.{k}"] = Metric(float(doc[k]), "higher",
                                         kind="bool")
    return out


def _extract_scaling(doc: dict) -> dict[str, Metric]:
    out: dict[str, Metric] = {}
    sp = doc.get("speedup_vs_1dev") or []
    if sp:
        out["scaling.speedup_max_dev"] = Metric(float(sp[-1]), "higher")
    for pt in doc.get("curve", []):
        dev = pt.get("devices", "?")
        for mode in ("batch_sharded", "halo_sharded"):
            v = _num((pt.get(mode) or {}).get("throughput_img_s"))
            if v is not None:
                out[f"scaling.{mode}.throughput_img_s@{dev}dev"] = \
                    Metric(v, "higher")
    for k, v in (doc.get("gates") or {}).items():
        if isinstance(v, bool):
            out[f"scaling.gate.{k}"] = Metric(float(v), "higher",
                                              kind="bool")
    return out


def _extract_startup(doc: dict) -> dict[str, Metric]:
    out: dict[str, Metric] = {}
    for row in doc.get("rows", []):
        p = f"startup.{row.get('network', '?')}"
        for k, direction in (("cold_compile_s", "lower"),
                             ("warm_load_s", "lower"),
                             ("artifact_bytes", "lower"),
                             ("startup_speedup", "higher")):
            v = _num(row.get(k))
            if v is not None:
                out[f"{p}.{k}"] = Metric(v, direction)
        if isinstance(row.get("fresh_process_parity"), bool):
            out[f"{p}.fresh_process_parity"] = Metric(
                float(row["fresh_process_parity"]), "higher", kind="bool")
    return out


def _summary_direction(name: str) -> str | None:
    n = name.lower()
    if "speedup" in n or "agreement" in n or "ratio" in n or "wins" in n:
        return "higher"
    if n.endswith(("_ms", "_s", "_bytes")) or "err" in n or "time" in n:
        return "lower"
    return None


def _extract_per_layer(doc: dict) -> dict[str, Metric]:
    out: dict[str, Metric] = {}
    summary = doc.get("summary")
    rows = summary if isinstance(summary, list) else [summary]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        tag = str(row.get("net", row.get("ltype", i)))
        if isinstance(summary, list) and "ltype" in row and "net" in row:
            tag = f"{row['net']}.{row['ltype']}"
        for k, v in row.items():
            if isinstance(v, dict):      # PR8-style nested {dtype: value}
                for dk, dv in v.items():
                    dv = _num(dv)
                    d = _summary_direction(k)
                    if dv is not None and d is not None:
                        out[f"summary.{tag}.{k}.{dk}"] = Metric(dv, d)
                continue
            v = _num(v)
            d = _summary_direction(k)
            if v is not None and d is not None:
                out[f"summary.{tag}.{k}"] = Metric(v, d)
    return out


def _extract_observe(doc: dict) -> dict[str, Metric]:
    out: dict[str, Metric] = {}
    # Machine-relative gates: both arms measured in the same run on the
    # same machine, so these compare across hosts (CI vs the committed
    # baseline) without tracking absolute hardware speed.
    v = _num(doc.get("overhead_pct"))
    if v is not None:
        out["observe.overhead_pct"] = Metric(v, "lower",
                                             kind="pct_points")
    v = _num((doc.get("decomposition") or {}).get("max_residual_pct"))
    if v is not None:
        out["observe.decomposition_max_residual_pct"] = \
            Metric(v, "lower", kind="pct_points")
    for k, val in (doc.get("gates") or {}).items():
        if isinstance(val, bool):
            out[f"observe.gate.{k}"] = Metric(float(val), "higher",
                                              kind="bool")
    # Absolute latencies: informational (cross-machine compare).
    for k in ("p50_disabled_ms", "p50_enabled_ms"):
        v = _num(doc.get(k))
        if v is not None:
            out[f"observe.{k}"] = Metric(v, None, gate=False)
    v = _num(doc.get("trace_events"))
    if v is not None:
        out["observe.trace_events"] = Metric(v, None, gate=False)
    return out


_EXTRACTORS = {"serving": _extract_serving, "scaling": _extract_scaling,
               "startup": _extract_startup, "per_layer": _extract_per_layer,
               "observe": _extract_observe}


def extract(doc: dict) -> dict[str, Metric]:
    """Tracked metrics of one BENCH artifact ({} for unknown formats)."""
    fn = _EXTRACTORS.get(detect(doc))
    return fn(doc) if fn else {}


# ---------------------------------------------------------------------------
# Compare
# ---------------------------------------------------------------------------

def compare(base: dict, current: dict, *, threshold: float = 1.5,
            pct_margin: float = 5.0) -> list[Finding]:
    """Findings over every metric present in BOTH artifacts, worst first.
    `threshold` is the multiplicative worsening that fails ratio metrics
    (2.0 = twice as slow / half the throughput); `pct_margin` the additive
    worsening (percentage points) that fails pct_points metrics."""
    bm, cm = extract(base), extract(current)
    findings: list[Finding] = []
    for name in sorted(set(bm) & set(cm)):
        b, c = bm[name], cm[name]
        ratio = None
        regressed = False
        if b.direction is not None and b.gate:
            if b.kind == "ratio":
                if b.direction == "lower" and b.value > 0 and c.value > 0:
                    ratio = c.value / b.value
                elif b.direction == "higher" and c.value > 0 \
                        and b.value > 0:
                    ratio = b.value / c.value
                regressed = ratio is not None and ratio > threshold
            elif b.kind == "pct_points":
                delta = (c.value - b.value if b.direction == "lower"
                         else b.value - c.value)
                regressed = delta > pct_margin
            elif b.kind == "count":
                regressed = (c.value > b.value if b.direction == "lower"
                             else c.value < b.value)
            elif b.kind == "bool":
                regressed = bool(b.value) and not bool(c.value)
        findings.append(Finding(name, b.value, c.value, b.direction,
                                b.kind, b.gate, ratio, regressed))
    findings.sort(key=lambda f: (not f.regressed,
                                 -(f.ratio or 0.0), f.metric))
    return findings


def summarize(findings: list[Finding]) -> list[str]:
    lines = []
    for f in findings:
        mark = "REGRESSED" if f.regressed else "ok"
        extra = f" ({f.ratio:.2f}x worse)" if f.regressed and f.ratio \
            else ""
        lines.append(f"  [{mark:>9}] {f.metric}: {f.base:g} -> "
                     f"{f.current:g}{extra}")
    return lines
