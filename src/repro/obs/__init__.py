"""Zero-dependency observability for the planned-convolution stack.

Three layers, from passive to active:

- `repro.obs.trace`   -- thread-safe nested span recorder (ring-buffered,
  explicit monotonic timestamps) exportable as chrome://tracing JSON.
- `repro.obs.metrics` -- process-level registry of counters / gauges /
  log-bucketed histograms with an atomic deep-copied snapshot. The serving
  runtime's ServerStats counters are views over one of these registries.
- `repro.obs.profile` -- the Profiler that wires both through the stack:
  compile() pass phases, plan-cache / autotune-race events, and the serve
  hot path (per-request queue-wait / batch-formation / dispatch /
  per-layer spans via NetworkPlan.apply(layer_hook=)).

Plus two offline tools built on the same data:

- `repro.obs.regress`  -- tracked-metric extraction + threshold compare
  over BENCH_*.json artifacts (the CLI lives in benchmarks/regress.py).
- `repro.obs.tuningdb` -- export/merge the auto_tuned measurement
  evidence persisted in NetworkPlan artifacts into a fleet-shareable
  tuning database that plan_conv2d consumes instead of re-measuring.

Everything here is disabled by default. `trace` and `metrics` import only
the standard library so `core/plan.py` can depend on them unconditionally;
the disabled fast path of every hook is a single global None check.
"""

from repro.obs import metrics, trace  # noqa: F401  (stdlib-only, safe)

__all__ = ["trace", "metrics", "profile", "regress", "tuningdb"]
