"""Thread-safe span tracing with chrome://tracing export. Stdlib only.

A `Tracer` records completed spans -- (name, t0, t1, thread, depth, args)
over `time.perf_counter()` timestamps -- into a bounded ring buffer
(oldest spans drop first; `dropped` counts them). Spans come from three
sources:

- `tracer.span(name, **args)`: a context manager; nesting depth is
  tracked per thread so exporters can reconstruct the call tree even for
  zero-duration spans.
- `tracer.add_span(name, t0, t1, **args)`: explicit timestamps, for code
  that already measured an interval (the serving runtime reconstructs
  per-layer spans from `NetworkPlan.apply(layer_hook=)` durations).
- `tracer.instant(name, **args)`: a point event (cache hits, autotune
  decisions).

The module-level API (`enable()` / `disable()` / `span()` / ...) routes
through one global tracer. Disabled -- the default -- every hook is a
single `is None` check and `span()` returns a shared no-op context
manager, so instrumented hot paths pay (provably, see
tests/test_obs.py::test_serve_disabled_emits_zero_spans) nothing.

`export_chrome()` emits the chrome://tracing / Perfetto "traceEvents"
JSON: "X" complete events (ts/dur in microseconds, rebased to the first
span) plus "i" instants, one row per python thread. Load the file at
chrome://tracing or https://ui.perfetto.dev.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Iterator

__all__ = ["Span", "Tracer", "enable", "disable", "get", "is_enabled",
           "span", "add_span", "instant", "export_chrome", "NULL_SPAN"]

DEFAULT_CAPACITY = 65536


class Span:
    """One completed (or instant: t1 == t0) trace event."""

    __slots__ = ("name", "t0", "t1", "tid", "depth", "args", "phase")

    def __init__(self, name: str, t0: float, t1: float, tid: int,
                 depth: int = 0, args: dict | None = None,
                 phase: str = "X"):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.depth = depth
        self.args = args or {}
        self.phase = phase                 # "X" complete | "i" instant

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:            # pragma: no cover - debug aid
        return (f"Span({self.name!r}, dur={self.duration_s * 1e3:.3f}ms, "
                f"depth={self.depth}, args={self.args})")


class _SpanCtx:
    """Context manager recording one nested span on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        self._depth = self._tracer._push()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        self._tracer._pop()
        if exc_type is not None:
            self._args = dict(self._args, error=repr(exc))
        self._tracer._record(Span(self._name, self._t0, t1,
                                  threading.get_ident(), self._depth,
                                  self._args))
        return False

    def set(self, **args: Any) -> None:
        """Attach args discovered mid-span (e.g. the autotune winner)."""
        self._args = dict(self._args, **args)


class _NullSpan:
    """The disabled-path span: no state, no timestamps, shared instance."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **args: Any) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffered span recorder; every method is thread-safe."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: deque[Span] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._recorded = 0

    # ---- recording -------------------------------------------------------

    def span(self, name: str, **args: Any) -> _SpanCtx:
        return _SpanCtx(self, name, args)

    def add_span(self, name: str, t0: float, t1: float,
                 tid: int | None = None, **args: Any) -> None:
        """Record an interval measured elsewhere (perf_counter stamps)."""
        self._record(Span(name, t0, t1,
                          tid if tid is not None else threading.get_ident(),
                          self._depth(), args))

    def instant(self, name: str, **args: Any) -> None:
        t = time.perf_counter()
        self._record(Span(name, t, t, threading.get_ident(),
                          self._depth(), args, phase="i"))

    def _record(self, s: Span) -> None:
        with self._lock:
            self._buf.append(s)       # deque(maxlen=) drops oldest itself
            self._recorded += 1

    # ---- per-thread nesting depth ----------------------------------------

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    def _push(self) -> int:
        d = getattr(self._local, "depth", 0)
        self._local.depth = d + 1
        return d

    def _pop(self) -> None:
        self._local.depth = max(0, getattr(self._local, "depth", 1) - 1)

    # ---- reading ---------------------------------------------------------

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._recorded - len(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def spans(self, prefix: str | None = None) -> list[Span]:
        """Chronological (by start time) copy, optionally name-filtered."""
        with self._lock:
            out = list(self._buf)
        if prefix is not None:
            out = [s for s in out if s.name.startswith(prefix)]
        out.sort(key=lambda s: s.t0)
        return out

    def __iter__(self) -> Iterator[Span]:
        return iter(self.spans())

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._recorded = 0

    # ---- chrome://tracing export -----------------------------------------

    def export_chrome(self, path: str | None = None) -> dict:
        """The trace as a chrome://tracing JSON object; optionally written
        to `path`. Timestamps rebase to the earliest span so ts starts
        near 0; all times are microseconds per the trace-event spec."""
        spans = self.spans()
        epoch = spans[0].t0 if spans else 0.0
        pid = os.getpid()
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": "repro"}}]
        for s in spans:
            ev = {"name": s.name, "ph": s.phase, "pid": pid, "tid": s.tid,
                  "ts": (s.t0 - epoch) * 1e6, "args": dict(s.args)}
            if s.phase == "X":
                ev["dur"] = (s.t1 - s.t0) * 1e6
            else:
                ev["s"] = "t"                       # thread-scoped instant
            ev["args"]["depth"] = s.depth
            events.append(ev)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"dropped_spans": self.dropped}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


# ---------------------------------------------------------------------------
# The global tracer: disabled (None) by default
# ---------------------------------------------------------------------------

_TRACER: Tracer | None = None


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install (or return the existing) global tracer."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def get() -> Tracer | None:
    return _TRACER


def is_enabled() -> bool:
    return _TRACER is not None


def span(name: str, **args: Any):
    """`with trace.span("compile.place"): ...` -- no-op when disabled."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, **args)


def add_span(name: str, t0: float, t1: float, **args: Any) -> None:
    t = _TRACER
    if t is not None:
        t.add_span(name, t0, t1, **args)


def instant(name: str, **args: Any) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, **args)


def export_chrome(path: str | None = None) -> dict:
    t = _TRACER
    if t is None:
        raise RuntimeError("tracing is disabled; call repro.obs.trace."
                           "enable() before exporting")
    return t.export_chrome(path)
