"""Fleet-shareable tuning database over auto_tuned measurement evidence.

The measured auto_tuned race (core/plan.py:_measure_autotune) already
persists its per-contender evidence into every NetworkPlan artifact, so a
warm artifact load never re-measures -- but a *different* network, batch
bucket, or host that plans the same layer shape starts the race from
scratch. This module closes that gap (the ROADMAP "artifact-level
autotuning" item): it walks artifacts (or live NetworkPlans), lifts each
measured decision into a standalone JSON database keyed by the layer's
planning identity, merges databases from many hosts (fastest winner
wins), and installs the result into `core/plan.py` so `plan_conv2d`
resolves `algorithm="auto_tuned"` layers with ZERO measurements --
adopting the recorded winner/tile/dtype with the original evidence
attached (decision still reports "measured"; the evidence gains a
`source: tuning_db` marker).

Consumption paths, warmest first:

    tuningdb.install("fleet.json")            # explicit, this process
    REPRO_TUNING_DB=fleet.json python ...     # env var, any process

Database shape (JSON):

    {"format": "repro.tuning_db", "version": 1,
     "hosts": [{"node": ..., "machine": ..., "entries": N}, ...],
     "entries": {<layer key>: {"winner": ..., "winner_label": ...,
                               "winner_dtype": ..., "winner_tile": ...,
                               "winner_time_s": ..., "evidence": [[k,v]..]}}}

The layer key is `repro.core.plan.tuning_db_key(...)` -- shapes, dtype,
stride, padding, groups, layout, and the compute_dtype *request* ("auto"
when the race fielded reduced-precision contenders), exactly the inputs
that decide a fresh race. Entries recorded by builds predating the
`pin_dtype`/`dtype_race` evidence keys key themselves conservatively
(pinned float32).
"""

from __future__ import annotations

import json
import os
import platform
import time
from typing import Any, Iterable, Iterator

__all__ = ["FORMAT", "VERSION", "collect", "export", "merge", "load",
           "save", "install", "clear"]

FORMAT = "repro.tuning_db"
VERSION = 1


# ---------------------------------------------------------------------------
# Collection: artifacts / NetworkPlans -> entries
# ---------------------------------------------------------------------------

def _iter_conv_metas(obj: Any) -> Iterator[dict]:
    """Every conv2d plan meta nested anywhere in a header/meta structure
    (separable dw/pw, inverted-residual expand/sep, conv1d inner/subplans
    all carry conv2d metas in nested dicts/lists)."""
    if isinstance(obj, dict):
        if obj.get("kind") == "conv2d":
            yield obj
        for v in obj.values():
            yield from _iter_conv_metas(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from _iter_conv_metas(v)


def _entry_from_meta(meta: dict) -> tuple[str, dict] | None:
    """(key, entry) for one conv meta, or None when it carries no measured
    auto_tuned evidence."""
    if meta.get("requested") != "auto_tuned" or not meta.get("autotune"):
        return None
    ev = {k: v for k, v in meta["autotune"]}
    if "winner" not in ev:
        return None
    from repro.core import plan as _plan
    request = "auto" if ev.get("dtype_race") else \
        str(ev.get("pin_dtype", "float32"))
    req_tile = ev.get("req_tile")
    key = _plan.tuning_db_key(
        meta["x_shape"], meta["w_shape"], meta["dtype"], meta["stride"],
        meta["padding"], meta["groups"], meta.get("layout", "NHWC"),
        request, req_tile)
    label = ev.get("winner_label")
    t_win = ev.get(f"t_{label}_s") if label else None
    tile = ev.get("winner_tile")
    entry = {
        "winner": ev["winner"],
        "winner_label": label,
        "winner_dtype": str(ev.get("winner_dtype", "float32")),
        "winner_tile": list(tile) if tile is not None else None,
        "winner_time_s": float(t_win) if t_win is not None else None,
        "evidence": [[k, (list(v) if isinstance(v, tuple) else v)]
                     for k, v in meta["autotune"]],
    }
    return key, entry


def _header_of_artifact(path: str) -> dict:
    import numpy as np
    with np.load(path, allow_pickle=False) as data:
        if "__header__" not in data:
            raise ValueError(f"{path}: not a NetworkPlan artifact "
                             f"(no __header__)")
        return json.loads(str(data["__header__"][()]))


def collect(source: Any) -> dict[str, dict]:
    """Entries from one source: an artifact path (.npz), a directory of
    artifacts, a live NetworkPlan, or an already-loaded header dict."""
    metas: Iterable[dict]
    if isinstance(source, str):
        if os.path.isdir(source):
            out: dict[str, dict] = {}
            for name in sorted(os.listdir(source)):
                if name.endswith(".npz"):
                    out.update(collect(os.path.join(source, name)))
            return out
        metas = _iter_conv_metas(_header_of_artifact(source))
    elif isinstance(source, dict):
        metas = _iter_conv_metas(source)
    else:
        # a live NetworkPlan: serialize plan metas without touching arrays
        metas = _iter_conv_metas(
            [plan.to_artifact()[0] for plan in source.plans.values()])
    out = {}
    for meta in metas:
        kv = _entry_from_meta(meta)
        if kv is not None:
            key, entry = kv
            prev = out.get(key)
            if prev is None or _faster(entry, prev):
                out[key] = entry
    return out


def _faster(a: dict, b: dict) -> bool:
    ta, tb = a.get("winner_time_s"), b.get("winner_time_s")
    if ta is None:
        return False
    return tb is None or ta < tb


# ---------------------------------------------------------------------------
# Databases: export / merge / save / load
# ---------------------------------------------------------------------------

def _host() -> dict:
    return {"node": platform.node(), "machine": platform.machine(),
            "system": platform.system(),
            "python": platform.python_version(),
            "exported_at": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                         time.gmtime())}


def export(sources: Any, path: str | None = None) -> dict:
    """Build a database document from one source or a list of sources
    (artifact paths / dirs / NetworkPlans); optionally write it."""
    if not isinstance(sources, (list, tuple)):
        sources = [sources]
    entries: dict[str, dict] = {}
    for src in sources:
        for key, entry in collect(src).items():
            prev = entries.get(key)
            if prev is None or _faster(entry, prev):
                entries[key] = entry
    doc = {"format": FORMAT, "version": VERSION,
           "hosts": [dict(_host(), entries=len(entries))],
           "entries": entries}
    if path is not None:
        save(doc, path)
    return doc


def merge(*docs: dict) -> dict:
    """Fleet merge: union of entries, conflicts resolved to the entry with
    the fastest recorded winner time; host provenance concatenates."""
    entries: dict[str, dict] = {}
    hosts: list[dict] = []
    for doc in docs:
        _check(doc)
        hosts.extend(doc.get("hosts", []))
        for key, entry in doc["entries"].items():
            prev = entries.get(key)
            if prev is None or _faster(entry, prev):
                entries[key] = entry
    return {"format": FORMAT, "version": VERSION, "hosts": hosts,
            "entries": entries}


def _check(doc: dict) -> None:
    if doc.get("format") != FORMAT:
        raise ValueError(f"not a tuning database (format="
                         f"{doc.get('format')!r}, expected {FORMAT!r})")
    if doc.get("version", 0) > VERSION:
        raise ValueError(f"tuning database version {doc.get('version')} "
                         f"is newer than this reader ({VERSION})")


def save(doc: dict, path: str) -> None:
    _check(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    _check(doc)
    return doc


# ---------------------------------------------------------------------------
# Installation: make plan_conv2d consume the database
# ---------------------------------------------------------------------------

def install(db: dict | str) -> int:
    """Install a database (document or path) into core/plan.py; returns
    the number of entries now consulted before any autotune measurement."""
    if isinstance(db, str):
        db = load(db)
    _check(db)
    from repro.core import plan as _plan
    _plan.set_tuning_db(db["entries"])
    return len(db["entries"])


def clear() -> None:
    from repro.core import plan as _plan
    _plan.set_tuning_db(None)
