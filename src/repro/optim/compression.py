"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 2+ pods the gradient reduction crosses the (slow) inter-pod links; int8
quantization cuts that wire traffic 4x vs fp32 (2x vs bf16). Error feedback
(Seide et al. / EF-SGD) keeps the quantization *unbiased over time*: the
residual of each step's quantization is carried and added to the next step's
gradient, so the compressed-SGD trajectory provably tracks the exact one.

Mechanics (per leaf):
  q, scale = quantize(g + err)           # symmetric per-tensor int8
  err'     = (g + err) - dequantize(q)   # carried residual
  wire     = q (int8) + scale (f32)      # 4x fewer bytes than f32 g

`cross_pod_mean` composes with SPMD jit via shard_map over the "pod" axis:
gradients are already pod-replicated means within each pod (XLA's data-axis
reduction); the pod-axis mean then runs on the quantized representation.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_F32 = jnp.float32
_I8_MAX = 127.0


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload, same shape as the gradient
    scale: jax.Array      # f32 scalar


def quantize(g: jax.Array) -> Compressed:
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(g.astype(_F32)))
    scale = jnp.where(amax > 0, amax / _I8_MAX, 1.0).astype(_F32)
    q = jnp.clip(jnp.round(g.astype(_F32) / scale), -_I8_MAX, _I8_MAX)
    return Compressed(q=q.astype(jnp.int8), scale=scale)


def dequantize(c: Compressed) -> jax.Array:
    return c.q.astype(_F32) * c.scale


def quantize_channelwise(g: jax.Array, channel_axes=(-1,)
                         ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-channel int8 quantization: one scale per position along
    `channel_axes` (every other axis is reduced), `q * scale == g` up to
    rounding. This is the plan-time weight quantizer for the low-precision
    Winograd executors (core/plan.py:_bind_weights): the transform-domain
    filter is quantized along its output-channel axis so dequantization is a
    single per-channel multiply that folds into the bias+activation
    epilogue. Zero channels (all-pad) get scale 1.0 so dequantization stays
    finite. Returns (q int8, scale f32 of the channel_axes shape)."""
    g = g.astype(_F32)
    axes = tuple(a % g.ndim for a in channel_axes)
    reduce_axes = tuple(i for i in range(g.ndim) if i not in axes)
    amax = jnp.max(jnp.abs(g), axis=reduce_axes)
    scale = jnp.where(amax > 0, amax / _I8_MAX, 1.0).astype(_F32)
    bshape = [g.shape[i] if i in axes else 1 for i in range(g.ndim)]
    q = jnp.clip(jnp.round(g / scale.reshape(bshape)), -_I8_MAX, _I8_MAX)
    return q.astype(jnp.int8), scale


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> tuple[Compressed, jax.Array]:
    """Returns (compressed(g + err), new_err)."""
    target = g.astype(_F32) + err
    c = quantize(target)
    new_err = target - dequantize(c)
    return c, new_err


def init_error_state(params: Any) -> Any:
    """Zero residuals, shaped/sharded like the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, _F32), params)


def pod_mean_int8(g: jax.Array, err: jax.Array, axis: str = "pod"
                  ) -> tuple[jax.Array, jax.Array]:
    """Mean a per-pod gradient shard over `axis` through an int8 wire with
    error feedback. MUST run inside a shard_map that maps `axis`.

    jax.lax.psum on the int8 payload would overflow; the standard scheme
    (1-bit/EF-SGD lineage) all-gathers the int8 payloads + scales and
    dequant-sums locally -- wire bytes = one int8 payload per pod, a 4x
    reduction vs an fp32 ring all-reduce (2x vs bf16).
    """
    c, new_err = compress_with_feedback(g, err)
    qs = jax.lax.all_gather(c.q, axis)            # (pods, ...) int8 wire
    scales = jax.lax.all_gather(c.scale, axis)    # (pods,)
    n = qs.shape[0]
    mean = jnp.tensordot(scales, qs.astype(_F32), axes=(0, 0)) / n
    return mean.astype(g.dtype), new_err


def pod_mean_int8_tree(grads: Any, err_state: Any, axis: str = "pod"
                       ) -> tuple[Any, Any]:
    """Tree-wide compressed pod-mean. MUST run inside a shard_map mapping
    `axis` (the caller owns the per-pod loss/grad structure -- grads hold the
    *pod-local* batch mean on entry and the global mean on exit)."""
    out = jax.tree.map(lambda g, e: pod_mean_int8(g, e, axis),
                       grads, err_state)
    new_grads = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err
