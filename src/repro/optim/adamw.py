"""AdamW with ZeRO-style sharded state, cosine schedule, global-norm clipping.

Optimizer moments inherit the parameter sharding (2-D TP x FSDP), which *is*
ZeRO-3: every chip holds only its shard of params, m and v. `state_dtype`
drops the moments to bf16 for the 100B+ archs (nemotron, llama4) where fp32
m/v alone would exceed pod HBM; the update math always runs in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # pytree like params
    v: Any


def init_state(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def abstract_state(params_shape: Any, cfg: AdamWConfig) -> AdamWState:
    return jax.eval_shape(lambda p: init_state(p, cfg), params_shape)


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(_F32)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(_F32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9)).astype(_F32)
    return jax.tree.map(lambda g: (g.astype(_F32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig) -> tuple[Any, AdamWState]:
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(state.step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(_F32)
    bc2 = 1 - b2 ** step.astype(_F32)

    def upd(p, g, m, v):
        g32 = g.astype(_F32)
        m32 = b1 * m.astype(_F32) + (1 - b1) * g32
        v32 = b2 * v.astype(_F32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(_F32)
        newp = p.astype(_F32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
