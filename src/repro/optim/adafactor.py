"""Adafactor (Shazeer & Stern, 2018) with factored second moments.

For the 100B+ archs, AdamW's two fp32 moments cost 8 bytes/param -- more
than the bf16 weights themselves. Adafactor stores row/column factors of the
second moment for every matrix-shaped parameter: O(n + m) instead of O(nm),
cutting optimizer HBM by ~2x at 340B scale (the nemotron deployment-fit
lever flagged in EXPERIMENTS.md section Perf). Factored state inherits the
parameter sharding on the surviving axis.

Implements the standard recipe: factored v for >=2D params, update clipping
by RMS (d=1.0), relative step size, no first moment by default (beta1=None).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2               # relative step scale
    decay_rate: float = 0.8        # beta2_t = 1 - t^-decay_rate
    eps1: float = 1e-30            # second-moment regularizer
    eps2: float = 1e-3             # parameter-scale floor
    clip_threshold: float = 1.0    # RMS update clip
    beta1: Optional[float] = None  # None = no first moment (memory-free)
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any     # row factors   (matrix params) or full v (vectors/scalars)
    vc: Any     # column factors (matrix params) or () placeholders
    m: Any      # first moments or () placeholders


def _factored(shape, cfg: AdafactorConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def init_state(params: Any, cfg: AdafactorConfig) -> AdafactorState:
    def vr(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-1], _F32)           # drop last axis
        return jnp.zeros(p.shape, _F32)

    def vc(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], _F32)
        return jnp.zeros((1,), _F32)                        # placeholder

    def m(p):
        return jnp.zeros(p.shape, _F32) if cfg.beta1 else jnp.zeros((1,), _F32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          m=jax.tree.map(m, params))


def abstract_state(params_shape: Any, cfg: AdafactorConfig) -> AdafactorState:
    return jax.eval_shape(lambda p: init_state(p, cfg), params_shape)


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)))


def apply_updates(params: Any, grads: Any, state: AdafactorState,
                  cfg: AdafactorConfig) -> tuple[Any, AdafactorState]:
    step = state.step + 1
    beta2 = 1.0 - step.astype(_F32) ** (-cfg.decay_rate)

    def upd(p, g, vr, vc, m):
        g32 = g.astype(_F32)
        g2 = jnp.square(g32) + cfg.eps1
        if _factored(p.shape, cfg):
            new_vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            new_vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            # v_hat = vr vc^T / mean(vr) (rank-1 reconstruction)
            denom = jnp.clip(jnp.mean(new_vr, axis=-1, keepdims=True),
                             cfg.eps1, None)
            vhat = (new_vr / denom)[..., None] * new_vc[..., None, :]
            update = g32 * jax.lax.rsqrt(vhat + cfg.eps1)
        else:
            new_vr = beta2 * vr + (1 - beta2) * g2
            new_vc = vc
            update = g32 * jax.lax.rsqrt(new_vr + cfg.eps1)
        # RMS clip
        update = update / jnp.maximum(1.0, _rms(update) / cfg.clip_threshold)
        if cfg.beta1:
            new_m = cfg.beta1 * m + (1 - cfg.beta1) * update
            update = new_m
        else:
            new_m = m
        scale = cfg.lr * jnp.maximum(cfg.eps2, _rms(p.astype(_F32)))
        newp = p.astype(_F32) - scale * update \
            - cfg.lr * cfg.weight_decay * p.astype(_F32)
        return newp.astype(p.dtype), new_vr, new_vc, new_m

    out = jax.tree.map(upd, params, grads, state.vr, state.vc, state.m)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2),
                                   m=pick(3))


def state_bytes(params: Any, cfg: AdafactorConfig) -> int:
    """Optimizer HBM footprint (the point of Adafactor)."""
    st = jax.eval_shape(lambda p: init_state(p, cfg), params)
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves((st.vr, st.vc, st.m)))
