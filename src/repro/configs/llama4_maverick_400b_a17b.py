"""llama4-maverick-400b-a17b [moe] -- 128-expert top-1 MoE, early fusion.
[hf:meta-llama/Llama-4-*]

48L d_model=5120 40H (kv=8) expert d_ff=8192 vocab=202048. Early-fusion
multimodality arrives as tokens (vocab covers image tokens) -- no frontend in
the backbone. Experts shard over the model axis (EP: 128 / 16 = 8 per chip).
MoE on every other layer (interleaved dense:MoE 1:1), which reproduces the
published ~400B total / ~17B active split.
"""

from repro.configs import shrink
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  every_k_layers=2, shard_mode="ep"),
    scan_unit=2,
    rope_theta=500_000.0,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
