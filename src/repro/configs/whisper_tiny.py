"""whisper-tiny [audio] -- encoder-decoder. [arXiv:2212.04356]

4L enc + 4L dec, d_model=384, 6H MHA, d_ff=1536, vocab=51865, GELU,
LayerNorm, learned positions. The conv frontend is a STUB at the dry-run
input boundary (precomputed frame embeddings, per the brief); the stem itself
is implemented in models/audio.py on the 1D Cook-Toom path and exercised by
smoke tests and examples.
"""

from repro.configs import shrink
from repro.models.config import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    pos_emb="learned",
    encoder=EncoderConfig(n_layers=4, n_ctx=1500),
    tie_embeddings=True,
    max_seq=32_768,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
