"""granite-moe-3b-a800m [moe] -- 40-expert top-8 MoE. [hf:ibm-granite/granite-3.0-*]

32L d_model=1536 24H (kv=8) expert d_ff=512 vocab=49155. 40 experts do not
divide the 16-way model axis, so experts use tensor-parallel sharding on the
FFN dim instead of EP (shard_mode="tp", see distributed/sharding.py).
"""

from repro.configs import shrink
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, shard_mode="tp"),
    tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
