"""falcon-mamba-7b [ssm] -- pure Mamba-1, attention-free. [arXiv:2410.05355]

64L d_model=4096, no FFN (d_ff=0: the Mamba mixer is the whole layer),
vocab=65024, ssm_state=16. The paper's technique applies here: the depthwise
causal conv1d (k=4) in every block routes through the 1D Cook-Toom kernel.
"""

from repro.configs import shrink
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=32,            # nominal; attention-free
    n_kv_heads=32,
    head_dim=128,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, scan_chunk=256),
    subquadratic=True,
    max_seq=524_288,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
