"""chameleon-34b [vlm] -- early-fusion, VQ image tokens, qk-norm. [arXiv:2405.09818]

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536 (unified text + VQ image
token vocabulary). The VQ-VAE image tokenizer is a frontend STUB per the
brief; the backbone consumes tokens. Chameleon's qk-norm stabilizer is on.
"""

from repro.configs import shrink
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
