"""jamba-v0.1-52b [hybrid] -- Mamba + attention 1:7, MoE 16e top-2. [arXiv:2403.19887]

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536. Period of 8 layers: one
attention layer per period (index 4), MoE on every second layer. The Mamba
conv1d routes through the Cook-Toom kernel (paper technique).
"""

from repro.configs import shrink
from repro.models.config import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every_k_layers=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, scan_chunk=256),
    attn_every=8,
    scan_unit=8,
    subquadratic=True,
    max_seq=524_288,
)


def smoke() -> ArchConfig:
    return shrink(CONFIG)
