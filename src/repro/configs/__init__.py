"""Architecture registry: one module per assigned architecture.

get_config(name)        -> full published config
get_smoke_config(name)  -> reduced same-family config for CPU smoke tests
SHAPES                  -> the assigned input-shape set (shared by all archs)
cells(name)             -> the (shape -> step kind) cells this arch runs
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ArchConfig, EncoderConfig, MoEConfig, SSMConfig

ARCH_IDS = (
    "falcon_mamba_7b",
    "whisper_tiny",
    "qwen1_5_32b",
    "nemotron_4_340b",
    "qwen2_5_3b",
    "yi_34b",
    "jamba_v0_1_52b",
    "llama4_maverick_400b_a17b",
    "granite_moe_3b_a800m",
    "chameleon_34b",
)

#: assigned LM shapes: name -> (seq_len, global_batch, step kind)
SHAPES: Dict[str, tuple] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def cells(name: str):
    """(shape_name, seq, batch, kind) cells for this arch. long_500k is only
    runnable with sub-quadratic attention (SSM/hybrid); for the pure
    full-attention archs it is reported as an explicit skip (DESIGN.md)."""
    cfg = get_config(name)
    out = []
    for shape, (seq, batch, kind) in SHAPES.items():
        if shape == "long_500k" and not cfg.subquadratic:
            out.append((shape, seq, batch, "skip"))
        else:
            out.append((shape, seq, batch, kind))
    return out


def _shrink_moe(m: MoEConfig | None) -> MoEConfig | None:
    if m is None:
        return None
    return dataclasses.replace(
        m, n_experts=min(m.n_experts, 8), top_k=min(m.top_k, 2),
        d_ff_expert=min(m.d_ff_expert, 128))


def shrink(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family config: same layer pattern, tiny dims."""
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    base = dict(
        n_layers=cfg.scan_unit * 2,
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        moe=_shrink_moe(cfg.moe),
        ssm=dataclasses.replace(cfg.ssm, d_state=8, scan_chunk=16)
        if cfg.ssm else None,
        encoder=dataclasses.replace(cfg.encoder, n_layers=2, n_ctx=16)
        if cfg.encoder else None,
        max_seq=256,
        logits_chunk=32,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
