"""Deterministic sharded synthetic data pipeline.

Batches are a pure function of (seed, step): restart-safe (a restore at step
k regenerates exactly the batch the failed run would have seen) and
host-shardable (each host materializes only its slice; here single-host, but
the slicing path is exercised). A background prefetch thread keeps
`prefetch_depth` batches ahead of the training loop.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.models.config import ArchConfig


class SyntheticLM:
    """Next-token LM batches with a learnable structure (token t+1 depends on
    token t modulo a small alphabet), so loss measurably decreases."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 host_index: int = 0, host_count: int = 1):
        self.cfg = cfg
        self.global_batch = batch
        self.batch = batch // host_count
        self.host_index = host_index
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, step, self.host_index))
        vocab = self.cfg.vocab
        b, s = self.batch, self.seq
        # markov-ish stream: x[t+1] = (a * x[t] + drift) % K, lifted into vocab
        k = min(257, vocab)
        x0 = rng.integers(0, k, size=(b, 1))
        a = 1 + 2 * rng.integers(0, 3, size=(b, 1))
        toks = [x0]
        for _ in range(s):
            toks.append((a * toks[-1] + 17) % k)
        seqs = np.concatenate(toks, axis=1) % vocab
        out = {"tokens": seqs[:, :-1].astype(np.int32),
               "labels": seqs[:, 1:].astype(np.int32)}
        if self.cfg.encoder is not None:
            out["frames"] = rng.standard_normal(
                (b, self.cfg.encoder.n_ctx, self.cfg.d_model)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
