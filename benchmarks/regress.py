"""Regression gate over BENCH_*.json perf artifacts.

Two modes:

  PYTHONPATH=src python -m benchmarks.regress BASE.json CURRENT.json
      # compare one pair: extract the tracked metrics of both artifacts
      # (repro.obs.regress knows every BENCH format this repo emits) and
      # exit 1 when any gated metric regressed past the threshold.

  PYTHONPATH=src python -m benchmarks.regress --trajectory CI_DIR
      # CI mode: for every committed BENCH_PR<n>.json in the repo root,
      # find its freshly-measured counterpart BENCH_PR<n>_ci*.json under
      # CI_DIR and compare committed -> fresh. Pairs in the PR10 observe
      # format gate HARD (their metrics are machine-relative -- overhead
      # percentage points, residual percentage, boolean gates -- so a CI
      # runner is comparable to the machine that produced the committed
      # baseline). Pre-existing absolute-latency formats are evaluated
      # WARN-ONLY by default: a slow CI runner is not a regression.
      # --strict upgrades warnings to failures for same-machine use.

Thresholds: ratio metrics fail past --threshold x worsening (default
1.5x); percentage-point metrics (the PR10 overhead gauge) fail past
--pct-margin additional points (default 5.0); count metrics (dropped /
incorrect requests) and boolean gates fail on ANY worsening.
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys

from repro.obs import regress as R


def _compare_pair(base_path: str, cur_path: str, *, threshold: float,
                  pct_margin: float, hard: bool) -> tuple[int, int]:
    """Print one pair's findings; return (n_gated, n_regressed)."""
    base, cur = R.load(base_path), R.load(cur_path)
    fmt = R.detect(base)
    findings = R.compare(base, cur, threshold=threshold,
                         pct_margin=pct_margin)
    regressed = [f for f in findings if f.regressed]
    mode = "gate" if hard else "warn-only"
    print(f"\n== {os.path.basename(base_path)} -> "
          f"{os.path.basename(cur_path)}  [format={fmt}, {mode}] ==")
    if not findings:
        print("  (no shared tracked metrics)")
        return 0, 0
    for line in R.summarize(findings):
        print(line)
    print(f"  {len(findings)} metric(s) compared, "
          f"{len(regressed)} regressed")
    return len(findings), len(regressed)


_CI_TAG = re.compile(r"^BENCH_PR(\d+)(?:_ci.*)?\.json$")


def _trajectory_pairs(root: str, ci_dir: str) -> list[tuple[str, str]]:
    """(committed, fresh) pairs: BENCH_PR<n>.json in `root` matched with
    BENCH_PR<n>_ci*.json (or BENCH_PR<n>.json) under `ci_dir`."""
    pairs = []
    for committed in sorted(glob.glob(os.path.join(root,
                                                   "BENCH_PR[0-9]*.json"))):
        m = _CI_TAG.match(os.path.basename(committed))
        if not m:
            continue
        n = m.group(1)
        fresh = (sorted(glob.glob(os.path.join(
                    ci_dir, f"BENCH_PR{n}_ci*.json")))
                 or sorted(glob.glob(os.path.join(
                    ci_dir, f"BENCH_PR{n}.json"))))
        if fresh:
            pairs.append((committed, fresh[0]))
    return pairs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.regress",
        description="Regression gate over BENCH_*.json artifacts.")
    ap.add_argument("base", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("current", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--trajectory", metavar="CI_DIR", default=None,
                    help="compare every committed BENCH_PR<n>.json in the "
                         "repo root against BENCH_PR<n>_ci*.json under "
                         "CI_DIR")
    ap.add_argument("--root", default=None,
                    help="override the repo root that holds the committed "
                         "trajectory (default: parent of benchmarks/)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="ratio-metric failure factor (default 1.5x)")
    ap.add_argument("--pct-margin", type=float, default=5.0,
                    help="percentage-point metric failure margin "
                         "(default 5.0)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report but always exit 0")
    ap.add_argument("--strict", action="store_true",
                    help="trajectory mode: gate pre-existing absolute-"
                         "latency formats too, not just the machine-"
                         "relative observe format")
    args = ap.parse_args(argv)

    failures = 0
    if args.trajectory is not None:
        root = args.root or os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))
        pairs = _trajectory_pairs(root, args.trajectory)
        if not pairs:
            print(f"error: no (committed, fresh) BENCH_PR<n> pairs between "
                  f"{root} and {args.trajectory}")
            return 2
        for committed, fresh in pairs:
            hard = args.strict or \
                R.detect(R.load(committed)) == "observe"
            _, regressed = _compare_pair(
                committed, fresh, threshold=args.threshold,
                pct_margin=args.pct_margin, hard=hard)
            if regressed and hard:
                failures += regressed
            elif regressed:
                print(f"  (warn-only: {regressed} regression(s) not gated "
                      f"-- absolute metrics across machines)")
    else:
        if not args.base or not args.current:
            ap.error("need BASE and CURRENT (or --trajectory CI_DIR)")
        _, failures = _compare_pair(
            args.base, args.current, threshold=args.threshold,
            pct_margin=args.pct_margin, hard=True)

    if failures and not args.warn_only:
        print(f"\nREGRESSION GATE FAILED: {failures} gated metric(s) "
              f"regressed")
        return 1
    if failures:
        print(f"\nwarn-only: {failures} regression(s) reported, exit 0")
    else:
        print("\nregression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
