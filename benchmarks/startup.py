"""Cold-plan vs warm-artifact startup: the paper's section-4 deployment
story measured end to end through the graph compiler.

For each network the harness measures
  * cold start -- compile(params, specs): lowering + fusion rewrites +
    placement + every filter transform;
  * save -- NetworkPlan.save(path) artifact emission (and the artifact
    size on disk);
  * warm start -- NetworkPlan.load(path) in this process with the plan
    caches cleared: no re-planning, no re-measuring, no filter-transform
    ops (the ship-transformed-weights path);
  * a FRESH-PROCESS reload: a subprocess loads the artifact, runs the same
    deterministic input, and must produce byte-identical output (the CI
    parity gate);
  * steady-state latency of the compiled plan vs the im2row baseline.

  PYTHONPATH=src python -m benchmarks.startup --out BENCH_PR5.json
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile import NetworkPlan, compile as compile_network
from repro.core.plan import clear_plan_cache, plan_cache_info
from repro.models import cnn

from benchmarks.common import bench_metadata, time_jitted

NETWORKS = ["mobilenet_v2", "vgg16"]

# The subprocess half of the fresh-process parity gate: load the artifact,
# run the deterministic input, print the output digest. No access to specs
# or raw params -- everything comes from the artifact.
_CHILD = r"""
import hashlib, sys
import jax.numpy as jnp
import numpy as np
from repro.core.compile import NetworkPlan
from repro.core.plan import plan_cache_info

path, res = sys.argv[1], int(sys.argv[2])
net = NetworkPlan.load(path)
x = jnp.asarray(np.random.default_rng(0).standard_normal(
    (1, res, res, 3)), jnp.float32)
y = np.asarray(net.apply(x))
info = plan_cache_info()
assert info["artifact_hits"] == 1, info
print(hashlib.sha256(y.tobytes()).hexdigest())
"""


def _digest(y) -> str:
    return hashlib.sha256(np.asarray(y).tobytes()).hexdigest()


def bench_startup(net: str, res: int, iters: int, warmup: int,
                  artifact_dir: str) -> dict:
    specs = cnn.NETWORKS[net][0]()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1, res, res, 3)), jnp.float32)
    path = os.path.join(artifact_dir, f"{net}_{res}.npz")

    clear_plan_cache()
    t0 = time.perf_counter()
    plan = compile_network(params, specs, res=res, algorithm="auto")
    jax.block_until_ready(plan.weight_arrays())
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    plan.save(path)
    save_s = time.perf_counter() - t0
    artifact_bytes = os.path.getsize(path)

    clear_plan_cache()
    t0 = time.perf_counter()
    loaded = NetworkPlan.load(path)
    jax.block_until_ready(loaded.weight_arrays())
    warm_s = time.perf_counter() - t0
    assert plan_cache_info()["artifact_hits"] == 1

    # in-process parity must be bitwise; fresh-process parity must match it.
    y_cold = plan.apply(x)
    y_warm = loaded.apply(x)
    assert np.array_equal(np.asarray(y_cold), np.asarray(y_warm)), \
        "save/load round-trip is not bitwise identical"
    child = subprocess.run(
        [sys.executable, "-c", _CHILD, path, str(res)],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 p for p in ("src", os.environ.get("PYTHONPATH")) if p)})
    assert child.returncode == 0, child.stderr
    fresh_digest = child.stdout.strip().splitlines()[-1]
    assert fresh_digest == _digest(y_cold), \
        (fresh_digest, _digest(y_cold))

    fn_planned = jax.jit(loaded.apply)
    fn_base = jax.jit(lambda x: cnn.cnn_forward(params, x, specs,
                                                algorithm="im2col"))
    t_planned = time_jitted(fn_planned, x, warmup=warmup, iters=iters)
    t_base = time_jitted(fn_base, x, warmup=warmup, iters=iters)

    return {"network": net, "res": res,
            "cold_compile_s": cold_s, "save_s": save_s,
            "warm_load_s": warm_s, "artifact_bytes": artifact_bytes,
            "startup_speedup": cold_s / warm_s,
            "t_planned_s": t_planned, "t_im2row_s": t_base,
            "fresh_process_parity": True,
            "output_sha256": _digest(y_cold)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--res", type=int, default=96,
                    help="input resolution (96 keeps the CI run in seconds; "
                         "use 224 for the paper setting)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("== cold compile vs warm artifact startup (compile/save/load) ==")
    print(f"{'Network':14s} {'cold(ms)':>9s} {'save(ms)':>9s} "
          f"{'warm(ms)':>9s} {'x-faster':>8s} {'MB':>6s} "
          f"{'planned(ms)':>12s} {'im2row(ms)':>11s}")
    with tempfile.TemporaryDirectory() as tmp:
        for net in args.networks:
            r = bench_startup(net, args.res, args.iters, args.warmup, tmp)
            rows.append(r)
            print(f"{r['network']:14s} {r['cold_compile_s']*1e3:9.1f} "
                  f"{r['save_s']*1e3:9.1f} {r['warm_load_s']*1e3:9.1f} "
                  f"{r['startup_speedup']:7.1f}x "
                  f"{r['artifact_bytes']/2**20:6.1f} "
                  f"{r['t_planned_s']*1e3:12.1f} "
                  f"{r['t_im2row_s']*1e3:11.1f}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"config": "startup", "meta": bench_metadata(),
                       "res": args.res, "rows": rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
