"""Paper section 4 claim: transform costs amortize over the GEMMs as the
output-channel count M grows, so achieved speedup approaches the theoretical
multiplication reduction asymptotically.

Fixes a 14x14xC 3x3 layer and sweeps M; reports winograd-vs-im2row speedup
per M alongside the theoretical F(4x4,3x3) bound of 4x.

The sweep also A/Bs the per-call path (filter transform inside every call,
the seed behavior) against planned execution (transform once at plan time,
steady-state apply) and records both, plus the plan-build cost -- the
section-4 insight made directly measurable. Each row records the cold build
(decisions + geometry + filter transform) and an immediate rebuild of the
same layer: with --plan-cache (default) the rebuild hits the process-level
spec cache and pays only the filter transform; --no-plan-cache clears the
cache first so the rebuild re-derives everything, exposing the cache's
contribution in the same JSON."""

from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as planlib
from repro.core.transforms import cook_toom

from benchmarks.common import time_jitted
from benchmarks.per_layer import _run_layer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--c-in", type=int, default=64)
    ap.add_argument("--hw", type=int, default=56)
    ap.add_argument("--m-sweep", nargs="*", type=int,
                    default=[4, 16, 64, 128, 256, 512])
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--plan-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="let each row's plan *rebuild* hit the process-level "
                         "spec cache (--no-plan-cache clears the cache before "
                         "the rebuild, so it re-derives all decisions)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    ct = cook_toom(4, 3)
    bound = ct.mult_reduction_2d
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, args.hw, args.hw, args.c_in)),
                    jnp.float32)
    rows = []
    print(f"== Amortization sweep: {args.hw}x{args.hw}x{args.c_in}, 3x3, "
          f"theoretical bound {bound:.2f}x "
          f"(plan cache {'on' if args.plan_cache else 'off'}) ==")
    print(f"{'M':>5s} {'im2col(us)':>11s} {'wino(us)':>10s} "
          f"{'planned(us)':>12s} {'build(us)':>10s} {'rebuild':>10s} "
          f"{'speedup':>8s} {'planned':>8s} {'of-bound':>9s}")
    for m in args.m_sweep:
        w = jnp.asarray(rng.standard_normal((3, 3, args.c_in, m)) / 3,
                        jnp.float32)
        kw = dict(kh=3, kw=3, c_out=m, stride=1)
        t_i = time_jitted(functools.partial(_run_layer, algorithm="im2col",
                                            **kw), x, w, iters=args.iters)
        t_w = time_jitted(functools.partial(_run_layer, algorithm="winograd",
                                            **kw), x, w, iters=args.iters)
        # planned path: the per-call numbers above re-transform the filter
        # every call; this one pre-transforms at plan time. Cold build first,
        # then a rebuild whose cost depends on the spec cache (the A/B the
        # --plan-cache flag controls).
        planlib.clear_plan_cache()
        t0 = time.perf_counter()
        p = planlib.plan_conv2d(x.shape, w, stride=1, algorithm="winograd")
        jax.block_until_ready(p.u)
        t_build = time.perf_counter() - t0
        if not args.plan_cache:
            planlib.clear_plan_cache()
        t0 = time.perf_counter()
        p = planlib.plan_conv2d(x.shape, w, stride=1, algorithm="winograd")
        jax.block_until_ready(p.u)
        t_rebuild = time.perf_counter() - t0
        t_p = time_jitted(jax.jit(p.apply), x, iters=args.iters)
        r = {"m": m, "t_im2col_s": t_i, "t_winograd_s": t_w,
             "t_winograd_planned_s": t_p, "plan_build_s": t_build,
             "plan_rebuild_s": t_rebuild,
             "plan_cache": bool(args.plan_cache),
             "speedup": t_i / t_w, "speedup_planned": t_i / t_p,
             "bound": bound}
        rows.append(r)
        print(f"{m:5d} {t_i*1e6:11.0f} {t_w*1e6:10.0f} {t_p*1e6:12.0f} "
              f"{t_build*1e6:10.0f} {t_rebuild*1e6:10.0f} "
              f"{r['speedup']:7.2f}x {r['speedup_planned']:7.2f}x "
              f"{100*r['speedup_planned']/bound:8.1f}%", flush=True)
    # the paper's claim: speedup is increasing in M (monotone up to noise)
    sp = [r["speedup"] for r in rows]
    print(f"asymptotic trend: {sp[0]:.2f}x @ M={rows[0]['m']} -> "
          f"{sp[-1]:.2f}x @ M={rows[-1]['m']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
