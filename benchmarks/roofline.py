"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section Roofline).

Reads every results/*.jsonl dry-run record and prints, per (arch x shape) on
the single-pod mesh: the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the roofline fraction
(t_dominant vs the best achievable = max(t_compute over MODEL_FLOPS)).

TPU v5e constants (DESIGN.md): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import OrderedDict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARCHS = ["falcon_mamba_7b", "whisper_tiny", "qwen1_5_32b", "nemotron_4_340b",
         "qwen2_5_3b", "yi_34b", "jamba_v0_1_52b",
         "llama4_maverick_400b_a17b", "granite_moe_3b_a800m", "chameleon_34b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, mesh: str = "single",
         phase: str = "baseline") -> "OrderedDict":
    """phase: "baseline" (pre-hillclimb records) or "optimized" (section-Perf
    re-measurements, marked with record["phase"])."""
    recs = OrderedDict()
    for f in sorted(glob.glob(os.path.join(results_dir, "*.jsonl"))):
        with open(f) as fh:
            for line in fh:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                rec_phase = r.get("phase", "baseline")
                if rec_phase != phase:
                    continue
                if r.get("mesh") == mesh and r.get("status") in ("ok", "skip"):
                    recs[(r["arch"], r["shape"])] = r
    return recs


def row(r: dict) -> dict:
    if r["status"] == "skip":
        return {"arch": r["arch"], "shape": r["shape"], "status": "skip"}
    rf = r["roofline"]
    model = r.get("model_flops_6nd", 0.0)
    useful = model / rf["flops_per_dev"] / rf["n_chips"] \
        if rf["flops_per_dev"] else 0.0
    t_dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    t_ideal = model / (rf["n_chips"] * PEAK_FLOPS)
    return {
        "arch": r["arch"], "shape": r["shape"], "status": "ok",
        "t_compute_s": rf["t_compute_s"], "t_memory_s": rf["t_memory_s"],
        "t_collective_s": rf["t_collective_s"],
        "bottleneck": rf["bottleneck"],
        "useful_flops_ratio": useful,
        "roofline_fraction": t_ideal / t_dom if t_dom else 0.0,
    }


def conv_executor_rows(network: str = "vgg_style") -> list[dict]:
    """Analytic roofline of the transform-domain conv executors over one
    paper network: per suitable conv layer, the HBM-bytes/FLOPs models of
    benchmarks.common for the F(4,3) Winograd, F(6,3) and rfft2 executors,
    reduced to t_compute / t_memory / bottleneck under the v5e constants.
    Pure analysis -- builds plan specs, runs nothing."""
    from benchmarks import common
    from repro.core import plan as planlib

    models = {
        "winograd": (common.winograd_domain_flops,
                     common.winograd_domain_hbm_bytes),
        "winograd_f63": (common.winograd_domain_flops,
                         common.winograd_domain_hbm_bytes),
        "fft": (common.fft_flops, common.fft_hbm_bytes),
    }
    rows = []
    for layer in common.conv_layer_inventory(network):
        if not layer["suitable"] or layer["kh"] == 1:
            continue
        x_shape = (1, layer["h"], layer["w"], layer["c_in"])
        w_shape = (layer["kh"], layer["kw"], layer["c_in"], layer["c_out"])
        for alg, (flops_fn, bytes_fn) in models.items():
            try:
                spec = planlib._build_spec(x_shape, w_shape, "float32",
                                           (1, 1), "SAME", alg, alg, None, 1)
            except Exception:
                continue  # executor does not cover this layer (e.g. 5x5 f63)
            fl, by = flops_fn(spec), bytes_fn(spec)
            t_c, t_m = fl / PEAK_FLOPS, by / HBM_BW
            rows.append({"layer": layer["name"], "algorithm": alg,
                         "flops": fl, "hbm_bytes": by,
                         "intensity": fl / by,
                         "t_compute_s": t_c, "t_memory_s": t_m,
                         "bottleneck": "compute" if t_c >= t_m else "memory"})
    return rows


def print_conv_executor_table(network: str) -> list[dict]:
    rows = conv_executor_rows(network)
    print(f"== Conv-executor analytic roofline ({network}, v5e constants) ==")
    print(f"{'layer':16s} {'algorithm':14s} {'GFLOP':>8s} {'MB':>8s} "
          f"{'flop/B':>7s} {'bound':>8s}")
    for d in rows:
        print(f"{d['layer']:16s} {d['algorithm']:14s} "
              f"{d['flops']/1e9:8.2f} {d['hbm_bytes']/1e6:8.1f} "
              f"{d['intensity']:7.1f} {d['bottleneck']:>8s}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--phase", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--conv-network", default=None,
                    help="print the conv-executor analytic roofline for this "
                         "paper network (e.g. vgg16) instead of the "
                         "dry-run table")
    args = ap.parse_args(argv)

    if args.conv_network:
        rows = print_conv_executor_table(args.conv_network)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rows, f, indent=1)
        return rows

    recs = load(args.results_dir, args.mesh, args.phase)
    rows = []
    print(f"== Roofline table ({args.mesh}-pod mesh, v5e constants) ==")
    print(f"{'arch':26s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
          f"{'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    for arch in ARCHS:
        for shape in SHAPES:
            r = recs.get((arch, shape))
            if r is None:
                if args.phase == "baseline":
                    print(f"{arch:26s} {shape:12s} {'MISSING':>9s}")
                continue
            d = row(r)
            rows.append(d)
            if d["status"] == "skip":
                print(f"{arch:26s} {shape:12s} {'skip (full attention @500k)'}")
                continue
            print(f"{arch:26s} {shape:12s} {d['t_compute_s']:9.3f} "
                  f"{d['t_memory_s']:9.3f} {d['t_collective_s']:9.3f} "
                  f"{d['bottleneck']:>10s} {d['useful_flops_ratio']:7.2f} "
                  f"{100*d['roofline_fraction']:6.1f}%")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
