"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section Roofline).

Reads every results/*.jsonl dry-run record and prints, per (arch x shape) on
the single-pod mesh: the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the roofline fraction
(t_dominant vs the best achievable = max(t_compute over MODEL_FLOPS)).

TPU v5e constants (DESIGN.md): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import OrderedDict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARCHS = ["falcon_mamba_7b", "whisper_tiny", "qwen1_5_32b", "nemotron_4_340b",
         "qwen2_5_3b", "yi_34b", "jamba_v0_1_52b",
         "llama4_maverick_400b_a17b", "granite_moe_3b_a800m", "chameleon_34b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, mesh: str = "single",
         phase: str = "baseline") -> "OrderedDict":
    """phase: "baseline" (pre-hillclimb records) or "optimized" (section-Perf
    re-measurements, marked with record["phase"])."""
    recs = OrderedDict()
    for f in sorted(glob.glob(os.path.join(results_dir, "*.jsonl"))):
        with open(f) as fh:
            for line in fh:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                rec_phase = r.get("phase", "baseline")
                if rec_phase != phase:
                    continue
                if r.get("mesh") == mesh and r.get("status") in ("ok", "skip"):
                    recs[(r["arch"], r["shape"])] = r
    return recs


def row(r: dict) -> dict:
    if r["status"] == "skip":
        return {"arch": r["arch"], "shape": r["shape"], "status": "skip"}
    rf = r["roofline"]
    model = r.get("model_flops_6nd", 0.0)
    useful = model / rf["flops_per_dev"] / rf["n_chips"] \
        if rf["flops_per_dev"] else 0.0
    t_dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    t_ideal = model / (rf["n_chips"] * PEAK_FLOPS)
    return {
        "arch": r["arch"], "shape": r["shape"], "status": "ok",
        "t_compute_s": rf["t_compute_s"], "t_memory_s": rf["t_memory_s"],
        "t_collective_s": rf["t_collective_s"],
        "bottleneck": rf["bottleneck"],
        "useful_flops_ratio": useful,
        "roofline_fraction": t_ideal / t_dom if t_dom else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--phase", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    recs = load(args.results_dir, args.mesh, args.phase)
    rows = []
    print(f"== Roofline table ({args.mesh}-pod mesh, v5e constants) ==")
    print(f"{'arch':26s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
          f"{'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    for arch in ARCHS:
        for shape in SHAPES:
            r = recs.get((arch, shape))
            if r is None:
                if args.phase == "baseline":
                    print(f"{arch:26s} {shape:12s} {'MISSING':>9s}")
                continue
            d = row(r)
            rows.append(d)
            if d["status"] == "skip":
                print(f"{arch:26s} {shape:12s} {'skip (full attention @500k)'}")
                continue
            print(f"{arch:26s} {shape:12s} {d['t_compute_s']:9.3f} "
                  f"{d['t_memory_s']:9.3f} {d['t_collective_s']:9.3f} "
                  f"{d['bottleneck']:>10s} {d['useful_flops_ratio']:7.2f} "
                  f"{100*d['roofline_fraction']:6.1f}%")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
