"""Observability overhead + decomposition benchmark (BENCH_PR10).

  PYTHONPATH=src python -m benchmarks.observe --quick --out BENCH_PR10.json

One MobileNet-v2 server (eager supervised dispatch, so per-layer hooks
fire) serves the same request stream twice per round, interleaved:
profiler DISABLED then ENABLED. Interleaving makes the A/B
machine-relative -- thermal drift and background noise hit both arms --
so the emitted metrics (overhead in PERCENT, decomposition residual in
percent, boolean gates) compare across machines, and CI can gate a fresh
run against the committed baseline (benchmarks/regress.py).

The enabled arm's trace is then audited: for every request, the four
profiler spans (queue_wait -> batch_formation -> dispatch -> respond)
must tile [submit, finish], so their sum is checked against the
independently measured ticket latency (max residual gated < 1%). The
chrome://tracing export and the process metrics snapshot are written
next to the JSON for CI artifact upload.

Artifact format "repro.observe/v1":
    p50_disabled_ms / p50_enabled_ms / overhead_pct
    decomposition: {max_residual_pct, per_request: [...]}
    span_table: named spans of one request (EXPERIMENTS.md table)
    trace_events: event count of the chrome export
    gates: {overhead_lt_10pct, decomposition_residual_lt_1pct,
            valid_chrome_trace, layer_spans_present}
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import bench_metadata
from repro.models import cnn
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace
from repro.runtime.serve import ServeConfig, Server


def _serve_round(srv, inputs, rng, n):
    """n sequential submit/wait requests; returns latencies (s)."""
    lat = []
    for _ in range(n):
        x = inputs[int(rng.integers(len(inputs)))]
        t = srv.submit(x)
        t.result(timeout=120)
        lat.append(t.latency_s)
    return lat


def _request_decomposition(tracer):
    """Per-request [queue_wait, batch_formation, dispatch, respond]
    reconstruction from the enabled arm's spans; returns rows with the
    residual vs the span-implied latency."""
    by_rid: dict[int, dict[str, tuple[float, float]]] = {}
    for s in tracer.spans():
        rid = s.args.get("rid")
        if rid is None or s.name not in ("serve.queue_wait",
                                         "serve.batch_formation",
                                         "serve.respond"):
            continue
        by_rid.setdefault(rid, {})[s.name] = (s.t0, s.t1)
    rows = []
    for rid, parts in sorted(by_rid.items()):
        if len(parts) != 3:
            continue
        qw = parts["serve.queue_wait"]
        bf = parts["serve.batch_formation"]
        rp = parts["serve.respond"]
        latency = rp[1] - qw[0]            # finish - submit
        pieces = {"queue_wait_ms": (qw[1] - qw[0]) * 1e3,
                  "batch_formation_ms": (bf[1] - bf[0]) * 1e3,
                  "dispatch_ms": (rp[0] - bf[1]) * 1e3,
                  "respond_ms": (rp[1] - rp[0]) * 1e3}
        total = sum(pieces.values())
        resid = abs(total - latency * 1e3) / max(latency * 1e3, 1e-9) * 100
        rows.append({"rid": rid,
                     **{k: round(v, 4) for k, v in pieces.items()},
                     "latency_ms": round(latency * 1e3, 4),
                     "residual_pct": round(resid, 4)})
    return rows


def _span_table(tracer, rid):
    """The named spans of one request, plus the layer children of its
    dispatch interval -- the EXPERIMENTS.md table."""
    spans = tracer.spans()
    mine = [s for s in spans if s.args.get("rid") == rid]
    if not mine:
        return []
    bf = next((s for s in mine if s.name == "serve.batch_formation"), None)
    rows = [{"span": s.name, "ms": round((s.t1 - s.t0) * 1e3, 4),
             **({"executor": s.args["executor"]}
                if "executor" in s.args else {})}
            for s in sorted(mine, key=lambda s: s.t0)]
    if bf is not None:
        t0 = bf.t1
        for d in spans:
            if d.name == "serve.dispatch" and abs(d.t0 - t0) < 1e-9:
                rows.append({"span": d.name,
                             "ms": round((d.t1 - d.t0) * 1e3, 4),
                             "batch": d.args.get("batch")})
                break
        for s in spans:
            if s.name.startswith("layer:") and s.t0 >= t0 - 1e-9:
                dispatch = next((d for d in spans
                                 if d.name == "serve.dispatch"
                                 and d.t0 <= s.t0 and s.t1 <= d.t1 + 1e-9),
                                None)
                if dispatch is not None and abs(dispatch.t0 - t0) < 1e-6:
                    rows.append({"span": s.name,
                                 "ms": round((s.t1 - s.t0) * 1e3, 4),
                                 "executor": s.args.get("executor", "?")})
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small resolution / fewer rounds (CI)")
    ap.add_argument("--res", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--out", default="BENCH_PR10.json")
    ap.add_argument("--trace-out", default=None,
                    help="chrome://tracing JSON path "
                         "(default: <out>.trace.json)")
    ap.add_argument("--metrics-out", default=None,
                    help="metrics snapshot path "
                         "(default: <out>.metrics.json)")
    args = ap.parse_args(argv)
    res = args.res or (32 if args.quick else 64)
    rounds = args.rounds or (5 if args.quick else 10)
    trace_out = args.trace_out or f"{args.out}.trace.json"
    metrics_out = args.metrics_out or f"{args.out}.metrics.json"

    print(f"[observe] MobileNet-v2 res={res}, {rounds} interleaved rounds "
          f"x {args.per_round} req/arm", flush=True)
    specs = cnn.NETWORKS["mobilenet_v2"][0]()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    rng = np.random.default_rng(7)
    inputs = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(4)]

    obs_profile.disable()
    cfg = ServeConfig(buckets=(1, 2), jit_dispatch=False, verbose=False)
    lat_dis, lat_en = [], []
    t_start = time.time()
    with Server(params, specs, res=res, algorithm="auto",
                config=cfg) as srv:
        # warmup both arms' code paths before measuring
        _serve_round(srv, inputs, rng, 2)
        obs_profile.enable()
        _serve_round(srv, inputs, rng, 2)
        obs_profile.disable()
        for r in range(rounds):
            lat_dis += _serve_round(srv, inputs, rng, args.per_round)
            obs_profile.enable()
            lat_en += _serve_round(srv, inputs, rng, args.per_round)
            obs_profile.disable(tracing=False)   # keep spans for audit
        tracer = obs_trace.get()
        decomp = _request_decomposition(tracer)
        table_rid = decomp[-1]["rid"] if decomp else None
        span_table = _span_table(tracer, table_rid) if decomp else []
        chrome = tracer.export_chrome(trace_out)
        stats_snapshot = srv.stats.snapshot()
    obs_trace.disable()

    with open(metrics_out, "w") as f:
        json.dump(obs_metrics.snapshot_all(), f, indent=1, sort_keys=True)

    p50_dis = float(np.percentile(lat_dis, 50)) * 1e3
    p50_en = float(np.percentile(lat_en, 50)) * 1e3
    overhead = (p50_en - p50_dis) / p50_dis * 100
    max_resid = max((r["residual_pct"] for r in decomp), default=1e9)
    n_layer_spans = sum(1 for r in span_table
                        if r["span"].startswith("layer:"))
    valid = (isinstance(chrome.get("traceEvents"), list)
             and len(chrome["traceEvents"]) > 0
             and all("ph" in e for e in chrome["traceEvents"]))

    doc = {
        "format": "repro.observe/v1",
        "meta": bench_metadata(),
        "network": "mobilenet_v2", "res": res,
        "rounds": rounds, "requests_per_arm": rounds * args.per_round,
        "p50_disabled_ms": round(p50_dis, 4),
        "p50_enabled_ms": round(p50_en, 4),
        "overhead_pct": round(overhead, 3),
        "decomposition": {
            "max_residual_pct": round(max_resid, 4),
            "per_request": decomp[:16],
        },
        "span_table": span_table,
        "trace_events": len(chrome["traceEvents"]),
        "trace_dropped": chrome["otherData"]["dropped_spans"],
        "serve_stats": {k: v for k, v in stats_snapshot.items()
                        if isinstance(v, int)},
        "gates": {
            "overhead_lt_10pct": overhead < 10.0,
            "decomposition_residual_lt_1pct": max_resid < 1.0,
            "valid_chrome_trace": bool(valid),
            "layer_spans_present": n_layer_spans > 0,
        },
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"[observe] p50 disabled {p50_dis:.3f} ms, enabled "
          f"{p50_en:.3f} ms -> overhead {overhead:+.2f}%", flush=True)
    print(f"[observe] decomposition max residual {max_resid:.4f}% over "
          f"{len(decomp)} requests; {len(chrome['traceEvents'])} trace "
          f"events -> {trace_out}", flush=True)
    print(f"[observe] gates: {doc['gates']}", flush=True)
    print(f"[observe] wrote {args.out} (+ {metrics_out}) in "
          f"{time.time() - t_start:.0f}s", flush=True)
    if not all(doc["gates"].values()):
        raise SystemExit(f"observe gates failed: {doc['gates']}")


if __name__ == "__main__":
    main()
